"""The train/serve CLI drivers end-to-end (tiny reduced runs, subprocess)."""

import os
import subprocess
import sys

from conftest import SRC


def _run_module(mod: str, *args: str, devices: int = 2, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-u", "-m", mod, *args],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


def test_train_cli_runs_and_resumes(tmp_path):
    out = _run_module(
        "repro.launch.train", "--arch", "smollm-360m", "--reduced",
        "--steps", "6", "--batch", "2", "--seq", "32", "--mesh", "2x1",
        "--ckpt", str(tmp_path), "--ckpt-every", "3")
    assert "done: 6 steps" in out
    out = _run_module(
        "repro.launch.train", "--arch", "smollm-360m", "--reduced",
        "--steps", "8", "--batch", "2", "--seq", "32", "--mesh", "2x1",
        "--ckpt", str(tmp_path), "--resume")
    assert "resumed step 6" in out
    assert "done: 2 steps" in out


def test_serve_cli(tmp_path):
    out = _run_module(
        "repro.launch.serve", "--arch", "musicgen-large", "--reduced",
        "--batch", "2", "--prompt-len", "8", "--new-tokens", "4",
        "--mesh", "2x1")
    assert "generated 8 tokens" in out
