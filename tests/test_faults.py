"""Fault-tolerant fabric (ISSUE-6): deterministic injection, model-driven
deadlines, the escalation ladder, and lease failover.

In-process tests cover the pure machinery (plans, policies, watchdog,
completion-unit cancel, scheduler bookkeeping in model-only mode); the
subprocess tests drive real 8-device dispatch through injected faults and
assert the headline contract — recoverable faults leave job results
bit-identical to a fault-free run.
"""

import math

import numpy as np
import pytest

from repro.core.completion import CompletionUnit
from repro.core.fabric import FabricScheduler, LeaseUnavailable
from repro.core.faults import (
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    deadline_cycles,
    predict_recovery,
    probe_bound,
)
from repro.core.policy import OffloadPolicy, RetryPolicy
from repro.ft.straggler import StepWatchdog, WatchdogConfig


# -- fault plans -------------------------------------------------------------


def test_fault_plan_seeded_deterministic():
    a = FaultPlan.random(7, n_faults=4, num_clusters=8, max_dispatch=6)
    b = FaultPlan.random(7, n_faults=4, num_clusters=8, max_dispatch=6)
    assert a.faults == b.faults
    c = FaultPlan.random(8, n_faults=4, num_clusters=8, max_dispatch=6)
    assert a.faults != c.faults
    assert len(a) == 4


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="non-empty cluster set"):
        FaultSpec(FaultKind.CLUSTER_DEATH)
    with pytest.raises(ValueError, match="factor > 0"):
        FaultSpec(FaultKind.STRAGGLE)
    with pytest.raises(ValueError, match="count >= 1"):
        FaultSpec(FaultKind.LOST_ARRIVAL, count=0)
    with pytest.raises(ValueError, match="at_dispatch"):
        FaultSpec(FaultKind.LOST_ARRIVAL, at_dispatch=-1)
    # string kinds coerce (the enum is string-valued, like every policy enum)
    assert FaultSpec("straggle", factor=2.0).kind is FaultKind.STRAGGLE


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="deadline_factor"):
        RetryPolicy(deadline_factor=1.0)
    with pytest.raises(ValueError, match="backoff"):
        RetryPolicy(backoff=0.5)
    with pytest.raises(TypeError, match="RetryPolicy"):
        OffloadPolicy(retry="retry")       # type: ignore[arg-type]
    assert OffloadPolicy(retry=RetryPolicy()).retry.max_attempts == 3


def test_deadline_formula():
    retry = RetryPolicy(deadline_factor=3.0, backoff=2.0)
    for attempt in range(4):
        assert deadline_cycles(1000.0, retry, attempt) == (
            3000.0 * 2.0 ** attempt)


def test_probe_bound_shape():
    assert probe_bound(8, 0) == 1                   # transient: one clean probe
    assert probe_bound(8, 1) == 1 + 2 * 3           # one dead in 8: 3 levels
    assert probe_bound(8, 2) > probe_bound(8, 1)
    assert probe_bound(1, 1) == 1 + 2 * 1 * 1


def test_predict_recovery_positive_and_monotone():
    from repro.core import jobs
    job = jobs.make_axpy(512)
    retry = RetryPolicy()
    lost = FaultPlan([FaultSpec(FaultKind.LOST_ARRIVAL)])
    death = FaultPlan([FaultSpec(FaultKind.CLUSTER_DEATH, clusters=(1, 2))])
    r_lost = predict_recovery(job, 4, lost, retry)
    r_death = predict_recovery(job, 4, death, retry)
    assert 0 < r_lost < r_death                     # localization costs more
    assert predict_recovery(job, 4, FaultPlan([]), retry) == 0.0


# -- the watchdog satellite (shared-default bug + model-seeded cold start) ---


def test_watchdog_config_not_shared_across_instances():
    w1, w2 = StepWatchdog(), StepWatchdog()
    assert w1.cfg is not w2.cfg
    w1.cfg.deadline_factor = 99.0                   # the old aliasing bug
    assert w2.cfg.deadline_factor == 3.0


def test_watchdog_cold_start_seeded_by_estimate():
    cold = StepWatchdog()
    assert cold.deadline() == float("inf")          # undecidable: never trips
    assert not cold.is_late(started_at=0.0, now=1e9)
    seeded = StepWatchdog(WatchdogConfig(min_deadline_s=0.01), estimate=0.2)
    assert seeded.deadline() == pytest.approx(3.0 * 0.2)
    assert seeded.is_late(started_at=0.0, now=0.7)
    # history takes over once observed (the rolling-p50 warm path)
    for lat in (0.05, 0.06, 0.07):
        seeded.observe(lat)
    assert seeded.deadline() == pytest.approx(3.0 * 0.06)


# -- completion-unit cancel (the failure detector's reset) -------------------


def test_completion_unit_cancel_resets_registers():
    unit = CompletionUnit(n_units=2)
    unit.program(4, job_id=0)
    unit.arrive(0, 3)
    assert unit.outstanding() == {0: 1}
    assert unit.cancel(0) == 1                      # returns the missing count
    assert unit.outstanding() == {}
    unit.program(4, job_id=2)                       # the copy is reusable
    unit.arrive(2, 4)
    unit.collect(2)
    assert unit.cancel(2) == 0                      # no-op on a clean register


# -- injector schedule (no devices needed) -----------------------------------


def test_injector_effects_keyed_by_dispatch_index():
    from repro.core import jobs
    spec = jobs.make_axpy(512).spec
    plan = FaultPlan([
        FaultSpec(FaultKind.LOST_ARRIVAL, at_dispatch=1, count=2),
        FaultSpec(FaultKind.CLUSTER_DEATH, at_dispatch=2, clusters=(5,)),
    ])
    inj = FaultInjector(plan)
    rt = object()
    inj.on_dispatch(rt, 0, (0, 1, 2, 3), spec)      # dispatch 0: clean
    assert inj.lost_arrivals(rt, 0) == 0
    inj.on_dispatch(rt, 1, (0, 1, 2, 3), spec)      # dispatch 1: 2 lost
    assert inj.lost_arrivals(rt, 1) == 2
    inj.on_dispatch(rt, 2, (4, 5, 6, 7), spec)      # dispatch 2: 5 dies
    assert inj.dead_clusters == frozenset({5})
    assert inj.lost_arrivals(rt, 2) == 1
    inj.on_dispatch(rt, 3, (4, 5), spec)            # death is persistent
    assert inj.lost_arrivals(rt, 3) == 1
    inj.revive([5])
    inj.on_dispatch(rt, 4, (4, 5), spec)
    assert inj.lost_arrivals(rt, 4) == 0
    assert inj.dispatch_index == 5
    assert inj.injected["lost_arrival"] == 1
    assert inj.injected["cluster_death"] == 1


# -- scheduler bookkeeping (model-only fabric) -------------------------------


def test_fail_clusters_quarantines_and_fails_over():
    sched = FabricScheduler(num_clusters=8)
    lease = sched.request("t", clusters=[0, 1, 2, 3])
    replaced = sched.fail_clusters([1])
    assert len(replaced) == 1 and replaced[0].lease_id == lease.lease_id
    assert replaced[0].clusters == (4, 5, 6, 7)     # equal-size healthy window
    assert sched.current_lease(lease) is replaced[0]
    assert sched.unhealthy_clusters() == (1,)
    assert 1 not in sched.free_clusters()
    with pytest.raises(LeaseUnavailable, match="unhealthy"):
        sched.request("u", clusters=[1])
    h = sched.health()
    assert h.failed_clusters == 1 and h.failovers == 1
    assert h.degradations == 0 and h.lost_leases == 0
    # repeated failure of the same cluster is idempotent
    sched.fail_clusters([1])
    assert sched.health().failed_clusters == 1
    sched.restore_clusters([1])
    assert sched.unhealthy_clusters() == ()
    assert 1 in sched.free_clusters()


def test_failover_degrades_when_no_equal_window():
    sched = FabricScheduler(num_clusters=8)
    lease = sched.request("t", n=4)                 # [0-3]
    sched.request("other", clusters=[4, 5])         # fragment the free space
    replaced = sched.fail_clusters([0])
    assert replaced[0].n == 2                       # largest pow2 that fits
    h = sched.health()
    assert h.failovers == 1 and h.degradations == 1
    assert lease.lease_id == replaced[0].lease_id


def test_failover_loses_lease_when_fabric_exhausted():
    sched = FabricScheduler(num_clusters=2)
    lease = sched.request("t", n=2)
    replaced = sched.fail_clusters([0, 1])
    assert replaced == ()
    assert sched.current_lease(lease) is None
    h = sched.health()
    assert h.lost_leases == 1 and h.failovers == 0


def test_reliable_path_rejects_resident_operands():
    from repro.core import jobs
    from repro.core.policy import Residency
    from repro.core.session import Session
    sess = Session(devices=["d0"])
    with pytest.raises(ValueError, match="host operand snapshots"):
        sess.submit(jobs.make_axpy(512), Residency.RESIDENT,
                    policy=OffloadPolicy(retry=RetryPolicy()))


# -- real dispatch under injection (8 simulated clusters) --------------------


def test_recovery_bit_identical_transient_and_backup(subproc):
    """Lost arrival -> in-place resubmit; straggle -> backup race; cluster
    death -> probe + disjoint backup window.  All three recover to the
    bit-exact fault-free result and count correctly in health()."""
    subproc("""
import numpy as np
from repro.api import (FaultInjector, FaultKind, FaultPlan, FaultSpec,
                       OffloadPolicy, RetryPolicy, Session)
from repro.core import jobs

job = jobs.make_axpy(512)
ops, _ = job.make_instance(0)
ref = np.asarray(Session().submit(job, dict(ops), n=4).wait())

# transient lost arrival: rung 1 (resubmit in place)
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.LOST_ARRIVAL,
                                         at_dispatch=0, count=1)]))
sess = Session(policy=OffloadPolicy(retry=RetryPolicy()), faults=inj)
out = np.asarray(sess.submit(job, dict(ops), n=4).wait())
np.testing.assert_array_equal(out, ref)
h = sess.health()
assert (h.deadline_trips, h.retries, h.probes, h.backups) == (1, 1, 1, 0), h
assert h.jobs_ok == 1 and h.jobs_failed == 0
sess.close()

# straggler past the deadline: speculative backup race, backup wins,
# results bit-equal either way
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.STRAGGLE,
                                         at_dispatch=0, factor=10.0)]))
sess = Session(policy=OffloadPolicy(retry=RetryPolicy()), faults=inj)
out = np.asarray(sess.submit(job, dict(ops), n=4).wait())
np.testing.assert_array_equal(out, ref)
h = sess.health()
assert h.backups == 1 and h.deadline_trips == 1 and h.retries == 0, h
sess.close()

# a mild straggler inside the deadline: no trip, no backup
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.STRAGGLE,
                                         at_dispatch=0, factor=0.5)]))
sess = Session(policy=OffloadPolicy(retry=RetryPolicy()), faults=inj)
out = np.asarray(sess.submit(job, dict(ops), n=4).wait())
np.testing.assert_array_equal(out, ref)
assert sess.health().deadline_trips == 0
sess.close()

# cluster death: rung 2 (bisection probes, disjoint backup window)
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.CLUSTER_DEATH,
                                         at_dispatch=0, clusters=(1,))]))
sess = Session(policy=OffloadPolicy(retry=RetryPolicy()), faults=inj)
out = np.asarray(sess.submit(job, dict(ops), n=4).wait())
np.testing.assert_array_equal(out, ref)
h = sess.health()
assert h.probes >= 1 and h.backups == 1 and h.jobs_ok == 1, h
sess.close()

# exhaustion: every cluster dead -> FaultError after max_attempts
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.CLUSTER_DEATH,
                                         at_dispatch=0,
                                         clusters=tuple(range(8)))]))
sess = Session(policy=OffloadPolicy(retry=RetryPolicy(max_attempts=2,
                                                      failover=False)),
               faults=inj)
from repro.api import FaultError
try:
    sess.submit(job, dict(ops), n=4).wait()
    raise SystemExit("expected FaultError")
except FaultError:
    pass
assert sess.health().jobs_failed >= 1
print("OK")
""")


def test_lease_failover_and_degradation_bit_identical(subproc):
    """Scheduler-mediated failover: a dead lease window is re-placed on
    healthy clusters (resident operands restaged), shrinking gracefully
    when no equal window exists — results stay bit-identical."""
    subproc("""
import jax, numpy as np
from repro.api import (FabricScheduler, FaultInjector, FaultKind, FaultPlan,
                       FaultSpec, OffloadPolicy, Residency, RetryPolicy,
                       Session, Tenant)
from repro.core import jobs

job = jobs.make_axpy(512)
ops, _ = job.make_instance(0)
ref4 = np.asarray(Session().submit(job, dict(ops), n=4).wait())

# whole lease dies -> rung 3: fail_clusters re-places it on [4-7]
sched = FabricScheduler(jax.devices())
lease = sched.request(Tenant("t"), clusters=[0, 1, 2, 3])
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.CLUSTER_DEATH,
                                         at_dispatch=0,
                                         clusters=(0, 1, 2, 3))]))
sess = Session(lease=lease, policy=OffloadPolicy(retry=RetryPolicy()),
               faults=inj)
out = np.asarray(sess.submit(job, dict(ops), n=4).wait())
np.testing.assert_array_equal(out, ref4)
assert tuple(sess.lease.clusters) == (4, 5, 6, 7)
assert sess.health().failovers == 1
fh = sched.health()
assert fh.failovers == 1 and fh.failed_clusters == 4
sess.close()
assert sched.leases == ()                     # close released the new lease

# degradation: whole-mesh lease, one cluster dies, no equal-size healthy
# window exists -> shrink to 4 (AXPY shards on out axis: bit-equal to n=4)
sched = FabricScheduler(jax.devices())
lease = sched.request(Tenant("t"), n=8)
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.CLUSTER_DEATH,
                                         at_dispatch=0, clusters=(2,))]))
sess = Session(lease=lease,
               policy=OffloadPolicy(retry=RetryPolicy(backup=False)),
               faults=inj)
out = np.asarray(sess.submit(job, dict(ops), n=8).wait())
np.testing.assert_array_equal(out, ref4)
assert sess.health().degraded == 1
assert sched.health().degradations == 1
assert len(sess.lease.clusters) == 4
sess.close()

# resident operands survive a failover: restaged from host snapshots
sched = FabricScheduler(jax.devices())
lease = sched.request(Tenant("t"), clusters=[0, 1, 2, 3])
inj = FaultInjector(FaultPlan([FaultSpec(FaultKind.CLUSTER_DEATH,
                                         at_dispatch=99, clusters=(1,))]))
sess = Session(lease=lease, faults=inj)
sess.stage(job, dict(ops), n=4)
r1 = np.asarray(sess.submit(job, Residency.RESIDENT, n=4).wait())
sched.fail_clusters([1])
assert sched.health().restaged_operands >= len(ops)
assert tuple(sess.lease.clusters) == (4, 5, 6, 7)
r2 = np.asarray(sess.submit(job, Residency.RESIDENT, n=4).wait())
np.testing.assert_array_equal(r2, r1)
sess.close()
print("OK")
""")


def test_backup_offload_delay_hook_race(subproc):
    """Wallclock-domain companion: BackupOffload with a deterministic
    delay hook reissues to the disjoint backup set, and the winner's
    result is bit-equal to the healthy primary's."""
    subproc("""
import jax, numpy as np
from repro.api import OffloadRuntime, StepWatchdog, WatchdogConfig
from repro.core import jobs
from repro.ft import BackupOffload

job = jobs.make_axpy(512)
rt = OffloadRuntime(jax.devices())
wd = StepWatchdog(WatchdogConfig(min_deadline_s=0.01), estimate=0.02)
slow = BackupOffload(rt, wd, delay_hook=lambda h: 10.0)
r_backup, _ = slow.run(job, 3, primary=[0, 1], backup=[2, 3])
assert slow.reissues == 1
fast = BackupOffload(OffloadRuntime(jax.devices()),
                     StepWatchdog(estimate=1e9), delay_hook=lambda h: 0.0)
r_primary, expected = fast.run(job, 3, primary=[0, 1], backup=[2, 3])
assert fast.reissues == 0
np.testing.assert_array_equal(np.asarray(r_backup), np.asarray(r_primary))
np.testing.assert_allclose(np.asarray(r_primary), expected, rtol=1e-12)
try:
    fast.run(job, 3, primary=[0, 1], backup=[1, 2])
    raise SystemExit("expected ValueError")
except ValueError:
    pass
print("OK")
""")


def test_serve_tenant_survives_failover_greedy_identical(subproc):
    """A serve tenant whose lease window fails keeps serving: the
    scheduler rebinds the lease, the tenant refreshes its stale
    descriptor, and greedy decode output is identical on the new window."""
    subproc("""
import jax, numpy as np
from repro import models as M
from repro.api import FabricScheduler
from repro.serve import ServeConfig, ServeTenant

cfg = M.reduced(M.get("smollm-360m"))
sched = FabricScheduler(jax.devices())
params = jax.device_get(M.init_params(jax.random.key(0), cfg))
tenant = ServeTenant(sched, cfg, params, ServeConfig(batch=4, max_len=24),
                     floor=2, burst=2)
assert tenant.lease.clusters == (0, 1)
prompts = np.random.default_rng(0).integers(
    0, cfg.vocab_size, (4, 8)).astype(np.int32)
out1 = tenant.generate(prompts, 5)
sched.fail_clusters([0])                      # the floor window dies
out2 = tenant.generate(prompts, 5)            # stale lease refreshed
np.testing.assert_array_equal(out1, out2)     # greedy => deterministic
assert tenant.lease.clusters != (0, 1)
assert sched.health().failovers == 1
tenant.close()
assert sched.leases == ()
print("OK")
""", x64=False, timeout=900)
