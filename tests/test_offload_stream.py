"""OffloadStream + fused dispatch batching.

The window/out-of-order-completion logic is host-side (CompletionUnit), so
the property tests run in-process on the default single device (n=1
cluster); multi-device pipelining and the fused-batch HLO structure run in
8-device subprocesses.
"""

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime
from repro.core.stream import OffloadStream

_K = 6
_JOB = jobs.make_axpy(64)
_INSTS, _EXPECTED = jobs.make_instances(_JOB, _K, seed0=7)

# module-scope runtimes so the 12 property examples share warm plans
_RT = {
    False: OffloadRuntime(n_units=4),
    True: OffloadRuntime(config=OffloadConfig(donate_operands=True),
                         n_units=4),
}
_STREAMS = {d: OffloadStream(_RT[d], _JOB, n=1) for d in (False, True)}
_BASELINE = {}


def _baseline(donate: bool):
    if donate not in _BASELINE:
        rt = OffloadRuntime(
            config=OffloadConfig(donate_operands=donate))
        _BASELINE[donate] = [rt.offload(_JOB, ops, n=1).wait()
                             for ops in _INSTS]
    return _BASELINE[donate]


@settings(max_examples=12, deadline=None)
@given(order=st.permutations(list(range(_K))),
       donate=st.sampled_from([False, True]))
def test_stream_out_of_order_wait_matches_sequential(order, donate):
    """Property: any wait order over a full stream window (including with
    donate_operands=True) yields the sequential results, drains every
    completion cause, and never corrupts plan residency."""
    baseline = _baseline(donate)
    rt, stream = _RT[donate], _STREAMS[donate]
    # prime plan residency independently of the stream's slot staging
    rt.offload(_JOB, _INSTS[0], n=1).wait()

    handles = [stream.submit(ops) for ops in _INSTS]
    results = {i: handles[i].wait() for i in order}
    for i in range(_K):
        assert np.array_equal(results[i], baseline[i]), (i, order, donate)
    assert rt.unit.outstanding() == {}          # all causes drained
    assert stream.inflight == 0 or stream.inflight <= stream.window

    # residency untouched by slot staging: the resident redispatch still
    # returns instance 0's result
    res = rt.offload(_JOB, "resident", n=1).wait()
    assert np.array_equal(res, baseline[0]), (order, donate)


def test_stream_window_bounded_by_completion_units():
    rt = OffloadRuntime(n_units=2)
    stream = OffloadStream(rt, _JOB, n=1)
    assert stream.window == 2
    handles = [stream.submit(ops) for ops in _INSTS]
    # 6 submits through a 2-deep window force 4 stalls, never > 2 in flight
    assert stream.stats["window_stalls"] == _K - 2
    assert stream.inflight <= 2
    out = stream.drain()
    assert stream.inflight == 0
    assert len(out) == 2                        # the still-in-flight tail
    for h, exp in zip(handles, _EXPECTED):
        assert np.allclose(h.wait(), exp, rtol=1e-4, atol=1e-5)


def test_stream_resident_submit():
    """submit("resident") pipelines the zero-staging redispatch; before
    any plan/residency exists it fails loudly."""
    rt = OffloadRuntime(n_units=4)
    rt.offload(_JOB, _INSTS[0], n=1).wait()
    stream = OffloadStream(rt, _JOB, n=1)
    puts = rt.stats.device_puts
    handles = [stream.submit("resident") for _ in range(5)]
    baseline = _baseline(False)
    for h in handles:
        assert np.array_equal(h.wait(), baseline[0])
    assert rt.stats.device_puts == puts          # zero uploads
    fresh = OffloadStream(OffloadRuntime(), _JOB, n=1)
    try:
        fresh.submit("resident")
        raise AssertionError("expected KeyError without a primed plan")
    except KeyError:
        pass


def test_stream_rejects_bad_depth_and_window_cap():
    rt = OffloadRuntime(n_units=4)
    for bad in (dict(depth=0), dict(window=0), dict(window=-1)):
        try:
            OffloadStream(rt, _JOB, n=1, **bad)
            raise AssertionError(f"expected ValueError for {bad}")
        except ValueError:
            pass
    assert OffloadStream(rt, _JOB, n=1, window=64).window == 4


def test_stream_pipelined_multi_device(subproc):
    """8-device stream: zero recompiles/plan rebuilds while pipelining,
    double-buffer staging counts, results equal fresh offloads."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime
from repro.core.stream import OffloadStream

job = jobs.make_axpy(2048)
insts, exps = jobs.make_instances(job, 10, seed0=3)
rt = OffloadRuntime(n_units=4)
stream = OffloadStream(rt, job, n=8)
res = stream.map(insts)
compiled = len(rt._compiled)
misses = rt.plan_misses
res2 = stream.map(list(reversed(insts)))
assert len(rt._compiled) == compiled        # zero recompiles while streaming
assert rt.plan_misses == misses             # one plan for the whole stream
for r, e in zip(res, exps):
    assert np.allclose(r, e, rtol=1e-9, atol=1e-9)
for r, e in zip(res2, reversed(exps)):
    assert np.allclose(r, e, rtol=1e-9, atol=1e-9)
# every submit staged its own operands (x, y) into a slot: 2 puts/job
assert rt.stats.device_puts == 2 * 20 + 1   # + the args upload
assert stream.stats["submitted"] == 20
assert rt.unit.outstanding() == {}
print("OK")
""")


def test_fused_dispatch_batching_all_kernels(subproc):
    """offload_fused(B) == B sequential offloads for every paper kernel;
    one completion-unit program per fused launch."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime

rt = OffloadRuntime()
for name, mk in jobs.PAPER_JOBS.items():
    job = mk() if name != "bfs" else mk(64)
    insts, exps = jobs.make_instances(job, 4, seed0=1)
    seq = [rt.offload(job, ops, n=4).wait() for ops in insts]
    fused = rt.offload_fused(job, insts, n=4).wait_each()
    for s, f, e in zip(seq, fused, exps):
        assert np.array_equal(s, f), name            # bit-for-bit vs serial
        assert np.allclose(f, e, rtol=1e-9, atol=1e-9), name
assert rt.unit.outstanding() == {}
print("OK")
""")


def test_fused_hlo_collectives_independent_of_B(subproc):
    """The fused program's collective count must not grow with B — the
    whole point of batching under one launch (O(1) wakeup analogue)."""
    subproc("""
from repro.core import jobs
from repro.core.offload import OffloadRuntime, count_collectives

rt = OffloadRuntime()
for mk in (jobs.make_axpy, jobs.make_atax, jobs.make_montecarlo):
    job = mk()
    c1 = count_collectives(rt.lowered_text(job, 8))
    c2 = count_collectives(rt.lowered_text(job, 8, fuse=2))
    c8 = count_collectives(rt.lowered_text(job, 8, fuse=8))
    assert c2 == c8, (job.spec.name, c2, c8)
    # fused launch adds no collective kinds over the single-job program
    for kind, n in c8.items():
        assert n <= max(c1[kind], 1), (job.spec.name, kind, c1, c8)
# the text cache returns the identical object on repeat queries
t = rt.lowered_text(jobs.make_axpy(), 8, fuse=8)
assert t is rt.lowered_text(jobs.make_axpy(), 8, fuse=8)
print("OK")
""")


def test_fused_resident_and_donation(subproc):
    """Resident fused redispatch under donate_operands self-heals exactly
    like the single-job plan."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime

rt = OffloadRuntime(config=OffloadConfig(donate_operands=True))
job = jobs.make_axpy(1024)
insts, exps = jobs.make_instances(job, 4, seed0=2)
r0 = rt.offload_fused(job, insts, n=8).wait()
r1 = rt.offload_fused(job, "resident", batch=4, n=8).wait()
r2 = rt.offload_fused(job, "resident", batch=4, n=8).wait()
assert np.array_equal(r0, r1) and np.array_equal(r1, r2)
for i, e in enumerate(exps):
    assert np.allclose(r0[i], e)
assert rt.stats.fused_jobs == 3 * 4
assert len(rt._compiled) == 1               # one fused program, ever
print("OK")
""")
