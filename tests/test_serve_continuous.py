"""Continuous batching (generate_many) + sub-batch padding (subprocess)."""


def test_subbatch_padding_matches_full_batch(subproc):
    """generate() on b < batch pads to the configured batch and slices:
    real rows' tokens are identical to the same rows in a full batch."""
    subproc("""
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
prompts = np.random.default_rng(2).integers(
    0, cfg.vocab_size, (4, 9)).astype(np.int32)
eng = ServeEngine(cfg, params, mesh, ServeConfig(batch=4, max_len=40))
full = eng.generate(prompts, 6)
for b in (1, 2, 3):
    sub = eng.generate(prompts[:b], 6)
    assert sub.shape == (b, 6)
    np.testing.assert_array_equal(sub, full[:b])
assert eng.stats["batch_padded_rows"] == 3 + 2 + 1
try:
    eng.generate(np.concatenate([prompts, prompts]), 6)
    raise SystemExit("expected ValueError for oversized batch")
except ValueError:
    pass
print("OK")
""", devices=8, x64=False, timeout=900)


def test_generate_many_matches_static_and_is_schedule_independent(subproc):
    """Greedy continuous batching == static generate for a full same-length
    batch, and each request's tokens are independent of co-scheduling
    (variable lengths, staggered arrivals, R > batch)."""
    subproc("""
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
rng = np.random.default_rng(5)
prompts = rng.integers(0, cfg.vocab_size, (4, 9)).astype(np.int32)

eng = ServeEngine(cfg, params, mesh,
                  ServeConfig(batch=4, max_len=48, prefill_bucket=8))
ref = eng.generate(prompts, 6)
outs = eng.generate_many([(prompts[i], 6) for i in range(4)])
for i in range(4):
    np.testing.assert_array_equal(outs[i], ref[i])

# variable lengths (incl. a single-token prompt: insert with no prefill),
# staggered arrivals, more requests than slots
lens = [1, 9, 7, 12, 6, 9]
reqs = [(rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32), 5)
        for s in lens]
outs2 = eng.generate_many(reqs, arrival_steps=[0, 0, 1, 3, 6, 8])
assert [len(o) for o in outs2] == [5] * 6
assert eng.stats["requests_retired"] >= 10
# schedule independence: each request alone emits the same greedy tokens
for i in (0, 3, 5):
    solo = eng.generate_many([reqs[i]])[0]
    np.testing.assert_array_equal(outs2[i], solo)

# mid-stream insert really interleaves: slots were refilled, not batched
assert eng.stats["prefill_inserts"] >= 4 + 6 + 3
print("OK")
""", devices=8, x64=False, timeout=900)


def test_generate_many_temperature_reproducible(subproc):
    """Temperature sampling through the ragged step: a fixed seed and a
    fixed schedule reproduce exactly; tokens stay in range."""
    subproc("""
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
rng = np.random.default_rng(9)
reqs = [(rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32), 4)
        for s in (4, 7, 6, 9, 5)]
# prefill_bucket > max_len exercises the bucket cap (prefill padded to
# the cache length, never past it)
eng = ServeEngine(cfg, params, mesh,
                  ServeConfig(batch=2, max_len=32, temperature=0.7,
                              prefill_bucket=64))
a = eng.generate_many(reqs, arrival_steps=[0, 0, 2, 4, 4])
b = eng.generate_many(reqs, arrival_steps=[0, 0, 2, 4, 4])
for x, y in zip(a, b):
    np.testing.assert_array_equal(x, y)
    assert (x >= 0).all() and (x < cfg.vocab_size).all()
print("OK")
""", devices=8, x64=False, timeout=900)
