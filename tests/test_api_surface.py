"""Public-API surface snapshot (ISSUE-4 satellite).

``repro.api`` is the framework's stable surface.  This test pins its
exported names and the parameter lists of every public callable, so a
refactor that silently renames a parameter, drops an export, or changes
a default's *presence* fails here — loudly — instead of breaking
downstream callers.  Intentional surface changes update SNAPSHOT in the
same commit.
"""

import enum
import inspect

import repro.api as api


def _params(fn):
    """Parameter names with a ``=`` suffix for defaulted ones."""
    out = []
    for p in inspect.signature(fn).parameters.values():
        if p.name.startswith("_") or p.name == "self":
            continue
        name = p.name
        if p.kind is inspect.Parameter.VAR_POSITIONAL:
            name = "*" + name
        elif p.kind is inspect.Parameter.VAR_KEYWORD:
            name = "**" + name
        elif p.default is not inspect.Parameter.empty:
            name += "="
        out.append(name)
    return tuple(out)


EXPORTS = (
    "AUTO", "BackupOffload", "ClusterLease", "Completion",
    "CompletionTimeout", "Diagnostic", "DiagnosticsLog",
    "DonatedOperandError", "Estimate",
    "Explain", "FabricHealth",
    "FabricScheduler", "FaultError", "FaultInjector", "FaultKind",
    "FaultPlan", "FaultSpec", "Fix", "GraphError", "GraphHandle",
    "GraphNode",
    "InfoDist", "JobHandle", "LeaseError",
    "LeaseUnavailable", "MulticastRequest", "OffloadConfig", "OffloadPolicy",
    "OffloadRuntime", "Overloaded", "PAPER_JOBS", "PaperJob", "PendingLease",
    "PerfFinding", "PlanDecision", "PlanStats",
    "Planner", "Ref", "ReliableHandle", "Residency", "RetryPolicy",
    "SanitizerError",
    "SchedulerPolicy", "Scoreboard", "ServeConfig", "ServeEngine",
    "ServeTenant",
    "Session", "SessionHandle", "SessionHealth", "Severity", "Staging",
    "StepWatchdog",
    "Tenant", "TenantKind", "UnknownDiagnosticCode", "VerificationError",
    "WatchdogConfig",
    "deadline_cycles",
    "elastic_restore", "estimate", "explain", "lint", "lint_graph",
    "make_instances",
    "predict_recovery",
    "predict_staging", "verify", "verify_graph", "verify_policy",
)

ENUMS = {
    "Staging": ("DIRECT", "HOST_FANOUT", "TREE", "TREE_RESHARD"),
    "Residency": ("FRESH", "RESIDENT"),
    "InfoDist": ("MULTICAST", "P2P_CHAIN"),
    "Completion": ("UNIT", "CENTRAL_COUNTER"),
    "Severity": ("ERROR", "WARNING", "PERF"),
    "TenantKind": ("OFFLOAD", "SERVE"),
    "FaultKind": ("CLUSTER_DEATH", "STRAGGLE", "HOST_LINK_STALL",
                  "LOST_ARRIVAL"),
}

SNAPSHOT = {
    "OffloadPolicy": ("staging=", "residency=", "info_dist=", "completion=",
                      "fuse=", "window=", "depth=", "donate_operands=",
                      "retry="),
    "OffloadPolicy.pinned": ("**fields",),
    "RetryPolicy": ("max_attempts=", "deadline_factor=", "backoff=",
                    "backup=", "failover="),
    "OffloadConfig": ("info_dist=", "completion=", "donate_operands=",
                      "staging="),
    "Planner": ("params=", "max_fuse=", "tree_min_bytes="),
    "Planner.decide": ("job", "clusters", "batch", "policy", "n_units",
                       "operands="),
    "Session": ("devices=", "lease=", "policy=", "n_units=", "params=",
                "planner=", "runtime=", "faults=", "verify=", "lint=",
                "diag_limit="),
    "Session.submit": ("job", "operands", "policy=", "job_args=", "n=",
                       "request=", "clusters=", "after=", "lint="),
    "Session.submit_graph": ("nodes", "policy=", "lint="),
    "GraphNode": ("job", "operands", "name=", "job_args=", "after=", "n=",
                  "request=", "clusters=", "fetch=", "session="),
    "Ref": ("node",),
    "GraphHandle.wait": (),
    "GraphHandle.result": ("node",),
    "FabricScheduler.submit_graph": ("nodes", "policy="),
    "Session.estimate": ("job", "batch=", "policy=", "n=", "clusters=",
                         "operands="),
    "Session.stage": ("job", "operands", "policy=", "n=", "request=",
                      "clusters="),
    "Session.drain": (),
    "Session.close": (),
    "Session.health": (),
    "Session.runtime": ("policy=",),
    "FabricScheduler": ("devices=", "num_clusters=", "params=", "policy="),
    "FabricScheduler.fail_clusters": ("clusters",),
    "FabricScheduler.restore_clusters": ("clusters",),
    "FabricScheduler.health": (),
    "FabricScheduler.current_lease": ("lease",),
    "FabricScheduler.request": ("tenant", "n=", "clusters=", "job=",
                                "batch=", "queue="),
    "FabricScheduler.release": ("lease",),
    "FabricScheduler.resize": ("lease", "n"),
    "FabricScheduler.session": ("tenant", "n=", "clusters=", "job=",
                                "batch=", "**session_kwargs"),
    "FabricScheduler.preempt": ("lease", "queue="),
    "FabricScheduler.revoke": ("lease",),
    "FabricScheduler.cancel": ("pending",),
    "FabricScheduler.compact": ("max_moves=",),
    "FabricScheduler.drain_deadline": ("lease",),
    "FabricScheduler.predict_retry_after": ("job=", "batch="),
    "ClusterLease": ("lease_id", "tenant", "clusters", "scheduler="),
    "ClusterLease.requests": (),
    "Tenant": ("name", "kind=", "weight=", "slo=", "priority="),
    "SchedulerPolicy": ("placement=", "align=", "share_slack=",
                        "preemption=", "max_queue_depth=", "aging_grants="),
    "Overloaded": ("message", "retry_after_cycles="),
    "ServeTenant": ("scheduler", "cfg", "host_params", "scfg", "tenant=",
                    "floor=", "burst=", "call="),
    "ServeTenant.generate": ("prompts", "n_new", "extra_inputs="),
    "SessionHandle.wait": (),
    "SessionHandle.explain": (),
    "ReliableHandle.wait": (),
    "ReliableHandle.explain": (),
    "FaultSpec": ("kind", "at_dispatch=", "clusters=", "factor=", "count="),
    "FaultPlan": ("faults=",),
    "FaultPlan.random": ("seed", "n_faults=", "num_clusters=",
                         "max_dispatch=", "kinds=", "max_factor="),
    "FaultInjector": ("plan", "params="),
    "StepWatchdog": ("cfg=", "estimate="),
    "deadline_cycles": ("base_cycles", "retry", "attempt="),
    "predict_recovery": ("job", "n", "plan", "retry", "params=",
                         "probe_n="),
    "estimate": ("job", "n=", "clusters=", "batch=", "policy=", "n_units=",
                 "params=", "operands=", "planner="),
    "predict_staging": ("nbytes", "clusters", "staging", "params="),
    "OffloadRuntime.offload": ("job", "operands", "job_args=", "n=",
                               "request=", "clusters="),
    "ServeConfig": ("batch=", "max_len=", "temperature=", "seed=",
                    "decode_mode=", "decode_chunk=", "prefill_bucket=",
                    "staging="),
    "ServeEngine.generate": ("prompts", "n_new", "extra_inputs="),
    "ServeEngine.generate_many": ("requests", "arrival_steps="),
    "Diagnostic": ("code", "message", "severity=", "node=", "name=",
                   "suggestion="),
    "Diagnostic.to_json": (),
    "Diagnostic.from_json": ("payload",),
    "Diagnostic.as_error": ("cls=",),
    "explain": ("code",),
    "verify": ("job", "policy=", "lease=", "operands=", "n=", "clusters=",
               "n_units="),
    "verify_graph": ("nodes", "policy=", "n_units=", "default_width=",
                     "session="),
    "verify_policy": ("policy=", "**fields"),
}


def test_exported_names():
    assert tuple(sorted(api.__all__)) == EXPORTS
    for name in EXPORTS:
        assert hasattr(api, name), name


def test_enum_members_pinned():
    for name, members in ENUMS.items():
        cls = getattr(api, name)
        assert issubclass(cls, enum.Enum)
        assert tuple(m.name for m in cls) == members, name


def test_auto_policy_shape():
    assert isinstance(api.AUTO, api.OffloadPolicy)
    assert api.AUTO.staging is None
    assert api.AUTO.fuse is None
    assert api.AUTO.window is None


def test_signatures_pinned():
    mismatches = {}
    for path, expected in SNAPSHOT.items():
        obj = api
        for part in path.split("."):
            obj = getattr(obj, part)
        got = _params(obj)
        if got != expected:
            mismatches[path] = got
    assert not mismatches, (
        "public-API signature drift — update tests/test_api_surface.py "
        f"SNAPSHOT intentionally: {mismatches}")
