"""The real JAX offload runtime on an 8-device CPU mesh (subprocess-isolated
so the main test process keeps its single default device)."""

import pytest


def test_all_jobs_both_modes(subproc):
    subproc("""
import jax, numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig
for cfg in (OffloadConfig.extended(), OffloadConfig.baseline()):
    rt = OffloadRuntime(config=cfg)
    for name, mk in jobs.PAPER_JOBS.items():
        job = mk() if name != "bfs" else mk(64)
        got, expected = rt.run(job, seed=1, n=8)
        assert np.allclose(got, expected, rtol=1e-9, atol=1e-9), (cfg, name)
print("OK")
""")


def test_collective_structure(subproc):
    """Baseline = O(n) chain of collective-permutes (2(n-1)); multicast =
    a single fused all-reduce.  The paper's co-design, visible in the HLO."""
    out = subproc("""
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig, count_collectives
job = jobs.make_axpy(1024)
mc = count_collectives(OffloadRuntime(config=OffloadConfig.extended()).lowered_text(job, 8))
bl = count_collectives(OffloadRuntime(config=OffloadConfig.baseline()).lowered_text(job, 8))
assert mc["collective-permute"] == 0, mc
assert mc["all-reduce"] <= 2, mc
assert bl["collective-permute"] == 2 * (8 - 1), bl
print("mc", mc)
print("bl", bl)
""")
    assert "mc" in out


def test_mask_selected_subsets(subproc):
    """Fig.-5 style subcube selections drive which devices participate."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig
from repro.core.multicast import MulticastRequest, CLUSTER_OFFSET_BITS
rt = OffloadRuntime(config=OffloadConfig.extended())
# clusters {1,3,5,7} = base 1, mask bits {1,2} of the cluster index
req = MulticastRequest(addr=1 << CLUSTER_OFFSET_BITS,
                       mask=0b110 << CLUSTER_OFFSET_BITS)
devs, ids = rt.select_clusters(request=req)
assert ids == [1, 3, 5, 7], ids
got, expected = rt.run(jobs.make_axpy(512), seed=2, request=req)
assert np.allclose(got, expected)
# arbitrary non-subcube set covered greedily
devs, ids = rt.select_clusters(clusters=[0, 1, 2, 5])
assert sorted(ids) == [0, 1, 2, 5]
got, expected = rt.run(jobs.make_axpy(512), seed=3, clusters=[0, 1, 2, 5])
assert np.allclose(got, expected)
print("OK")
""")


def test_multiple_outstanding_jobs(subproc):
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig
rt = OffloadRuntime(config=OffloadConfig.extended())
j1, j2 = jobs.make_axpy(256), jobs.make_matmul()
o1, e1 = j1.make_instance(5)
o2, e2 = j2.make_instance(5)
h1 = rt.offload(j1, o1, n=4)
h2 = rt.offload(j2, o2, n=2)
assert set(rt.unit.outstanding()) == {0, 1}
r2 = h2.wait()   # out-of-order completion
r1 = h1.wait()
assert np.allclose(r1, e1) and np.allclose(r2, e2)
print("OK")
""")


def test_wrong_distribution_corrupts_result(subproc):
    """The job-info chain is live: if the baseline chain were wrong (args
    not reaching remote clusters), results would be visibly corrupted —
    guard that the scale factor actually rides the chain."""
    subproc("""
import numpy as np, jax.numpy as jnp
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig
rt = OffloadRuntime(config=OffloadConfig.baseline())
job = jobs.make_axpy(512)
operands, expected = job.make_instance(0)
h = rt.offload(job, operands, job_args=np.full((8,), 2.0), n=8)
got = h.wait()
# args[0]=2.0 scales the output: proves every cluster received the args
assert np.allclose(got, 2.0 * expected)
print("OK")
""")


def test_straggler_backup_offload(subproc):
    """ft: watchdog-triggered speculative re-execution on a disjoint subset."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig
from repro.ft.straggler import BackupOffload, StepWatchdog, WatchdogConfig

rt = OffloadRuntime(config=OffloadConfig.extended())
wd = StepWatchdog(WatchdogConfig(min_deadline_s=0.05, deadline_factor=3.0))
# warm the latency history so the deadline is tight
for _ in range(5):
    wd.observe(0.01)
slow = {"next": 10.0}   # first dispatch straggles 10 s (simulated)
bo = BackupOffload(rt, wd, delay_hook=lambda h: slow.pop("next", 0.0))
job = jobs.make_axpy(512)
r, e = bo.run(job, seed=1, primary=[0, 1, 2, 3], backup=[4, 5, 6, 7])
assert bo.reissues == 1
assert np.allclose(r, e)
# healthy second run: no reissue
r, e = bo.run(job, seed=2, primary=[0, 1, 2, 3], backup=[4, 5, 6, 7])
assert bo.reissues == 1
assert np.allclose(r, e)
print("OK")
""")


def test_offload_wallclock_multicast_not_slower(subproc):
    """Wall-clock sanity on the CPU mesh: the multicast path's dispatch is
    not slower than the chain (it has strictly less collective depth)."""
    out = subproc("""
import time, numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig
job = jobs.make_axpy(4096)
operands, _ = job.make_instance(0)
def bench(cfg):
    rt = OffloadRuntime(config=cfg)
    h = rt.offload(job, operands, n=8); h.wait()   # warmup+compile
    t0 = time.perf_counter()
    for _ in range(20):
        rt.offload(job, operands, n=8).wait()
    return (time.perf_counter() - t0) / 20
t_mc = bench(OffloadConfig.extended())
t_bl = bench(OffloadConfig.baseline())
print(f"mc={t_mc*1e6:.0f}us bl={t_bl*1e6:.0f}us ratio={t_bl/t_mc:.2f}")
assert t_mc < t_bl * 1.5   # generous: CPU dispatch noise
""")
    assert "ratio" in out
