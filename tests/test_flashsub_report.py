"""Unit tests for the perf tooling: flash substitution + report aggregation."""

import json
import os

from repro.launch.flashsub import AttnShape, attn_shape_for, flash_terms, substitute
from repro.launch.report import load_records, roofline_table, summarize
from repro.launch.roofline import Roofline
from repro.models.registry import get


def test_flash_terms_scaling():
    a = AttnShape(layers=2, batch_global=8, heads=4, head_dim=64, seq=1024)
    f1, b1 = flash_terms(a, chips=1)
    f256, b256 = flash_terms(a, chips=256)
    assert f1 / f256 == 256 and b1 / b256 == 256
    # doubling seq quadruples flops, doubles streamed bytes
    a2 = AttnShape(layers=2, batch_global=8, heads=4, head_dim=64, seq=2048)
    f2, b2 = flash_terms(a2, 1)
    assert abs(f2 / f1 - 4.0) < 1e-6
    assert abs(b2 / b1 - 2.0) < 1e-6


def test_attn_shape_per_family():
    assert attn_shape_for(get("falcon-mamba-7b"), "train", 4096, 256) is None
    z = attn_shape_for(get("zamba2-2.7b"), "train", 4096, 256)
    assert z.layers == 9            # shared-block applications, not 54
    d = attn_shape_for(get("deepseek-v2-lite-16b"), "train", 4096, 256)
    assert d.head_dim == 128 + 64   # MLA nope+rope
    p = attn_shape_for(get("yi-9b"), "prefill", 32768, 32)
    assert p.passes_flops == 1.0    # no backward in prefill


def test_substitute_adds_terms():
    stub = Roofline(flops=1e12, bytes_accessed=1e11, collective_bytes=1e9,
                    collectives={}, model_flops=1e15, chips=256)
    a = AttnShape(layers=4, batch_global=32, heads=8, head_dim=128, seq=4096)
    out = substitute(stub, a)
    assert out.flops > stub.flops
    assert out.bytes_accessed > stub.bytes_accessed
    assert out.collective_bytes == stub.collective_bytes
    assert substitute(stub, None) is stub


def test_report_roundtrip(tmp_path):
    rec = {"arch": "a", "shape": "train_4k", "mesh": "pod16x16",
           "status": "ok", "tag": "t",
           "memory": {"argument_bytes_per_device": 1e9,
                      "output_bytes_per_device": 1e9,
                      "temp_bytes_per_device": 2e9,
                      "alias_bytes_per_device": 0},
           "roofline": {"t_compute_s": 1.0, "t_memory_s": 2.0,
                        "t_collective_s": 0.5, "bottleneck": "memory",
                        "useful_flops_fraction": 0.5,
                        "roofline_fraction": 0.25}}
    skip = {"arch": "b", "shape": "long_500k", "mesh": "pod16x16",
            "status": "skipped", "reason": "full-attention", "tag": "t"}
    for i, r in enumerate((rec, skip)):
        with open(os.path.join(tmp_path, f"r{i}.json"), "w") as f:
            json.dump(r, f)
    recs = load_records(str(tmp_path), tag="t")
    assert len(recs) == 2
    table = roofline_table(recs)
    assert "memory" in table and "SKIP" in table
    s = summarize(recs)
    assert "1 ok" in s and "1 documented skips" in s
