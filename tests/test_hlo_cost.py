"""The trip-count-corrected HLO cost analyzer vs analytic ground truth.

These tests also document WHY the module exists: XLA's cost_analysis counts
while bodies once (first test), which would under-count every scan-shaped
program in this framework.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo_text

N = 256
DOT = 2 * N ** 3


def _one(x):
    return jnp.tanh(x @ x)


def _flops(f, *sds):
    comp = jax.jit(f).lower(*sds).compile()
    return comp, analyze_hlo_text(comp.as_text())


SDS = jax.ShapeDtypeStruct((N, N), jnp.float32)


def test_xla_cost_analysis_undercounts_scans():
    def scanned(x):
        def body(c, _):
            return _one(c), None
        return jax.lax.scan(body, x, None, length=7)[0]

    comp, corrected = _flops(scanned, SDS)
    raw = comp.cost_analysis()
    raw = raw[0] if isinstance(raw, list) else raw
    assert raw["flops"] < 2 * DOT            # XLA: body counted once
    assert corrected.flops == pytest.approx(7 * DOT, rel=0.05)


def test_nested_scans():
    def nested(x):
        def inner(c, _):
            return c @ x, None
        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return jnp.tanh(c2), None
        return jax.lax.scan(outer, x, None, length=3)[0]

    _, c = _flops(nested, SDS)
    assert c.flops == pytest.approx(15 * DOT, rel=0.05)


def test_unrolled_matches_scanned():
    def unrolled(x):
        for _ in range(7):
            x = _one(x)
        return x

    def scanned(x):
        def body(c, _):
            return _one(c), None
        return jax.lax.scan(body, x, None, length=7)[0]

    _, cu = _flops(unrolled, SDS)
    _, cs = _flops(scanned, SDS)
    assert cu.flops == pytest.approx(cs.flops, rel=0.05)


def test_plain_dot_exact():
    _, c = _flops(lambda a, b: a @ b, SDS, SDS)
    assert c.flops == pytest.approx(DOT, rel=0.01)


def test_transcendentals_counted():
    _, c = _flops(lambda x: jnp.exp(x), SDS)
    assert c.transcendentals == pytest.approx(N * N, rel=0.01)


def test_collectives_in_loops_multiplied(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze_hlo_text
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
def g(w, x):
    def body(c, wl):
        return jnp.tanh(c @ wl), None
    return jax.lax.scan(body, x, w)[0].sum()
gj = jax.jit(g, in_shardings=(
    NamedSharding(mesh, P(None, "model", None)), NamedSharding(mesh, P("data", None))))
comp = gj.lower(jax.ShapeDtypeStruct((6, 512, 512), jnp.float32),
                jax.ShapeDtypeStruct((128, 512), jnp.float32)).compile()
c = analyze_hlo_text(comp.as_text())
# the per-iteration reduction must be multiplied by the 6 loop trips
per_iter = {k: v for k, v in c.collective_counts.items() if v}
total = sum(per_iter.values())
assert total >= 6, per_iter
print("counts", per_iter)
""", devices=8, x64=False)
    assert "counts" in out


def test_bytes_fusion_boundary_reasonable():
    """Traffic of a bare matmul ≈ operands + result (not 10×)."""
    _, c = _flops(lambda a, b: a @ b, SDS, SDS)
    expect = 3 * N * N * 4
    assert expect * 0.5 < c.bytes < expect * 4


def test_dus_in_place_counts_windows_not_buffers():
    """Scan-carried dynamic-update-slices alias in place: traffic must be
    the updated window × trips, not the full buffer × trips (this was a 190×
    overcount on scan-carried gradients before the fix)."""
    import jax
    import jax.numpy as jnp

    def f(buf):
        def body(c, i):
            b = jax.lax.dynamic_update_slice(
                c, jnp.ones((1, 512), jnp.float32), (i, 0))
            return b, None
        return jax.lax.scan(body, buf, jnp.arange(64))[0]

    c = analyze_hlo_text(
        jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 512), jnp.float32)).compile().as_text())
    buffer_traffic = 64 * 64 * 512 * 4 * 2
    assert c.bytes < buffer_traffic / 4
