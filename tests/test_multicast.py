"""Property tests for the paper's address-mask multicast encoding (§4.2)."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import multicast as mc


# --- the decoder condition vs a brute-force oracle --------------------------------


@given(
    addr=st.integers(0, (1 << mc.ADDR_BITS) - 1),
    mask=st.integers(0, (1 << mc.ADDR_BITS) - 1),
)
@settings(max_examples=300)
def test_decode_match_equals_bruteforce(addr, mask):
    """A request matches a cluster iff one of its encoded addresses lies in
    that cluster's address map — the paper's AND-reduction must agree with
    explicit enumeration (capped fanout keeps enumeration tractable)."""
    if bin(mask).count("1") > 12:
        mask &= (1 << 12) - 1          # cap fanout at 4096 addresses
    req = mc.MulticastRequest(addr=addr, mask=mask)
    maps = mc.occamy_cluster_maps()
    got = set(mc.matching_ports(req, maps))
    want = set()
    for a in req.addresses():
        for i, am in enumerate(maps):
            if am.contains(a):
                want.add(i)
    assert got == want


def test_paper_figure5_example():
    """Fig. 5: addr=cluster 1 of quadrant 2, mask bits 19 and 21 ->
    clusters 1 and 3 of quadrants 0 and 2."""
    addr = (2 << (mc.CLUSTER_OFFSET_BITS + mc.CLUSTER_IDX_BITS)) | (
        1 << mc.CLUSTER_OFFSET_BITS)
    mask = (1 << 19) | (1 << 21)
    req = mc.MulticastRequest(addr=addr, mask=mask)
    got = mc.decode_cluster_selection(req)
    want = sorted(q * 4 + c for q in (0, 2) for c in (1, 3))
    assert got == want
    assert req.fanout == 4


# --- selection encoding round trips ------------------------------------------------


@given(st.sets(st.integers(0, mc.NUM_CLUSTERS - 1), min_size=1, max_size=32))
@settings(max_examples=200)
def test_multi_request_cover_roundtrip(clusters):
    """Greedy subcube cover reaches exactly the requested clusters."""
    reqs = mc.encode_cluster_selection_multi(clusters)
    reached = set()
    for r in reqs:
        members = set(mc.decode_cluster_selection(r))
        assert not (members & reached), "cover must be disjoint"
        reached |= members
    assert reached == clusters


@given(
    base=st.integers(0, mc.NUM_CLUSTERS - 1),
    varying=st.integers(0, mc.NUM_CLUSTERS - 1),
)
@settings(max_examples=200)
def test_subcube_single_request(base, varying):
    """Any subcube encodes as exactly one request (the hardware's unit)."""
    members = sorted({(base & ~varying) | s for s in mc._submasks(varying)})
    req = mc.encode_cluster_selection(members)
    assert mc.decode_cluster_selection(req) == members


def test_non_subcube_rejected():
    with pytest.raises(ValueError):
        mc.encode_cluster_selection([0, 1, 2])     # size 3: not a power of two


def test_mask_encoding_counts():
    """Masking n bits encodes 2^n addresses (§4.2)."""
    for nbits in range(6):
        mask = (1 << nbits) - 1
        req = mc.MulticastRequest(addr=0, mask=mask << mc.CLUSTER_OFFSET_BITS)
        assert req.fanout == 1 << nbits
        assert len(list(req.addresses())) == 1 << nbits
