"""Training substrate: optimizer correctness, microbatching equivalence,
schedules, compression, checkpoint/elastic behaviour."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.dist.compression import (
    dequantize_int8, error_feedback_compress, init_residual, quantize_int8,
)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import linear_warmup_cosine


def test_adamw_against_naive_reference():
    """One AdamW step vs a hand-written scalar reference."""
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0, clip_norm=0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]])}
    state = adamw_init(p, cfg)
    newp, state, _ = adamw_update(g, state, p, jnp.float32(0.01), cfg)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat, vhat = m / 0.1, v / 0.001
    want = np.asarray(p["w"]) - 0.01 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


def test_adamw_weight_decay_matrices_only():
    cfg = AdamWConfig(weight_decay=0.1, clip_norm=0)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    state = adamw_init(p, cfg)
    newp, _, _ = adamw_update(g, state, p, jnp.float32(0.1), cfg)
    assert float(newp["w"][0, 0]) < 1.0      # decayed
    assert float(newp["b"][0]) == 1.0        # biases not decayed


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    state = adamw_init(p, cfg)
    _, _, metrics = adamw_update(g, state, p, jnp.float32(0.0), cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedule_shape():
    lrs = [float(linear_warmup_cosine(jnp.asarray(s), base_lr=1.0,
                                      warmup_steps=10, total_steps=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup rises
    assert lrs[10] == pytest.approx(max(lrs), rel=0.05)
    assert lrs[-1] < 0.2                   # cosine decays


def test_microbatch_equivalence():
    """grads(mb=1) == grads(mb=4) on the same global batch."""
    from repro import models as M
    from repro.train.step import grads_with_microbatching
    cfg = M.reduced(M.get("smollm-360m"))
    params = M.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (8, 32)).astype(np.int32),
             "labels": rng.integers(0, cfg.vocab_size, (8, 32)).astype(np.int32)}
    call = M.CallConfig()
    l1, g1 = grads_with_microbatching(cfg, call, 1)(params, batch)
    l4, g4 = grads_with_microbatching(cfg, call, 4)(params, batch)
    assert float(l1) == pytest.approx(float(l4), rel=1e-4)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=1.5e-3)  # bf16 accumulation-order noise


# --- compression -------------------------------------------------------------------


@given(st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(seed):
    x = jnp.asarray(np.random.default_rng(seed).standard_normal(512), jnp.float32)
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-7


def test_error_feedback_reduces_bias():
    """Σ of compressed grads with feedback tracks Σ of true grads (the
    residual carries what quantization dropped)."""
    rng = np.random.default_rng(0)
    grads = [{"w": jnp.asarray(rng.standard_normal(256) * 0.01, jnp.float32)}
             for _ in range(50)]
    residual = init_residual(grads[0])
    acc_c = np.zeros(256)
    acc_t = np.zeros(256)
    for g in grads:
        dq, residual = error_feedback_compress(g, residual)
        acc_c += np.asarray(dq["w"])
        acc_t += np.asarray(g["w"])
    # with feedback, accumulated error stays at one quantization step
    q, scale = quantize_int8(jnp.asarray(acc_t, jnp.float32))
    assert np.abs(acc_c - acc_t).max() < 5 * float(scale)


def test_compressed_psum_on_mesh(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.dist.compression import compressed_psum
mesh = Mesh(np.array(jax.devices()), ("data",))
def f(x):
    return compressed_psum(x, "data")
fs = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)
got = fs(x)
want = x.sum(axis=0, keepdims=True)
rel = np.abs(np.asarray(got[0:1]) - np.asarray(want)).max() / np.abs(np.asarray(want)).max()
assert rel < 0.05, rel
print("OK", rel)
""")


def test_dp_grads_compressed_close_to_exact(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.dist.compression import dp_grads_compressed
mesh = Mesh(np.array(jax.devices()), ("data",))
def loss(w, batch):
    x, y = batch["x"], batch["y"]
    pred = x @ w
    return jnp.mean((pred - y) ** 2)
rng = np.random.default_rng(0)
w = jnp.asarray(rng.standard_normal((16, 1)), jnp.float32)
batch = {"x": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32),
         "y": jnp.asarray(rng.standard_normal((32, 1)), jnp.float32)}
gfn = dp_grads_compressed(loss, axis="data")
gs = jax.jit(shard_map(gfn, mesh=mesh,
    in_specs=(P(), {"x": P("data"), "y": P("data")}),
    out_specs=(P(), P())))
loss_c, g_c = gs(w, batch)
loss_e, g_e = jax.value_and_grad(loss)(w, batch)
rel = np.abs(np.asarray(g_c) - np.asarray(g_e)).max() / (np.abs(np.asarray(g_e)).max() + 1e-9)
assert rel < 0.05, rel
assert abs(float(loss_c) - float(loss_e)) < 1e-5
print("OK", rel)
""")
