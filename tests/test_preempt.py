"""Overload-robust fabric (ISSUE-7): revocable leases, SLO admission,
graceful degradation, and the satellites riding along — backfill-aging
starvation bound, ``cancel()`` error paths, the completion-unit
cancel-vs-deferred-replay race, preemption contention in the simulator,
and chaos composition of fault plans."""

import dataclasses

import pytest

from repro.core import jobs, simulator
from repro.core.completion import CompletionUnit
from repro.core.fabric import (
    FabricScheduler,
    LeaseError,
    Overloaded,
    PendingLease,
    SchedulerPolicy,
    Tenant,
)
from repro.core.faults import FaultKind, FaultPlan, FaultSpec
from repro.core.policy import TenantKind
from repro.core.simulator import (
    PreemptionEvent,
    TenantWorkload,
    fabric_makespan_model,
    simulate_fabric,
)


# ---------------------------------------------------------------------------
# Tenant / SchedulerPolicy vocabulary
# ---------------------------------------------------------------------------


def test_tenant_slo_priority_validation():
    t = Tenant("t", weight=2.0, slo=1000.0, priority=1)
    assert t.slo == 1000.0 and t.priority == 1
    with pytest.raises(ValueError, match="slo"):
        Tenant("t", slo=0.0)
    with pytest.raises(ValueError, match="slo"):
        Tenant("t", slo=-5.0)


def test_scheduler_policy_overload_knobs_validated():
    pol = SchedulerPolicy(preemption="priority", max_queue_depth=2,
                          aging_grants=3)
    assert pol.preemption == "priority"
    with pytest.raises(ValueError, match="preemption"):
        SchedulerPolicy(preemption="sometimes")
    with pytest.raises(ValueError, match="max_queue_depth"):
        SchedulerPolicy(max_queue_depth=-1)
    with pytest.raises(ValueError, match="aging_grants"):
        SchedulerPolicy(aging_grants=0)


# ---------------------------------------------------------------------------
# Satellite: backfill starvation — aging + head reservation
# ---------------------------------------------------------------------------


def test_backfill_aging_bounds_starvation():
    """A blocked big request is bypassed at most ``aging_grants`` times;
    after that it reserves the fabric and freed capacity accrues to it."""
    sched = FabricScheduler(num_clusters=8,
                            policy=SchedulerPolicy(aging_grants=2))
    holds = [sched.request("hold0", clusters=[0, 1, 2, 3]),
             sched.request("hold1", clusters=[4, 5, 6, 7])]
    big = sched.request(Tenant("big"), n=8, queue=True)
    smalls = [sched.request(Tenant(f"s{k}"), n=4, queue=True)
              for k in range(3)]

    sched.release(holds[0])          # small0 backfills past blocked big
    assert smalls[0].ready and not big.ready and big.skipped == 1
    sched.release(smalls[0].lease)   # small1 backfills: second bypass
    assert smalls[1].ready and big.skipped == 2
    sched.release(smalls[1].lease)   # aged out: the head reserves now
    assert not smalls[2].ready, (
        "backfill past an aged head must stop (head reservation)")
    assert not big.ready
    sched.release(holds[1])          # full fabric free -> the big grant
    assert big.ready and big.lease.n == 8
    assert not smalls[2].ready       # still behind the big lease
    sched.release(big.lease)
    assert smalls[2].ready


def test_direct_grants_prefer_queue_order_after_release():
    """Weighted ranking: a heavier queued tenant grants first even when
    queued later (weight beats FIFO inside a priority class)."""
    sched = FabricScheduler(num_clusters=4)
    hold = sched.request("hold", n=4)
    light = sched.request(Tenant("light", weight=1.0), n=4, queue=True)
    heavy = sched.request(Tenant("heavy", weight=8.0), n=4, queue=True)
    sched.release(hold)
    assert heavy.ready and not light.ready


# ---------------------------------------------------------------------------
# Satellite: cancel() — withdraw a queued request
# ---------------------------------------------------------------------------


def test_cancel_removes_queued_request_and_unblocks():
    sched = FabricScheduler(num_clusters=4,
                            policy=SchedulerPolicy(aging_grants=1))
    hold = sched.request("hold", n=4)
    big = sched.request(Tenant("big"), n=4, queue=True)
    small = sched.request(Tenant("small"), n=2, queue=True)
    sched.cancel(big)
    assert big.cancelled and big not in sched.pending
    sched.release(hold)
    assert small.ready        # the cancelled head no longer reserves


def test_cancel_error_paths():
    sched = FabricScheduler(num_clusters=4)
    hold = sched.request("hold", n=4)
    pend = sched.request(Tenant("t"), n=2, queue=True)
    # cancelling twice: second is a LeaseError
    sched.cancel(pend)
    with pytest.raises(LeaseError, match="not queued"):
        sched.cancel(pend)
    # a granted pending must be released, not cancelled
    pend2 = sched.request(Tenant("t2"), n=2, queue=True)
    sched.release(hold)
    assert pend2.ready
    with pytest.raises(LeaseError, match="already granted"):
        sched.cancel(pend2)
    # a foreign PendingLease was never queued here
    foreign = PendingLease("x", 2, None, None, 1)
    with pytest.raises(LeaseError, match="not queued"):
        sched.cancel(foreign)


# ---------------------------------------------------------------------------
# SLO admission: typed Overloaded backpressure
# ---------------------------------------------------------------------------


def test_queue_depth_sheds_with_typed_overloaded():
    sched = FabricScheduler(num_clusters=4,
                            policy=SchedulerPolicy(max_queue_depth=1))
    sched.request("hold", n=4, job=jobs.make_axpy(1024))
    sched.request(Tenant("q0"), n=4, queue=True)
    with pytest.raises(Overloaded) as exc:
        sched.request(Tenant("q1"), n=4, queue=True)
    assert exc.value.retry_after_cycles > 0.0
    assert sched.health().overloaded == 1


def test_slo_violation_sheds_instead_of_queueing():
    sched = FabricScheduler(num_clusters=4)
    sched.request("hold", n=4, job=jobs.make_axpy(1024))
    job = jobs.make_covariance(32, 64)
    tight = Tenant("tight", slo=1.0)
    with pytest.raises(Overloaded) as exc:
        sched.request(tight, n=4, job=job, queue=True)
    assert exc.value.retry_after_cycles > 0.0
    # a generous SLO queues fine
    ok = sched.request(Tenant("ok", slo=1e12), n=4, job=job, queue=True)
    assert isinstance(ok, PendingLease)
    assert sched.health().overloaded == 1


def test_session_slo_gate_rejects_predictably_slow_submit(subproc):
    subproc("""
import jax
from repro.api import FabricScheduler, Overloaded, Session, Tenant
from repro.core import jobs

job = jobs.make_covariance(32, 64)
sched = FabricScheduler(jax.devices())
lease = sched.request(Tenant("tight", slo=10.0), clusters=[0, 1])
sess = Session(lease=lease)
ops, _ = job.make_instance(0)
try:
    sess.submit(job, dict(ops), n=2)
    raise SystemExit("expected Overloaded")
except Overloaded as e:
    assert e.retry_after_cycles >= 0.0
sess.close()

sched = FabricScheduler(jax.devices())
lease = sched.request(Tenant("ok", slo=1e12), clusters=[0, 1])
sess = Session(lease=lease)
out = sess.submit(job, dict(ops), n=2).wait()
assert out is not None
sess.close()
print("OK")
""", devices=4)


# ---------------------------------------------------------------------------
# Tentpole: preempt / revoke lifecycle (model-only)
# ---------------------------------------------------------------------------


def test_preempt_queues_and_regrants_same_lease_id():
    sched = FabricScheduler(num_clusters=8)
    victim = sched.request(Tenant("victim"), clusters=[0, 1, 2, 3],
                           job=jobs.make_axpy(1024))
    blocker = sched.request("blocker", clusters=[4, 5, 6, 7])
    taker = sched.request(Tenant("taker", weight=8.0), n=4, queue=True)
    deadline = sched.drain_deadline(victim)
    assert deadline > 0.0
    pend = sched.preempt(victim)
    assert sched.health().preemptions == 1
    assert taker.ready, "the freed window goes to the queued tenant"
    assert not pend.ready and pend.resume_id == victim.lease_id
    assert sched.current_lease(victim) is None
    sched.release(blocker)
    assert pend.ready
    assert pend.lease.lease_id == victim.lease_id
    assert pend.lease.clusters == (4, 5, 6, 7)


def test_preempt_drain_deadline_is_model_driven():
    """deadline = deadline_factor x predict_makespan(job, window, batch)."""
    from repro.core.faults import deadline_cycles
    from repro.core.policy import RetryPolicy

    job = jobs.make_covariance(32, 64)
    sched = FabricScheduler(num_clusters=8)
    lease = sched.request(Tenant("t"), n=4, job=job, batch=3)
    expect = deadline_cycles(
        sched.predict_makespan(job, lease.clusters, 3), RetryPolicy())
    assert sched.drain_deadline(lease) == pytest.approx(expect)


def test_revoke_ends_lease_permanently():
    sched = FabricScheduler(num_clusters=4)
    lease = sched.request(Tenant("t"), n=2)
    sched.revoke(lease)
    assert sched.current_lease(lease) is None
    assert sched.pending == ()            # no re-queue
    assert sched.health().preemptions == 1
    with pytest.raises(LeaseError, match="not active"):
        sched.preempt(lease)


# ---------------------------------------------------------------------------
# Tentpole: compaction and the degradation ladder (model-only)
# ---------------------------------------------------------------------------


def test_compact_coalesces_free_capacity():
    sched = FabricScheduler(num_clusters=8)
    a = sched.request("a", clusters=[0, 1])
    b = sched.request("b", clusters=[4, 5])
    with pytest.raises(LeaseError):
        sched.request("big", n=4)
    moves = sched.compact()
    assert moves == 1 and sched.health().migrations == 1
    assert sched.current_lease(b).clusters == (2, 3)
    assert sched.current_lease(a).clusters == (0, 1)
    big = sched.request("big", n=4)
    assert big.clusters == (4, 5, 6, 7)


def test_pressure_ladder_shrinks_elastic_floor_before_revoking():
    sched = FabricScheduler(
        num_clusters=8, policy=SchedulerPolicy(preemption="priority"))
    serve = sched.request(Tenant("serve", kind=TenantKind.SERVE), n=4)
    sched.register_elastic(serve, floor=2)
    other = sched.request(Tenant("other"), clusters=[4, 5, 6, 7])
    # no free window; the ladder shrinks serve to its floor, not revoke
    lease = sched.request(Tenant("t", priority=1), n=2)
    assert lease.n == 2
    assert sched.current_lease(serve).n == 2
    assert sched.health().preemptions == 0
    assert sched.health().floor_shrinks == 0
    assert sched.current_lease(other) is not None
    assert sched.elastic_floor(sched.current_lease(serve)) == 2


def test_pressure_ladder_halves_floors_then_preempts():
    sched = FabricScheduler(
        num_clusters=8, policy=SchedulerPolicy(preemption="priority"))
    serve = sched.request(Tenant("serve", kind=TenantKind.SERVE), n=4)
    sched.register_elastic(serve, floor=4)       # already at its floor
    low = sched.request(Tenant("low", priority=0), clusters=[4, 5, 6, 7])
    # rung 2b halves the floor (4 -> 2), freeing a 2-window
    l1 = sched.request(Tenant("hi", priority=1), n=2)
    assert l1.n == 2 and sched.health().floor_shrinks == 1
    assert sched.elastic_floor(sched.current_lease(serve)) == 2
    # nothing left to shrink for a 4-window: the low-priority lease is
    # revoked (elastic serve leases are never victims)
    l2 = sched.request(Tenant("hi", priority=1), n=4)
    assert l2.clusters == (4, 5, 6, 7)
    assert sched.health().preemptions == 1
    assert sched.current_lease(low) is None
    assert any(p.resume_id is not None for p in sched.pending)
    assert sched.current_lease(serve) is not None


def test_degraded_grant_takes_model_equal_smaller_window():
    """A request whose job is as fast on half the clusters degrades to
    the smaller pow2 window instead of revoking anything."""
    job = jobs.make_covariance(32, 64)       # 8-wide beats 16-wide
    sched = FabricScheduler(
        num_clusters=32, policy=SchedulerPolicy(preemption="priority"))
    low = sched.request(Tenant("low", priority=0), n=16,
                        job=jobs.make_axpy(1024))
    sched.request(Tenant("pad", priority=0), n=8)
    lease = sched.request(Tenant("hi", priority=1), n=16, job=job, batch=4)
    assert lease.n < 16
    assert sched.health().degraded_grants == 1
    assert sched.health().preemptions == 0
    assert sched.current_lease(low) is not None


def test_preempted_victims_cannot_starve_forever():
    """A revoked lease's re-queue entry competes with weighted aging like
    any other pending request and eventually re-places."""
    sched = FabricScheduler(
        num_clusters=8, policy=SchedulerPolicy(preemption="priority"))
    victim = sched.request(Tenant("victim", priority=0), n=8,
                           job=jobs.make_axpy(1024))
    hi = sched.request(Tenant("hi", priority=1), n=8,
                       job=jobs.make_axpy(1024))
    assert sched.health().preemptions == 1
    pend = next(p for p in sched.pending
                if p.resume_id == victim.lease_id)
    sched.release(hi)
    assert pend.ready and pend.lease.lease_id == victim.lease_id


# ---------------------------------------------------------------------------
# Satellite: CompletionUnit.cancel racing the deferred-IRQ replay
# ---------------------------------------------------------------------------


def test_cancel_purges_pending_irq_of_completed_job():
    cu = CompletionUnit(n_units=2)
    cu.program(1, job_id=0)
    cu.arrive(job_id=0)                  # fires: pending cause 0
    assert cu.pending_cause() == 0
    cu.cancel(0)                         # raced: completion already fired
    assert cu.pending_cause() is None, (
        "a cancelled job's fired IPI must not stay pending")
    # the unit is reusable for the next job sharing it (2 % 2 == 0)
    cu.program(1, job_id=2)
    cu.arrive(job_id=2)
    cu.collect(2)                        # must see 2, never the stale 0


def test_cancel_purges_deferred_replay_of_completed_job():
    """Fig. 6 replay race: B completes while A's IPI is pending, so B's
    cause sits in the deferred list; cancelling B must purge it, or the
    replay fires a stale interrupt for a later job on B's unit."""
    cu = CompletionUnit(n_units=2)
    cu.program(1, job_id=0)
    cu.program(1, job_id=1)
    cu.arrive(job_id=0)                  # A pending
    cu.arrive(job_id=1)                  # B fired -> deferred behind A
    cu.cancel(1)                         # abandon B after its completion
    assert cu.clear() == 0               # A's IPI
    assert cu.pending_cause() is None, (
        "cancelled B's deferred completion replayed as a stale IPI")
    # job 3 shares B's unit; its completion must be the only cause seen
    cu.program(1, job_id=3)
    cu.arrive(job_id=3)
    cu.collect(3)
    assert cu.pending_cause() is None


def test_cancel_purges_collected_cause():
    cu = CompletionUnit(n_units=1)
    cu.program(1, job_id=0)
    cu.arrive(job_id=0)
    cu.program(1, job_id=1)
    cu.arrive(job_id=1)
    cu.collect(1)                        # parks cause 0 in _collected
    cu.cancel(0)
    cu.program(1, job_id=0)
    cu.arrive(job_id=0)
    cu.collect(0)                        # fresh completion, not the stale park
    assert cu.pending_cause() is None


# ---------------------------------------------------------------------------
# Simulator: preemption contention events
# ---------------------------------------------------------------------------


def test_preemption_event_validation():
    with pytest.raises(ValueError, match="after_jobs"):
        PreemptionEvent("t", after_jobs=0, new_clusters=(0,))
    with pytest.raises(ValueError, match="cluster"):
        PreemptionEvent("t", after_jobs=1, new_clusters=())
    with pytest.raises(ValueError, match="restage_cycles"):
        PreemptionEvent("t", after_jobs=1, new_clusters=(0,),
                        restage_cycles=-1.0)


def test_simulated_preemption_drains_and_delays():
    """A preemption boundary strictly delays the tenant's completion
    (drain + restage + re-placement) and the closed form tracks the
    event model within the paper bar."""
    spec = jobs.make_covariance(32, 64).spec
    w = TenantWorkload("t", spec, tuple(range(8)), jobs=8)
    base = simulate_fabric([w])
    ev = PreemptionEvent("t", after_jobs=4, new_clusters=tuple(range(8, 12)),
                         restage_cycles=5_000.0)
    out = simulate_fabric([w], preemptions=[ev])
    assert out.makespan > base.makespan
    assert len(out.job_completions["t"]) == 8
    pred = fabric_makespan_model([w], preemptions=[ev])
    assert simulator.model_error(pred, out.makespan) < 0.15
    # completions stay monotonic across the boundary
    cs = out.job_completions["t"]
    assert all(a < b for a, b in zip(cs, cs[1:]))


def test_preemption_event_ignored_outside_job_range():
    spec = jobs.make_axpy(1024).spec
    w = TenantWorkload("t", spec, tuple(range(4)), jobs=3)
    ev = PreemptionEvent("t", after_jobs=3, new_clusters=(4, 5))
    assert (simulate_fabric([w], preemptions=[ev]).makespan
            == simulate_fabric([w]).makespan)


# ---------------------------------------------------------------------------
# Satellite: chaos composition of fault plans
# ---------------------------------------------------------------------------


def test_fault_plan_compose_merges_and_orders():
    a = FaultPlan([FaultSpec(FaultKind.STRAGGLE, at_dispatch=3, factor=2.0)])
    b = FaultPlan([FaultSpec(FaultKind.LOST_ARRIVAL, at_dispatch=0, count=1),
                   FaultSpec(FaultKind.CLUSTER_DEATH, at_dispatch=5,
                             clusters=(1,))])
    merged = a.compose(b)
    assert [f.at_dispatch for f in merged] == [0, 3, 5]
    assert len(a) == 1 and len(b) == 2          # inputs untouched
    via_add = a + b
    assert [f.at_dispatch for f in via_add] == [0, 3, 5]
    with pytest.raises(TypeError):
        a + 42          # not a FaultPlan


def test_fault_plan_compose_deterministic_with_random():
    a = FaultPlan.random(11, n_faults=2)
    b = FaultPlan.random(22, n_faults=2)
    assert ([f.at_dispatch for f in a.compose(b)]
            == [f.at_dispatch for f in a.compose(b)])
    assert dataclasses.astuple(a.compose(b).faults[0]) is not None
