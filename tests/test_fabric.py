"""Fabric scheduler (ISSUE-5): leases, placement, contention, isolation.

Model-level behavior (admission, placement, queueing, resize, the
multi-tenant contention model) runs in-process — the scheduler needs no
devices.  Dispatch-level isolation (the acceptance criterion: concurrent
sessions on disjoint leases are bit-equal to sequential full-mesh runs)
runs in an 8-device subprocess like the other dispatch tests.
"""

import numpy as np
import pytest

from repro.core import jobs
from repro.core import multicast as mc
from repro.core.fabric import (
    ClusterLease, FabricScheduler, LeaseError, LeaseUnavailable,
    SchedulerPolicy, Tenant,
)
from repro.core.params import OccamyParams
from repro.core.policy import (
    AUTO, OffloadPolicy, Residency, Staging, TenantKind,
)
from repro.core.session import Session, estimate
from repro.core.simulator import (
    TenantWorkload, fabric_makespan_model, model_error, simulate_fabric,
)

TWO_QUADRANTS = OccamyParams(num_quadrants=2)   # an 8-cluster small grid


# ---------------------------------------------------------------------------
# Lease windows: multicast legality + tree legality
# ---------------------------------------------------------------------------


def test_window_encoding_aligned_is_single_request():
    for start, n in ((0, 8), (8, 8), (4, 4), (16, 16), (3, 1)):
        reqs = mc.encode_contiguous_window(start, n)
        assert len(reqs) == 1
        assert sorted(mc.decode_cluster_selection(reqs[0])) == list(
            range(start, start + n))


def test_window_encoding_covers_any_window_exactly():
    for start, n in ((3, 5), (1, 7), (5, 11), (0, 32), (31, 1)):
        reqs = mc.encode_contiguous_window(start, n)
        got = sorted(c for r in reqs
                     for c in mc.decode_cluster_selection(r))
        assert got == list(range(start, start + n)), (start, n, reqs)


def test_window_encoding_bounds():
    with pytest.raises(ValueError):
        mc.encode_contiguous_window(0, 0)
    with pytest.raises(ValueError):
        mc.encode_contiguous_window(30, 4)      # spills past cluster 31


def test_lease_requests_and_tree_reach_the_window():
    sched = FabricScheduler(num_clusters=32)
    lease = sched.request("t", n=8)
    assert len(lease.requests()) == 1           # aligned pow2 => one mask
    tree = lease.tree()
    assert tree.reached() == lease.clusters
    assert tree.n_edges == lease.n - 1


def test_lease_validation_and_noncontiguous_cover():
    with pytest.raises(ValueError):
        ClusterLease(1, "t", ())
    with pytest.raises(ValueError):
        ClusterLease(1, "t", (3, 1))            # unsorted
    with pytest.raises(ValueError):
        ClusterLease(1, "t", (-1, 0))
    # a synthesized lease over a non-contiguous runtime window still
    # covers exactly its clusters (multiple subcube requests)
    lease = ClusterLease(1, "t", (0, 2, 4, 6))
    got = sorted(c for r in lease.requests()
                 for c in mc.decode_cluster_selection(r))
    assert got == [0, 2, 4, 6]


# ---------------------------------------------------------------------------
# Admission: rejection, queueing, model-driven placement and sizing
# ---------------------------------------------------------------------------


def test_lease_larger_than_fabric_rejected():
    sched = FabricScheduler(num_clusters=32)
    with pytest.raises(ValueError, match="exceeds the 32-cluster fabric"):
        sched.request("t", n=64)
    with pytest.raises(ValueError):
        sched.request("t", n=0)
    with pytest.raises(ValueError):
        sched.request("t", clusters=[30, 31, 32])


def test_overlapping_lease_rejected_and_disjoint_grants():
    sched = FabricScheduler(num_clusters=32)
    a = sched.request("A", n=8)
    b = sched.request("B", n=8)
    assert set(a.clusters).isdisjoint(b.clusters)
    with pytest.raises(LeaseUnavailable, match="already leased"):
        sched.request("C", clusters=list(a.clusters))
    with pytest.raises(ValueError, match="contiguous"):
        sched.request("C", clusters=[16, 18])
    # full fabric: no window of 32 left
    with pytest.raises(LeaseUnavailable):
        sched.request("C", n=32)


def test_queueing_grants_fifo_on_release():
    sched = FabricScheduler(num_clusters=8)
    a = sched.request("A", n=8)
    p1 = sched.request("B", n=4, queue=True)
    p2 = sched.request("C", n=2, queue=True)
    assert not p1.ready and not p2.ready and len(sched.pending) == 2
    sched.release(a)
    assert p1.ready and p2.ready
    assert set(p1.lease.clusters).isdisjoint(p2.lease.clusters)
    assert not sched.pending


def test_model_placement_prefers_quadrant_local_windows():
    # clusters 0 and 1 busy: first_fit straddles quadrants ([2..5]),
    # the model-scored placement stays inside quadrant 1 ([4..7])
    for placement, expected in (("model", (4, 5, 6, 7)),
                                ("first_fit", (2, 3, 4, 5))):
        sched = FabricScheduler(
            num_clusters=8, params=TWO_QUADRANTS,
            policy=SchedulerPolicy(placement=placement, align=False))
        sched.request("busy", clusters=[0, 1])
        lease = sched.request("t", n=4)
        assert lease.clusters == expected, placement
    model = FabricScheduler(num_clusters=8, params=TWO_QUADRANTS)
    model.request("busy", clusters=[0, 1])
    chosen = model.request("t", n=4)
    assert chosen.tree(TWO_QUADRANTS.clusters_per_quadrant
                       ).cross_quadrant_edges(
        TWO_QUADRANTS.clusters_per_quadrant) == 0


def test_model_driven_slice_sizing():
    # a fine-grained job gets a small slice (overheads grow with n and
    # the share-slack prefers leaving fabric to co-tenants); a
    # compute-heavy job gets a bigger one
    sched = FabricScheduler(num_clusters=32)
    small = sched.request("a", job=jobs.make_axpy(1024), batch=16)
    big = sched.request("b", job=jobs.make_matmul(64, 64, 64), batch=16)
    assert small.n < big.n
    assert small.n >= 1 and big.n <= 32
    with pytest.raises(ValueError, match="one of n / clusters / job"):
        sched.request("c")


def test_tenant_registry_and_kinds():
    sched = FabricScheduler(num_clusters=8)
    lease = sched.request(Tenant("serve", kind=TenantKind.SERVE), n=2)
    assert sched.tenant("serve").kind is TenantKind.SERVE
    assert lease.tenant == "serve"
    with pytest.raises(ValueError):
        Tenant("")
    with pytest.raises(ValueError):
        SchedulerPolicy(placement="best_fit")


# ---------------------------------------------------------------------------
# Resize: the serve tenant's elastic grow/shrink
# ---------------------------------------------------------------------------


def test_resize_keeps_start_and_grants_pending():
    sched = FabricScheduler(num_clusters=8)
    lease = sched.request("serve", n=2)
    grown = sched.resize(lease, 6)
    assert grown.clusters[0] == lease.clusters[0]       # extended in place
    assert grown.n == 6
    pend = sched.request("offload", n=4, queue=True)
    assert not pend.ready
    shrunk = sched.resize(grown, 2)
    assert shrunk.clusters == lease.clusters
    assert pend.ready and pend.lease.n == 4             # freed head-room
    # stale lease objects are rejected after a resize
    with pytest.raises(LeaseError, match="stale|current"):
        sched.release(grown)
    with pytest.raises(LeaseUnavailable):
        sched.resize(shrunk, 8)                         # offload holds 4
    sched.release(shrunk)
    sched.release(pend.lease)
    assert sched.free_clusters() == tuple(range(8))


def test_resize_relocation_grants_pending():
    # relocation frees the old window; queued requests for it must not
    # starve while the clusters sit free
    sched = FabricScheduler(num_clusters=8)
    a = sched.request("A", clusters=[0, 1])
    sched.request("B", clusters=[2, 3])
    pend = sched.request("C", clusters=[0, 1], queue=True)
    grown = sched.resize(a, 4)              # cannot extend: relocates
    assert grown.clusters == (4, 5, 6, 7)
    assert pend.ready and pend.lease.clusters == (0, 1)


def test_resize_bounds():
    sched = FabricScheduler(num_clusters=8)
    lease = sched.request("t", n=2)
    with pytest.raises(ValueError):
        sched.resize(lease, 0)
    with pytest.raises(ValueError):
        sched.resize(lease, 9)
    assert sched.resize(lease, 2) is lease              # no-op


# ---------------------------------------------------------------------------
# Multi-tenant contention model + its closed form
# ---------------------------------------------------------------------------


def _mixed_workloads():
    return [
        TenantWorkload("serve", jobs.make_matmul(16, 16, 16).spec,
                       tuple(range(0, 8)), jobs=16),
        TenantWorkload("axpy", jobs.make_axpy(1024).spec,
                       tuple(range(8, 16)), jobs=16),
        TenantWorkload("cov", jobs.make_covariance(32, 64).spec,
                       tuple(range(16, 24)), jobs=16),
        TenantWorkload("atax", jobs.make_atax(64, 64).spec,
                       tuple(range(24, 32)), jobs=16),
    ]


def test_disjoint_leases_beat_serialized_whole_mesh():
    ws = _mixed_workloads()
    sched = simulate_fabric(ws)
    full = tuple(range(32))
    serial = simulate_fabric(
        [TenantWorkload(w.tenant, w.spec, full, jobs=w.jobs, window=1)
         for w in ws])
    assert sched.makespan < serial.makespan
    assert sched.utilization(32) / serial.utilization(32) >= 1.5
    assert sched.work == serial.work                    # same useful work


def test_fabric_makespan_model_within_paper_bar():
    for ws in (_mixed_workloads(),
               [TenantWorkload("solo", jobs.make_axpy(4096).spec,
                               tuple(range(8)), jobs=8)],
               [TenantWorkload(w.tenant, w.spec, tuple(range(32)),
                               jobs=w.jobs, window=1)
                for w in _mixed_workloads()]):
        measured = simulate_fabric(ws).makespan
        predicted = fabric_makespan_model(ws)
        assert model_error(predicted, measured) < 0.15, ws[0].tenant


def test_makespan_is_arrival_relative():
    spec = jobs.make_axpy(1024).spec
    base = simulate_fabric(
        [TenantWorkload("a", spec, (0, 1, 2, 3), jobs=4)])
    late = simulate_fabric(
        [TenantWorkload("a", spec, (0, 1, 2, 3), jobs=4,
                        arrival=100000.0)])
    assert late.makespan == pytest.approx(base.makespan)
    assert fabric_makespan_model(
        [TenantWorkload("a", spec, (0, 1, 2, 3), jobs=4,
                        arrival=100000.0)]) == pytest.approx(
        fabric_makespan_model(
            [TenantWorkload("a", spec, (0, 1, 2, 3), jobs=4)]))


def test_shared_lease_serializes_device_phases():
    spec = jobs.make_axpy(1024).spec
    shared = simulate_fabric(
        [TenantWorkload("a", spec, (0, 1, 2, 3), jobs=4),
         TenantWorkload("b", spec, (0, 1, 2, 3), jobs=4)])
    disjoint = simulate_fabric(
        [TenantWorkload("a", spec, (0, 1, 2, 3), jobs=4),
         TenantWorkload("b", spec, (4, 5, 6, 7), jobs=4)])
    assert disjoint.makespan < shared.makespan


# ---------------------------------------------------------------------------
# Policy combinations + the fused explain fix (satellites)
# ---------------------------------------------------------------------------


def test_invalid_policy_combinations():
    with pytest.raises(ValueError, match="RESIDENT stages no operands"):
        OffloadPolicy(residency=Residency.RESIDENT, staging=Staging.TREE)
    with pytest.raises(ValueError, match="RESIDENT stages no operands"):
        OffloadPolicy(residency=Residency.RESIDENT,
                      staging=Staging.HOST_FANOUT, fuse=2)
    # DIRECT (a no-op for resident) and unset stay legal
    OffloadPolicy(residency=Residency.RESIDENT, staging=Staging.DIRECT)
    AUTO.pinned(residency=Residency.RESIDENT)


def test_resident_submit_drops_pinned_staging():
    """A tree-staging policy is reusable for the resident redispatch it
    primed: submit pins residency and drops the staging pin instead of
    synthesizing the forbidden RESIDENT+TREE combination."""
    sess = Session(devices=["cpu0", "cpu1"])
    job = jobs.make_axpy(64)
    pol = OffloadPolicy(staging=Staging.TREE, window=1)
    # nothing staged yet, so the dispatch itself fails with "no plan" —
    # but only AFTER the policy passed validation (the old bug raised
    # ValueError from inside pinned() before reaching the plan lookup)
    with pytest.raises(KeyError, match="no dispatch plan"):
        sess.submit(job, Residency.RESIDENT, policy=pol, n=1)


def test_estimate_reports_per_instance_and_per_launch_terms():
    job = jobs.make_axpy(1024)
    est = estimate(job, n=8, batch=8, policy=OffloadPolicy(fuse=4))
    from repro.core.phases import Phase
    from repro.core.session import CONST_PHASES
    per_launch = est.per_launch_phases
    per_inst = est.per_instance_phases
    for ph, v in est.phases.items():
        if ph in CONST_PHASES:
            assert per_launch[ph] == v
            assert per_inst[ph] == pytest.approx(v / 4)
        else:
            assert per_launch[ph] == pytest.approx(v * 4)
            assert per_inst[ph] == v
    text = est.table()
    assert "per-instance" in text and "per-launch (B=4)" in text
    # an unfused estimate keeps the single-column table
    unfused = estimate(job, n=8, policy=OffloadPolicy(fuse=1, window=1))
    assert "per-launch" not in unfused.table()
    assert f"phase {Phase.E.name}" in unfused.table()


# ---------------------------------------------------------------------------
# Session error paths (satellite): closed sessions, resident misuse
# ---------------------------------------------------------------------------


def test_submit_after_close_raises():
    sess = Session(devices=["cpu0"])      # duck devices: no dispatch happens
    sess.close()
    assert sess.closed
    job = jobs.make_axpy(64)
    with pytest.raises(RuntimeError, match="closed session"):
        sess.submit(job, {"x": np.zeros(64), "y": np.zeros(64)})
    with pytest.raises(RuntimeError, match="closed session"):
        sess.estimate(job)
    with pytest.raises(RuntimeError, match="closed session"):
        sess.stage(job, {"x": np.zeros(64), "y": np.zeros(64)})
    sess.close()                          # idempotent


def test_close_after_external_release_is_quiet():
    sched = FabricScheduler(devices=["d0", "d1", "d2", "d3"])
    lease = sched.request("t", n=2)
    sess = Session(lease=lease)
    sched.release(lease)                  # e.g. an external reclaim
    sess.close()                          # cleanup, not a second release
    assert sess.closed and not lease.active


def test_serve_tenant_grow_survives_fragmented_fabric():
    # free count 6 but the largest contiguous window is 4: the burst
    # must land on the widest window that fits, not raise
    from repro.serve.engine import ServeTenant
    sched = FabricScheduler(num_clusters=8)
    tenant = ServeTenant(sched, cfg=None, host_params=None, scfg=None,
                         floor=1, burst=8)
    assert tenant.lease.clusters == (0,)
    sched.request("offload", clusters=[3])
    tenant._grow()
    assert tenant.lease.n == 4            # the largest free window
    tenant._shrink()
    assert tenant.lease.n == 1
    tenant.close()


def test_session_lease_conflicts_rejected():
    sched = FabricScheduler(num_clusters=4)
    lease = sched.request("t", n=2)
    with pytest.raises(ValueError, match="lease or devices"):
        Session(devices=["cpu0"], lease=lease)
    with pytest.raises(LeaseError, match="model-only"):
        Session(lease=lease)              # scheduler has no devices
    # a plain session synthesizes its whole window as a one-tenant lease
    sess = Session(devices=["cpu0", "cpu1"])
    assert isinstance(sess.lease, ClusterLease)
    assert sess.lease.clusters == (0, 1)
    assert sess.lease.tenant == "default"


# ---------------------------------------------------------------------------
# Acceptance: concurrent sessions on disjoint leases are bit-equal to
# sequential full-mesh runs on the same selections (8-device subprocess)
# ---------------------------------------------------------------------------


def test_disjoint_lease_sessions_bit_equal_to_sequential(subproc):
    subproc("""
import numpy as np
import jax
from repro.api import FabricScheduler, Residency, Session
from repro.core import jobs

sched = FabricScheduler(jax.devices())
A = sched.request("tenantA", clusters=[0, 1, 2, 3])
B = sched.request("tenantB", clusters=[4, 5, 6, 7])
sa = Session(lease=A)
sb = Session(lease=B)

axpy = jobs.make_axpy(1024)
atax = jobs.make_atax(32, 32)      # psum reduction: order-sensitive
ia, ea = jobs.make_instances(axpy, 4, seed0=0)
it, et = jobs.make_instances(atax, 4, seed0=10)

# concurrent: interleaved submits, both leases in flight at once
handles = []
for k in range(4):
    handles.append(("A", sa.submit(axpy, ia[k])))
    handles.append(("B", sb.submit(atax, it[k])))
conc = {"A": [], "B": []}
for who, h in handles:
    conc[who].append(h.wait())

# plans are keyed by the lease's *global* ids
assert any(k[1] == (4, 5, 6, 7) for k in sb.runtime()._plans)
assert any(k[1] == (0, 1, 2, 3) for k in sa.runtime()._plans)

# sequential: one whole-mesh session, same selections, one job at a time
sa.close(); sb.close()
assert not A.active and not B.active
full = Session()
seq = {"A": [], "B": []}
for k in range(4):
    seq["A"].append(full.submit(axpy, ia[k], clusters=[0, 1, 2, 3],
                                ).wait())
    seq["B"].append(full.submit(atax, it[k], clusters=[4, 5, 6, 7],
                                ).wait())

for who, exps in (("A", ea), ("B", et)):
    for got_c, got_s, exp in zip(conc[who], seq[who], exps):
        assert np.array_equal(np.asarray(got_c), np.asarray(got_s)), who
        assert np.allclose(got_s, exp)
print("OK")
""")


def test_lease_session_quadrant_aware_tree_staging(subproc):
    """A lease away from cluster 0 derives its staging tree from its real
    fabric position: one h2d upload, n-1 d2d edges, global-root device."""
    subproc("""
import numpy as np
import jax
from repro.api import FabricScheduler, OffloadPolicy, Session, Staging
from repro.core import jobs

sched = FabricScheduler(jax.devices())
sched.request("pad", clusters=[0, 1, 2, 3])
lease = sched.request("t", clusters=[4, 5, 6, 7])
sess = Session(lease=lease)
job = jobs.make_covariance(16, 32)
ops, exp = job.make_instance(0)
h = sess.submit(job, ops, policy=OffloadPolicy(staging=Staging.TREE,
                                               fuse=1, window=1))
assert np.allclose(h.wait(), exp)
plan = next(iter(sess.runtime()._plans.values()))
assert plan.cluster_ids == (4, 5, 6, 7)
assert plan._stager.tree.root == 4
assert plan.stats.tree_stages >= 1
assert plan.stats.h2d_bytes < 4 * ops["data"].nbytes   # O(1), not O(n)
sess.close()
print("OK")
""")


def test_serve_tenant_elastic_lease(subproc):
    """The serve tenant grows to the free fabric for a burst, shrinks to
    its floor between bursts, and repeated bursts reuse the warm engine."""
    subproc("""
import jax, numpy as np
from repro import models as M
from repro.api import FabricScheduler
from repro.serve import ServeConfig, ServeTenant

cfg = M.reduced(M.get("smollm-360m"))
sched = FabricScheduler(jax.devices())
params = jax.device_get(M.init_params(jax.random.key(0), cfg))
tenant = ServeTenant(sched, cfg, params, ServeConfig(batch=4, max_len=24),
                     floor=1, burst=4)
assert tenant.lease.n == 1 and len(sched.free_clusters()) == 3
prompts = np.random.default_rng(0).integers(
    0, cfg.vocab_size, (4, 8)).astype(np.int32)
out1 = tenant.generate(prompts, 6)
assert tenant.lease.n == 1                      # shrunk back after burst
assert len(sched.free_clusters()) == 3
out2 = tenant.generate(prompts, 6)              # warm burst, same window
np.testing.assert_array_equal(out1, out2)       # greedy => deterministic
assert len(tenant._engines) == 1                # the burst window, reused
# an offload tenant takes the head-room while serve is idle; the next
# burst is capped to what is free (here: the floor itself)
lease = sched.request("offload", n=3)
assert lease.clusters == (1, 2, 3)
out3 = tenant.generate(prompts, 6)
assert out3.shape == out1.shape
assert tenant.lease.n == 1
assert len(tenant._engines) == 2                # + the floor-window engine
lease.release()
tenant.close()
assert len(sched.free_clusters()) == 4
print("OK")
""", devices=4, x64=False, timeout=900)
