"""Dry-run machinery on a small (4×2) mesh: the same cell-builder code path
the 256/512-chip dry-run uses, kept cheap enough for CI.

The full production sweep (every arch × shape × {16×16, 2×16×16}) is run by
``python -m repro.launch.dryrun --all --mesh both`` and recorded in
EXPERIMENTS.md §Dry-run.
"""

import pytest


@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),
    ("smollm-360m", "decode_32k"),
    ("deepseek-v2-lite-16b", "train_4k"),
    ("falcon-mamba-7b", "long_500k"),
    ("zamba2-2.7b", "decode_32k"),
    ("paligemma-3b", "prefill_32k"),
])
def test_cell_lowers_and_compiles_small_mesh(subproc, arch, shape):
    subproc(f"""
import numpy as np, jax
from jax.sharding import Mesh
from repro.launch.cells import build_cell
from repro.launch.roofline import analyze

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
fn, args, meta = build_cell("{arch}", "{shape}", mesh)
compiled = fn.lower(*args).compile()
mem = compiled.memory_analysis()
roof = analyze(compiled, meta.model_flops, meta.chips)
assert roof.flops > 0 and roof.bytes_accessed > 0
assert roof.bottleneck in ("compute", "memory", "collective")
assert 0 <= roof.roofline_fraction <= 1.5
print("OK", roof.bottleneck, f"{{roof.roofline_fraction:.4f}}")
""", devices=8, x64=False, timeout=900)


def test_long_500k_skips_full_attention():
    from repro.launch.cells import applicable
    from repro.models.registry import get
    ok, why = applicable(get("yi-9b"), "long_500k")
    assert not ok and "full-attention" in why
    ok, _ = applicable(get("falcon-mamba-7b"), "long_500k")
    assert ok
    ok, _ = applicable(get("zamba2-2.7b"), "long_500k")
    assert ok


def test_make_production_mesh_shapes(subproc):
    subproc("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh(multi_pod=False)
assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.shape == (2, 16, 16)
assert m2.axis_names == ("pod", "data", "model")
print("OK")
""", devices=512, x64=False)
