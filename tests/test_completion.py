"""CompletionUnit register semantics (paper fig. 6) + property tests."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.completion import CompletionUnit


def test_basic_fire_and_reset():
    u = CompletionUnit()
    u.program(4, job_id=0)
    for _ in range(3):
        u.arrive(0)
        assert u.pending_cause() is None
    u.arrive(0)
    assert u.pending_cause() == 0          # fired at arrivals == offload
    assert u.clear() == 0
    assert u.pending_cause() is None
    u.program(2, job_id=0)                 # auto-reset allows reuse
    u.arrive(0, count=2)
    assert u.clear() == 0


def test_deferred_interrupt():
    """Fig. 6: a completion while another IPI is pending fires only after
    the pending one is cleared."""
    u = CompletionUnit(n_units=2)
    u.program(1, job_id=0)
    u.program(1, job_id=1)
    u.arrive(0)
    u.arrive(1)                            # completes while job 0 pending
    assert u.pending_cause() == 0
    assert u.clear() == 0
    assert u.pending_cause() == 1          # deferred IPI fires now
    assert u.clear() == 1


def test_outstanding_tracking():
    u = CompletionUnit(n_units=4)
    u.program(3, job_id=0)
    u.program(5, job_id=1)
    u.arrive(0)
    assert u.outstanding() == {0: 2, 1: 5}


def test_double_program_rejected():
    u = CompletionUnit()
    u.program(2, 0)
    with pytest.raises(RuntimeError):
        u.program(3, 0)


def test_arrival_without_program_rejected():
    u = CompletionUnit()
    with pytest.raises(RuntimeError):
        u.arrive(0)


@given(st.lists(st.integers(1, 6), min_size=1, max_size=20))
@settings(max_examples=100)
def test_every_programmed_job_eventually_fires(counts):
    """Property: N jobs through one unit, arrivals delivered in order ->
    every job fires exactly once, in order, regardless of arrival batching."""
    u = CompletionUnit(n_units=1)
    fired = []
    for jid, n in enumerate(counts):
        u.program(n, 0)
        left = n
        while left:
            step = min(left, 2)
            u.arrive(0, count=step)
            left -= step
        fired.append(u.clear())
    assert fired == [0] * len(counts)


@given(order=st.permutations(list(range(4))))
@settings(max_examples=40)
def test_out_of_order_completion(order):
    """Multiple outstanding jobs may complete in any order; causes are
    delivered in completion order."""
    u = CompletionUnit(n_units=4)
    for j in range(4):
        u.program(1, j)
    for j in order:
        u.arrive(j)
    causes = [u.clear() for _ in range(4)]
    assert causes == list(order)
