"""The unified session API: one submit path, typed policies, AUTO
planner, and the <15 %-error estimate contract (ISSUE-4 tentpole).

Model-level tests run in-process (pure arithmetic, no devices); dispatch
tests run in an 8-device subprocess like the rest of the offload suite.
The recorded-benchmark tests pin the acceptance criteria against
``BENCH_offload.json``: every ``Session.estimate`` prediction within the
paper's 15 % bar on the recorded points, and ``policy=AUTO`` never
slower than the best hand-picked legacy mode on the recorded ``stream``,
``staging``, and ``fused`` suites.
"""

import json
import os

import pytest

from repro.core import jobs, simulator
from repro.core.policy import AUTO, OffloadPolicy, Residency, Staging
from repro.core.session import Planner, estimate, predict_staging

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH = os.path.join(REPO, "BENCH_offload.json")

NS = (1, 2, 4, 8, 16, 32)

#: wallclock guard between separately-timed rows: this substrate (an
#: 8-device XLA mesh on a small CPU share) oscillates +-30% on
#: multi-second timescales — two timings of the *identical* submit
#: configuration measure 0.7x-1.4x apart (see the stream child's
#: round-robin note).  The strict acceptance claims below are therefore
#: the deterministic ones (decision identity, cycle-domain regret); the
#: wallclock comparisons only guard against a real regression hiding
#: under the noise.
WALL_TOL = 0.75


def _bench_rows(suite):
    with open(BENCH) as f:
        data = json.load(f)
    entry = data["suites"].get(suite)
    if entry is None or "rows" not in entry:
        pytest.skip(f"suite {suite} not recorded in BENCH_offload.json")
    return {r["name"]: r["value"] for r in entry["rows"]}


# ---------------------------------------------------------------------------
# The estimate contract (model-level, in-process)
# ---------------------------------------------------------------------------


def test_estimate_under_bar_every_job_every_n():
    """Session.estimate stays under the paper's 15 % bar across all six
    kernels and the full cluster sweep (the fig.-12 validation, through
    the session surface)."""
    cases = (jobs.make_axpy(1024), jobs.make_atax(64, 64),
             jobs.make_matmul(16, 16, 16), jobs.make_covariance(32, 64),
             jobs.make_montecarlo(16384), jobs.make_bfs(256))
    worst = 0.0
    for job in cases:
        for n in NS:
            est = estimate(job, n=n, policy=AUTO)
            sim = simulator.simulate(job.spec, n, "multicast").total
            worst = max(worst, simulator.model_error(est.job_cycles, sim))
    assert worst < 0.15, f"estimate model error {worst * 100:.1f}% >= 15%"


def test_estimate_vs_recorded_fig09_points():
    """Satellite: predictions within 15 % of the *recorded* runtime
    points (fig. 9 multicast curves in BENCH_offload.json)."""
    rows = _bench_rows("fig09")
    cases = {"axpy": jobs.make_axpy(1024), "atax": jobs.make_atax(64, 64)}
    checked = 0
    for label, job in cases.items():
        for n in NS:
            rec = rows.get(f"fig09/{label}/multicast/n={n}")
            if rec is None:
                continue
            est = estimate(job, n=n, policy=AUTO)
            assert simulator.model_error(est.job_cycles, rec) < 0.15, (
                label, n, est.job_cycles, rec)
            checked += 1
    assert checked >= 10


def test_predict_staging_vs_recorded_bench_points():
    """Satellite: every recorded staging point predicted within 15 % —
    the closed-form contract against the recorded discrete-event grid."""
    rows = _bench_rows("staging")
    checked = 0
    for name, rec in rows.items():
        if name.endswith("/model_error") or "/hf_over_tree/" in name:
            continue
        # staging/{kib}KiB/{mode}/n={n}
        _, kib, mode, npart = name.split("/")
        nbytes = int(kib[:-3]) * 1024
        n = int(npart.split("=")[1])
        pred = predict_staging(nbytes, n, Staging(mode))
        assert simulator.model_error(pred, rec) < 0.15, (name, pred, rec)
        checked += 1
    assert checked >= 30


def test_estimate_baseline_policy_and_validation():
    job = jobs.make_axpy(1024)
    base = estimate(job, n=8,
                    policy=OffloadPolicy(info_dist="p2p_chain"))
    ext = estimate(job, n=8, policy=AUTO)
    sim = simulator.simulate(job.spec, 8, "baseline").total
    assert base.job_cycles == pytest.approx(sim)
    assert base.job_cycles > ext.job_cycles      # the paper's headline
    with pytest.raises(ValueError):
        estimate(job)                            # n xor clusters
    with pytest.raises(ValueError):
        estimate(job, n=8, clusters=[0, 1])
    with pytest.raises(ValueError):
        estimate(job, n=0)
    with pytest.raises(ValueError):
        estimate(job, n=8, batch=0)


# ---------------------------------------------------------------------------
# The AUTO planner (model-level, in-process)
# ---------------------------------------------------------------------------


def test_planner_staging_decision():
    planner = Planner()
    # nothing replicated / single cluster -> nothing to fan out
    assert planner.pick_staging(0, 8) is Staging.DIRECT
    assert planner.pick_staging(1 << 20, 1) is Staging.DIRECT
    # the broadcast class at real widths rides the tree (cycle domain)
    for n in (4, 8, 16, 32):
        assert planner.pick_staging(64 * 1024, n) is Staging.TREE, n


def test_planner_substrate_tree_guard():
    """decide() only rides the tree once the replicated footprint is in
    the bandwidth-bound regime (Planner.TREE_MIN_BYTES); a model-faithful
    planner (tree_min_bytes=0) follows the cycle model everywhere."""
    small = jobs.make_covariance(32, 64)        # 16 KiB replicated
    big = jobs.make_covariance(1024, 2048)      # 16 MiB replicated
    default = Planner()
    assert default.decide(small, 8, 1, AUTO, 4).staging is Staging.DIRECT
    assert default.decide(big, 8, 1, AUTO, 4).staging is Staging.TREE
    faithful = Planner(tree_min_bytes=0)
    assert faithful.decide(small, 8, 1, AUTO, 4).staging is Staging.TREE
    # a pinned policy overrides the guard in either direction
    pinned = default.decide(small, 8, 1, AUTO.pinned(staging=Staging.TREE), 4)
    assert pinned.staging is Staging.TREE


def test_planner_fuse_and_window_decisions():
    planner = Planner()
    fine = jobs.make_axpy(16384).spec        # dispatch/staging-bound
    coarse = jobs.make_matmul(256, 256, 256).spec  # compute-bound
    assert planner.pick_fuse(fine, 8, batch=32) == 8
    assert planner.pick_fuse(coarse, 8, batch=32) == 1
    assert planner.pick_fuse(fine, 8, batch=1) == 1   # nothing to fuse
    assert planner.pick_fuse(fine, 8, batch=3) == 2   # capped by batch
    # window: bounded by completion units and by the launch count
    assert planner.pick_window(batch=1, fuse=1, n_units=4) == 4
    assert planner.pick_window(batch=32, fuse=1, n_units=4) == 4
    assert planner.pick_window(batch=32, fuse=8, n_units=8) == 4
    assert planner.pick_window(batch=8, fuse=8, n_units=4) == 1
    # resident single-job redispatch cannot fuse
    d = planner.decide(jobs.make_axpy(1024), 8, 1,
                       AUTO.pinned(residency=Residency.RESIDENT), 4)
    assert d.fuse == 1 and d.staging is Staging.DIRECT


def test_auto_staging_never_slower_on_recorded_grid():
    """Acceptance: AUTO's staging pick, evaluated point-by-point on the
    recorded staging suite, never loses to either hand-picked data
    path (exact, deterministic cycles)."""
    rows = _bench_rows("staging")
    planner = Planner()
    checked = 0
    for kib in (4, 64, 1024):
        for n in NS:
            by_mode = {m: rows.get(f"staging/{kib}KiB/{m}/n={n}")
                       for m in ("host_fanout", "tree")}
            if None in by_mode.values():
                continue
            pick = planner.pick_staging(kib * 1024, n)
            chosen = by_mode["tree" if pick in (Staging.TREE,
                                                Staging.TREE_RESHARD)
                             else "host_fanout"]
            assert chosen <= min(by_mode.values()), (kib, n, pick, by_mode)
            checked += 1
    assert checked >= 12


def test_auto_never_slower_on_recorded_stream_and_fused():
    """Acceptance: AUTO's fusion/pipeline configuration against the
    recorded ``stream`` suite — the planner's pick must match (fused
    regime) or measure at least as fast as (wallclock rows, within the
    measurement-noise allowance) the best hand-picked legacy mode."""
    rows = _bench_rows("stream")

    # fused regime (fine-grained axpy): the recorded AUTO pick must be
    # the B whose recorded per-job dispatch is the minimum of every
    # hand-picked mode, including the unfused resident baseline
    pick = int(rows["stream/fused/auto_fuse_pick"])
    b_rows = {b: rows[f"stream/fused/B{b}/dispatch"] for b in (1, 2, 4, 8)}
    legacy_best = min(min(b_rows.values()),
                      rows["stream/fused/resident_single_dispatch"])
    assert b_rows[pick] <= legacy_best, (pick, b_rows)

    # model side of the same claim, independent of the recording
    assert Planner().pick_fuse(jobs.make_axpy(16384).spec, 8, 8) == pick

    # stream regime (compute-bound matmul, fresh operands): AUTO's
    # recorded decision IS the best hand-picked configuration — the
    # pipelined, unfused mode (strict; the two dispatch through the same
    # stream machinery, so equality of configuration is equality of
    # mode).  The recorded wallclock row additionally sits within the
    # substrate-noise guard of the best fresh-staging legacy row.
    assert int(rows["stream/matmul256/8dev/auto/fuse"]) == 1
    assert int(rows["stream/matmul256/8dev/auto/window"]) > 1
    best_fresh = max(rows["stream/matmul256/8dev/seq_restage"],
                     rows["stream/matmul256/8dev/pipelined"])
    assert rows["stream/matmul256/8dev/auto"] >= best_fresh * WALL_TOL

    # resident regime: the open window (what AUTO picks for streaming
    # submits) beats — or ties within noise — the sequential mode
    assert (rows["stream/matmul256/8dev/pipelined_resident"]
            >= rows["stream/matmul256/8dev/seq_resident"] * WALL_TOL)


# ---------------------------------------------------------------------------
# The one submit path (dispatch-level, 8-device subprocess)
# ---------------------------------------------------------------------------


def test_session_single_multi_resident_paths(subproc):
    """submit(dict) / submit([dicts]) / submit(RESIDENT) all dispatch
    correctly through one path, with the planner's counters visible."""
    subproc("""
import numpy as np
from repro.api import AUTO, OffloadPolicy, Residency, Session
from repro.core import jobs

job = jobs.make_matmul(32, 16, 16)
insts, exps = jobs.make_instances(job, 6, seed0=0)
sess = Session(n_units=4)

# single
h = sess.submit(job, insts[0], n=8)
assert np.allclose(h.wait(), exps[0])

# multi under a pinned policy: 6 jobs at fuse=4 -> one fused launch + 2
# pipelined singles, results in submit order
hm = sess.submit(job, insts, n=8, policy=OffloadPolicy(fuse=4))
res = hm.wait()
assert len(res) == 6
for r, e in zip(res, exps):
    assert np.allclose(r, e)
assert hm.decision.fuse == 4
assert hm.jobs == 6

# resident redispatch (typed), primed through session.stage
sess.stage(job, insts[3], n=8)
hr = sess.submit(job, Residency.RESIDENT, n=8,
                 policy=OffloadPolicy(window=1))
assert np.allclose(hr.wait(), exps[3])

# resident fused redispatch of a staged (B, ...) batch
sess.stage(job, insts[:4], n=8)
hf = sess.submit(job, Residency.RESIDENT, n=8,
                 policy=OffloadPolicy(fuse=4, window=1))
rf = hf.wait()
assert len(rf) == 4
for r, e in zip(rf, exps[:4]):
    assert np.allclose(r, e)

# explain: predicted phases next to measured counters
text = str(h.explain())
assert "phase E" in text and "measured" in text and "device_puts" in text
assert sess.stats.dispatches >= 6
print("OK")
""")


def test_session_pipelines_successive_singles(subproc):
    """Successive single submits of one (job, selection) pair share a
    pipelined stream: handles stay in flight up to the window, results
    stay correct in any wait order, and no plan/compile is rebuilt."""
    subproc("""
import numpy as np
from repro.api import OffloadPolicy, Session
from repro.core import jobs

job = jobs.make_axpy(2048)
insts, exps = jobs.make_instances(job, 10, seed0=5)
sess = Session(n_units=3)
sess.submit(job, insts[0], n=4).wait()            # warm plan + compile
rt = sess.runtime()
plans_before, compiled_before = len(rt._plans), len(rt._compiled)

handles = [sess.submit(job, insts[i], n=4) for i in range(10)]
stream = next(iter(sess._streams.values()))
assert 1 <= stream.inflight <= 3                  # window = n_units
assert stream.stats["window_stalls"] >= 10 - 3    # the window filled
for h, e in zip(reversed(handles), reversed(exps)):   # any order
    assert np.allclose(h.wait(), e)
assert len(rt._plans) == plans_before
assert len(rt._compiled) == compiled_before
sess.drain()

# a pinned window=1 policy is the sequential mode
hseq = sess.submit(job, insts[0], n=4, policy=OffloadPolicy(window=1))
assert np.allclose(hseq.wait(), exps[0])
print("OK")
""")


def test_session_auto_tree_staging_and_baseline(subproc):
    """AUTO picks tree staging for the broadcast class (one host upload
    per replicated operand, byte-counted) and a baseline policy flows
    through the same submit path with the O(n) chain structure."""
    subproc("""
import numpy as np
from repro.api import AUTO, InfoDist, OffloadPolicy, Planner, Residency, Session, Staging
from repro.core import jobs
from repro.core.offload import count_collectives

job = jobs.make_covariance(64, 128)     # 64 KiB replicated data matrix
# model-faithful planner: follow the cycle model's tree pick at any size
sess = Session(planner=Planner(tree_min_bytes=0))
operands, expected = job.make_instance(0)
h = sess.submit(job, operands, n=8)
assert h.decision.staging is Staging.TREE
assert np.allclose(h.wait(), expected)
st = h.explain().stats
# tree staging: the replicated operand (and the replicated job args)
# crossed the host link exactly once each, fanning out device-to-device
args_bytes = 8 * 8
assert st.h2d_bytes == operands["data"].nbytes + args_bytes
assert st.d2d_bytes == 7 * (operands["data"].nbytes + args_bytes)
assert st.tree_stages == 2

est = sess.estimate(job, n=8)
assert est.staging_cycles["tree"] < est.staging_cycles["host_fanout"]

# baseline implementation through the same path
base = OffloadPolicy(info_dist=InfoDist.P2P_CHAIN,
                     completion="central_counter")
hb = sess.submit(job, operands, n=8, policy=base)
assert np.allclose(hb.wait(), expected)
colls = count_collectives(sess.runtime(base).lowered_text(job, 8))
assert colls["collective-permute"] == 2 * (8 - 1)
print("OK")
""")


def test_session_window_cap_and_adopted_runtime(subproc):
    """Regressions: a pinned window above the completion-unit count is
    clamped (not a CompletionUnit crash), and a Session adopting a
    runtime with a non-default staging config keeps its warm plans."""
    subproc("""
import numpy as np
from repro.api import (
    OffloadConfig, OffloadPolicy, OffloadRuntime, Residency, Session, Staging,
)
from repro.core import jobs

job = jobs.make_matmul(32, 16, 16)
insts, exps = jobs.make_instances(job, 12, seed0=0)

# 6 fused launches through a window pinned far above n_units=4: the
# submit path must clamp to the completion-unit copies
sess = Session(n_units=4)
h = sess.submit(job, insts, n=8, policy=OffloadPolicy(fuse=2, window=16))
for r, e in zip(h.wait(), exps):
    assert np.allclose(r, e)

# a runtime whose config default is TREE staging still backs the
# session (warm plan + residency survive adoption)
rt = OffloadRuntime(config=OffloadConfig(staging=Staging.TREE))
rt.offload(job, insts[0], n=8).wait()
s2 = Session(runtime=rt)
got = s2.submit(job, Residency.RESIDENT, n=8,
                policy=OffloadPolicy(window=1)).wait()
assert np.allclose(got, exps[0])
assert s2.runtime() is rt
print("OK")
""")


def test_legacy_surface_deprecations(subproc):
    """Satellite: every legacy spelling warns exactly once per call and
    keeps working; the session path stays silent."""
    subproc("""
import warnings
import numpy as np
from repro.api import Residency, Session, Staging
from repro.core import jobs
from repro.core.offload import OffloadRuntime
from repro.core.stream import OffloadStream

job = jobs.make_axpy(512)
operands, expected = job.make_instance(0)
rt = OffloadRuntime()

def deprecations(records):
    return [w for w in records if issubclass(w.category, DeprecationWarning)]

# offload(job, "resident") warns; Residency.RESIDENT does not
rt.offload(job, operands, n=2).wait()
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    got = rt.offload(job, "resident", n=2).wait()
assert np.allclose(got, expected) and len(deprecations(w)) == 1
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    rt.offload(job, Residency.RESIDENT, n=2).wait()
assert not deprecations(w)

# string via= warns; Staging enum does not
plan = rt.plan(job, operands, n=2)
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    plan.stage(operands, via="tree")
assert len(deprecations(w)) == 1
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    plan.stage(operands, via=Staging.TREE)
assert not deprecations(w)

# direct OffloadStream construction warns (string staging doubles up)
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    stream = OffloadStream(rt, job, n=2, staging="tree")
assert len(deprecations(w)) == 2
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    stream.submit("resident").wait()
assert len(deprecations(w)) == 1

# direct offload_fused warns
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    insts, _ = jobs.make_instances(job, 2, seed0=0)
    rt.offload_fused(job, insts, n=2).wait()
assert len(deprecations(w)) == 1

# unknown modes still fail loudly under either spelling
try:
    rt.offload(job, "residnet", n=2)
    raise AssertionError("expected ValueError")
except ValueError:
    pass
try:
    rt.offload(job, Residency.FRESH, n=2)
    raise AssertionError("expected ValueError")
except ValueError:
    pass

# the session path is warning-free
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    sess = Session()
    sess.submit(job, operands, n=2).wait()
    sess.stage(job, operands, n=2)
    sess.submit(job, Residency.RESIDENT, n=2).wait()
    sess.drain()
assert not deprecations(w), [str(x.message) for x in deprecations(w)]
print("OK")
""")
