"""The dispatch fast path: plan caching, resident operands, donation,
job-args caching, and out-of-order completion (subprocess, 8-device mesh)."""


def test_warm_plan_zero_recompiles_and_zero_device_puts(subproc):
    """A warm resident dispatch does no compilation and no host->device
    operand transfer; results are bit-for-bit identical to fresh staging."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime

rt = OffloadRuntime()
job = jobs.make_axpy(2048)
operands, expected = job.make_instance(3)

r_fresh = rt.offload(job, operands, n=8).wait()
compiled_after_first = len(rt._compiled)
plans_after_first = rt.plan_misses
puts_after_first = rt.stats.device_puts

for _ in range(3):
    r_res = rt.offload(job, "resident", n=8).wait()
    assert np.array_equal(r_fresh, r_res)            # bit-for-bit

assert len(rt._compiled) == compiled_after_first     # zero recompiles
assert rt.plan_misses == plans_after_first           # zero plan rebuilds
assert rt.stats.device_puts == puts_after_first      # zero uploads
assert rt.stats.resident_hits == 3 * 2               # 2 operands x 3 jobs
assert np.allclose(r_fresh, expected)
print("OK")
""")


def test_resident_matches_fresh_across_jobs(subproc):
    """Resident results == fresh-staging results for every paper kernel."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime

rt = OffloadRuntime()
for name, mk in jobs.PAPER_JOBS.items():
    job = mk() if name != "bfs" else mk(64)
    operands, expected = job.make_instance(2)
    fresh = rt.offload(job, operands, n=4).wait()
    res = rt.offload(job, "resident", n=4).wait()
    assert np.array_equal(fresh, res), name
    assert np.allclose(fresh, expected, rtol=1e-9, atol=1e-9), name
print("OK")
""")


def test_donation_does_not_corrupt_reuse(subproc):
    """donate_operands consumes resident buffers; the plan re-stages from
    host refs so repeated resident dispatch stays correct."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig

rt = OffloadRuntime(config=OffloadConfig(donate_operands=True))
job = jobs.make_axpy(1024)
operands, expected = job.make_instance(1)
r0 = rt.offload(job, operands, n=8).wait()
r1 = rt.offload(job, "resident", n=8).wait()
r2 = rt.offload(job, "resident", n=8).wait()
assert np.array_equal(r0, r1) and np.array_equal(r1, r2)
assert np.allclose(r0, expected)
assert rt.stats.donation_restages == 2 * 2   # 2 operands x 2 resident jobs
# and still zero recompiles across all of it
assert len(rt._compiled) == 1
print("OK")
""")


def test_out_of_order_wait_three_outstanding(subproc):
    """>=3 outstanding jobs waited on in reverse order all resolve."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime

rt = OffloadRuntime()
js = [jobs.make_axpy(256), jobs.make_matmul(), jobs.make_axpy(128)]
insts = [j.make_instance(i) for i, j in enumerate(js)]
hs = [rt.offload(j, ops, n=nsel)
      for (j, (ops, _), nsel) in zip(js, insts, (4, 2, 8))]
assert set(rt.unit.outstanding()) == {0, 1, 2}
results = [hs[2].wait(), hs[0].wait(), hs[1].wait()]
for h, (_, exp) in zip(hs, insts):
    assert np.allclose(h.wait(), exp)        # wait() is idempotent
assert rt.unit.outstanding() == {}
print("OK")
""")


def test_job_args_cache_and_invalidation(subproc):
    """Unchanged job args skip the upload; changed args and invalidated
    operands re-stage (and change the result, proving they were applied)."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime

rt = OffloadRuntime()
job = jobs.make_axpy(512)
operands, expected = job.make_instance(0)
rt.offload(job, operands, n=8).wait()
rt.offload(job, "resident", n=8).wait()
assert rt.stats.args_hits == 1               # same default args -> skipped

# changed args re-upload and scale the result (the job-info path is live)
r2 = rt.offload(job, "resident", job_args=np.full((8,), 2.0), n=8).wait()
assert np.allclose(r2, 2.0 * expected)

# explicit invalidation forces an error until re-staged
plan = rt.plan(job, operands, n=8)
plan.invalidate()
try:
    rt.offload(job, "resident", n=8)
    raise SystemExit("expected RuntimeError after invalidate()")
except RuntimeError:
    pass
r3 = rt.offload(job, operands, n=8).wait()
assert np.allclose(r3, expected)
print("OK")
""")


def test_plan_api_direct_staging(subproc):
    """plan() + plan.stage() primes residency without a dispatch."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime

rt = OffloadRuntime()
job = jobs.make_axpy(512)
operands, expected = job.make_instance(4)
plan = rt.plan(job, operands, n=4)
assert not plan.has_resident                 # plan() only resolves/caches
plan.stage(operands)
assert plan.has_resident
got = rt.offload(job, "resident", n=4).wait()
assert np.allclose(got, expected)
assert rt.plan(job, n=4) is plan             # cached lookup, no operands
print("OK")
""")
