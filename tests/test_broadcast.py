"""The hierarchical broadcast tree and the staging cost model.

Tree shape/coverage is property-tested against the brute-force
``MulticastRequest`` decode oracle: every selected cluster is reached
exactly once, the depth respects the fig.-5 two-level bound, and the
degenerate (n=1) and non-power-of-two selections behave.  The staging
cost model's closed form is validated against the discrete-event
simulation under the paper's <15 % bar (§6).
"""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import broadcast as bc
from repro.core import multicast as mc
from repro.core import simulator


def check_tree(tree: bc.BroadcastTree, ids) -> None:
    """Structural invariants every fan-out tree must satisfy."""
    ids = sorted(set(ids))
    assert tree.clusters == tuple(ids)
    assert tree.root == ids[0]
    # coverage: root + every edge destination == the selection, no repeats
    assert tree.reached() == tuple(ids)
    assert len(tree.edges) == len(ids) - 1
    dsts = [d for _, d in tree.edges]
    assert len(dsts) == len(set(dsts)), "a cluster was reached twice"
    assert tree.root not in dsts
    # causality: every level's sources already hold the data, and a level
    # never reuses a node (edges of one level are parallel transfers)
    have = {tree.root}
    for level in tree.levels:
        used = set()
        assert level, "empty level recorded"
        for s, d in level:
            assert s in have, f"source {s} sends before receiving"
            assert d not in have, f"{d} receives twice"
            assert s not in used and d not in used, "node reused in level"
            used |= {s, d}
        have |= {d for _, d in level}
    assert have == set(ids)
    # the fig.-5 depth bound
    assert tree.depth <= bc.depth_bound(ids)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, mc.NUM_CLUSTERS - 1), min_size=1,
                max_size=mc.NUM_CLUSTERS))
def test_tree_covers_any_selection(ids):
    check_tree(bc.build_tree(ids), ids)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, (1 << mc.CLUSTER_IDX_BITS + mc.QUADRANT_IDX_BITS) - 1),
       st.integers(0, (1 << mc.CLUSTER_IDX_BITS + mc.QUADRANT_IDX_BITS) - 1))
def test_tree_from_request_matches_decode_oracle(base, varying):
    """The tree reaches exactly the clusters the (addr, mask) decodes to."""
    req = mc.MulticastRequest(addr=base << mc.CLUSTER_OFFSET_BITS,
                              mask=varying << mc.CLUSTER_OFFSET_BITS)
    oracle = mc.decode_cluster_selection(req, mc.NUM_CLUSTERS)
    tree = bc.tree_from_request(req)
    assert tree.reached() == tuple(sorted(oracle))
    check_tree(tree, oracle)


def test_degenerate_single_cluster():
    tree = bc.build_tree([5])
    assert tree.depth == 0 and tree.edges == () and tree.root == 5
    assert tree.reached() == (5,)


def test_non_power_of_two_selection():
    ids = [0, 1, 2, 5, 6]                # 3 + 2 across two quadrants
    tree = bc.build_tree(ids)
    check_tree(tree, ids)
    assert tree.depth <= 1 + 2           # ceil(log2 2) + ceil(log2 3)


def test_quadrant_structure_full_mesh():
    """Full 32-cluster selection: inter-quadrant rounds precede intra, and
    the depth hits exactly ceil(log2 8) + ceil(log2 4) = 5."""
    tree = bc.build_tree(range(mc.NUM_CLUSTERS))
    assert tree.depth == 5 == bc.depth_bound(range(mc.NUM_CLUSTERS))
    q = lambda c: c // mc.CLUSTERS_PER_QUADRANT
    for level in tree.levels[:3]:        # the rep broadcast crosses quadrants
        assert all(q(s) != q(d) for s, d in level)
    for level in tree.levels[3:]:        # the fan-in stays quadrant-local
        assert all(q(s) == q(d) for s, d in level)


def test_parents_map_is_a_tree():
    tree = bc.build_tree(range(8))
    parents = tree.parents()
    assert set(parents) == set(range(1, 8))
    for child in parents:                # every node walks back to the root
        seen, node = set(), child
        while node != tree.root:
            assert node not in seen
            seen.add(node)
            node = parents[node]


def test_empty_selection_rejected():
    with pytest.raises(ValueError):
        bc.build_tree([])


# --- staging cost model ------------------------------------------------------


def test_staging_model_error_below_paper_bar():
    """Closed form vs discrete event < 15% in the link-bound regime."""
    for kib in (4, 64, 1024):
        for mode in simulator.STAGING_MODES:
            for n in (1, 2, 4, 8, 16, 32):
                err = simulator.staging_model_error(kib * 1024, n, mode)
                assert err < 0.15, (kib, mode, n, err)


def test_tree_staging_beats_host_fanout_in_cycles():
    """Link-bound operands: the O(1)-link + O(log n)-hop tree undercuts the
    O(n) link from n=4 up.  Tiny operands flip the other way until the
    saved link transfers outweigh the per-hop latency (the offload-decision
    flavour of §5.6) — the model resolves the crossover."""
    for nbytes in (64 * 1024, 1024 * 1024):
        for n in (4, 8, 16, 32):
            tree = simulator.simulate_staging(nbytes, n, "tree")
            hf = simulator.simulate_staging(nbytes, n, "host_fanout")
            assert tree < hf, (nbytes, n, tree, hf)
    # 4 KiB: per-hop latency dominates at n=8, the link wins by n=16
    assert (simulator.simulate_staging(4096, 8, "tree")
            > simulator.simulate_staging(4096, 8, "host_fanout"))
    assert (simulator.simulate_staging(4096, 16, "tree")
            < simulator.simulate_staging(4096, 16, "host_fanout"))


def test_staging_monotone_in_n_and_size():
    last = 0.0
    for n in (1, 2, 4, 8, 16, 32):
        t = simulator.simulate_staging(64 * 1024, n, "tree")
        assert t > last
        last = t
    assert (simulator.simulate_staging(2 << 20, 8, "host_fanout")
            > simulator.simulate_staging(1 << 20, 8, "host_fanout"))


def test_staging_accepts_explicit_selection():
    """Cluster-id selections (not just counts) drive the tree shape: a
    cross-quadrant pair pays the cross-quadrant hop the closed form
    assumes, a same-quadrant pair is cheaper."""
    same = simulator.simulate_staging(64 * 1024, [0, 1], "tree")
    cross = simulator.simulate_staging(64 * 1024, [0, 4], "tree")
    assert cross > same
    assert simulator.staging_model_error(64 * 1024, [0, 4], "tree") < 0.15


def test_cost_model_calibration_roundtrip():
    cm = simulator.StagingCostModel.calibrate(10.0, 18.0, 26.0, k=4)
    assert cm.t_up == pytest.approx(8.0)
    assert cm.t_edge == pytest.approx(16.0 / 3)
    assert cm.predict("host_fanout", 1) == pytest.approx(10.0)
    assert cm.predict("host_fanout", 8) == pytest.approx(66.0)
    assert cm.predict("tree", 8) == pytest.approx(
        2.0 + 8.0 + 7 * 16.0 / 3)
    with pytest.raises(ValueError):
        simulator.StagingCostModel.calibrate(10.0, 9.0, 26.0)
    with pytest.raises(ValueError):
        cm.predict("warp", 4)


def test_model_error_api():
    assert simulator.model_error(115.0, 100.0) == pytest.approx(0.15)
    assert simulator.model_error(85.0, 100.0) == pytest.approx(0.15)
    with pytest.raises(ValueError):
        simulator.model_error(1.0, 0.0)
