"""Elastic rescale (repro.ft.elastic): restore onto a shrunken mesh.

Complements tests/test_checkpoint.py (which drives a full train loop):
here we exercise the restore path in isolation — a checkpoint written
under an 8-way data mesh comes back bit-identical on 2 surviving
devices, with the shardings re-derived for the smaller mesh.
"""

import jax
import numpy as np

from repro.ft.elastic import make_data_mesh


def test_make_data_mesh_defaults_to_all_devices():
    mesh = make_data_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == jax.device_count()
    two = make_data_mesh(jax.devices()[:1])
    assert two.devices.size == 1


def test_elastic_restore_shrunken_mesh_bit_identical(subproc, tmp_path):
    subproc(f"""
import jax, numpy as np
from jax.sharding import PartitionSpec
from repro import models as M
from repro.checkpoint import latest_step, save
from repro.dist.sharding import param_specs
from repro.ft.elastic import elastic_restore, make_data_mesh
from repro.optim.adamw import adamw_init

cfg = M.reduced(M.get("smollm-360m"))
devs = jax.devices()
params = jax.device_get(M.init_params(jax.random.key(0), cfg))
opt = adamw_init(params)

mesh8 = make_data_mesh(devs)
pspecs = param_specs(params, mesh8)
specs = {{"params": pspecs,
          "opt": {{"mu": pspecs, "nu": pspecs, "count": PartitionSpec()}}}}
d = r"{tmp_path}"
save(d, 3, {{"params": params, "opt": opt}}, specs, data_index=12)
assert latest_step(d) == 3

# half the machine is gone: restore on the 2 survivors
pshapes = jax.tree.map(
    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
step, data_index, state, mesh2 = elastic_restore(d, devs[:2], pshapes)
assert (step, data_index) == (3, 12)
assert mesh2.devices.size == 2 and mesh2.axis_names == ("data",)

restored = jax.device_get(state)
jax.tree.map(np.testing.assert_array_equal, restored["params"], params)
jax.tree.map(np.testing.assert_array_equal, restored["opt"], opt)

# the restored arrays really live on the shrunken mesh
leaf = jax.tree.leaves(state["params"])[0]
assert len(leaf.devices()) <= 2
print("OK")
""", devices=8, x64=False)
