"""The static offload verifier + hazard sanitizer (ISSUE-9).

Three layers of coverage:

* **diagnostics** — the stable ``OFL###`` code table is snapshot-pinned
  the way ``test_api_surface.py`` pins the API; every code JSON
  round-trips; every code has a unit test triggering it *statically*
  (no dispatch).
* **verifier** — property tests over randomly generated DAGs with
  seeded defects (cycle / dangling ref / double-donate / sharding
  mismatch) assert the exact expected code set, and defect-free random
  DAGs verify clean; a subprocess check shows a verified graph runs
  bit-identical to an unverified one.
* **sanitizer** — each hazard class (read-after-donate, read-after-
  revoke, issue-order violation, double collect, lease overlap) trips
  :class:`SanitizerError` through the real hook sites, and a clean run
  under ``REPRO_SANITIZE=1`` records events with zero violations.
"""

import json

import numpy as np
import pytest

from repro.analysis import (
    CODES,
    Diagnostic,
    SanitizerError,
    Severity,
    VerificationError,
    explain,
    sanitizer,
    verify,
    verify_graph,
    verify_policy,
)
from repro.core import jobs
from repro.core.policy import OffloadPolicy, Residency, RetryPolicy
from repro.core.scoreboard import GraphNode, Ref, Scoreboard

from _hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# the diagnostic vocabulary
# ---------------------------------------------------------------------------

#: append-only snapshot: a released code keeps its number, title, and
#: severity forever (new codes extend this table in the same commit)
CODE_SNAPSHOT = {
    "OFL001": ("dependency cycle", "error"),
    "OFL002": ("dangling or malformed node reference", "error"),
    "OFL003": ("use-after-donate", "error"),
    "OFL004": ("WAR/WAW rename required", "warning"),
    "OFL005": ("cross-lease circular wait", "warning"),
    "OFL006": ("sharding mismatch", "error"),
    "OFL007": ("graph width exceeds the in-flight window", "warning"),
    "OFL008": ("invalid mode value", "error"),
    "OFL009": ("invalid policy field", "error"),
    "OFL010": ("policy contradiction", "error"),
    "OFL011": ("inactive lease", "error"),
    "OFLP101": ("suboptimal staging mode", "perf"),
    "OFLP102": ("missed fusion opportunity", "perf"),
    "OFLP103": ("in-flight window below model-optimal", "perf"),
    "OFLP104": ("reshard/forward on the critical path", "perf"),
    "OFLP105": ("selection breaks single-request multicast", "perf"),
    "OFLP106": ("resident operand never reused", "perf"),
    "OFLP107": ("donation disabled on a dead buffer", "perf"),
}


def codes_of(diags):
    return sorted({d.code for d in diags})


def test_code_table_pinned():
    assert {c: (i.title, i.severity.value) for c, i in CODES.items()} \
        == CODE_SNAPSHOT


def test_every_code_json_round_trips():
    for code in CODES:
        d = Diagnostic(code, f"synthetic {code} finding",
                       severity=CODES[code].severity, node=3, name="n3")
        restored = Diagnostic.from_json(d.to_json())
        assert restored == d
        payload = json.loads(d.to_json())
        assert payload["code"] == code
        assert payload["title"] == CODES[code].title
        assert payload["severity"] == CODES[code].severity.value


def test_explain_and_unknown_code():
    from repro.analysis.diagnostics import UnknownDiagnosticCode

    for code in CODES:
        text = explain(code)
        assert code in text and CODES[code].title in text
    # the typed error is still a KeyError (the legacy contract), but
    # carries the offending code and a nearest-code suggestion
    with pytest.raises(KeyError):
        explain("OFL999")
    with pytest.raises(UnknownDiagnosticCode) as ei:
        explain("OFLP110")
    assert ei.value.code == "OFLP110"
    assert ei.value.suggestion in CODES
    assert ei.value.suggestion.startswith("OFLP")
    assert "did you mean" in str(ei.value)
    with pytest.raises(UnknownDiagnosticCode) as ei:
        explain("ofl001")   # close but not a code: suggests the real one
    assert ei.value.suggestion == "OFL001"
    with pytest.raises(ValueError):
        Diagnostic("OFL999", "nope")


def test_as_error_carries_diagnostic():
    d = Diagnostic("OFL010", "a contradicts b")
    err = d.as_error(TypeError)
    assert isinstance(err, TypeError)
    assert err.code == "OFL010"
    assert err.diagnostic is d


# ---------------------------------------------------------------------------
# per-code static triggers
# ---------------------------------------------------------------------------

_JOB = jobs.make_axpy(64)
_OPS = {k: np.asarray(v, dtype="float32")
        for k, v in _JOB.make_instance(0)[0].items()}


class _DeletedBuf:
    """Duck-types a donated jax array (shape + is_deleted)."""

    shape = (64,)

    def is_deleted(self):
        return True


def test_ofl001_cycle():
    nodes = [GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("b")}, name="a"),
             GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("a")}, name="b")]
    assert codes_of(verify_graph(nodes)) == ["OFL001"]


def test_ofl001_self_dependency():
    nodes = [GraphNode(_JOB, {"x": _OPS["x"], "y": Ref(0)})]
    assert codes_of(verify_graph(nodes)) == ["OFL001"]


def test_ofl002_dangling_ref_and_empty():
    nodes = [GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("ghost")})]
    assert codes_of(verify_graph(nodes)) == ["OFL002"]
    assert codes_of(verify_graph([])) == ["OFL002"]
    assert codes_of(verify_graph([GraphNode(_JOB, _OPS),
                                  "not a node"])) == ["OFL002"]


def test_ofl002_duplicate_names_and_bad_operands():
    nodes = [GraphNode(_JOB, _OPS, name="dup"),
             GraphNode(_JOB, _OPS, name="dup")]
    assert "OFL002" in codes_of(verify_graph(nodes))
    nodes = [GraphNode(_JOB, "resident-typo-string")]
    assert codes_of(verify_graph(nodes)) == ["OFL002"]


def test_ofl003_use_after_donate_static():
    nodes = [GraphNode(_JOB, {"x": _DeletedBuf(), "y": _OPS["y"]})]
    diags = verify_graph(nodes)
    assert codes_of(diags) == ["OFL003"]
    assert "donating dispatch" in diags[0].message
    # single-submit shape too
    diags = verify(_JOB, operands={"x": _DeletedBuf(), "y": _OPS["y"]})
    assert codes_of(diags) == ["OFL003"]


def test_ofl004_donation_rename_warning():
    pol = OffloadPolicy(donate_operands=True)
    nodes = [GraphNode(_JOB, _OPS, name="p"),
             GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("p")})]
    diags = verify_graph(nodes, policy=pol)
    assert "OFL004" in codes_of(diags)
    (d,) = [d for d in diags if d.code == "OFL004"]
    assert d.severity is Severity.WARNING and d.node == 0
    # no donation -> no warning
    assert "OFL004" not in codes_of(verify_graph(nodes))


def test_ofl005_cross_lease_cycle_warning():
    class _S:          # stand-in sessions: identity is all that matters
        pass

    s1, s2 = _S(), _S()
    nodes = [
        GraphNode(_JOB, _OPS, name="a", session=s1),
        GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("a")}, name="b",
                  session=s2),
        GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("b")}, name="c",
                  session=s1),
        GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("c")}, name="d",
                  session=s2),
    ]
    diags = verify_graph(nodes)
    assert "OFL005" in codes_of(diags)
    assert all(d.severity is Severity.WARNING
               for d in diags if d.code == "OFL005")
    # one-way cross-lease flow is fine
    assert "OFL005" not in codes_of(verify_graph(nodes[:2]))


def test_ofl006_shard_divisibility_and_name_mismatch():
    odd = jobs.make_axpy(63)
    ops, _ = odd.make_instance(0)
    nodes = [GraphNode(odd, {k: np.asarray(v) for k, v in ops.items()}, n=8)]
    assert codes_of(verify_graph(nodes)) == ["OFL006"]
    # operand names that don't match the job's shard_axes
    nodes = [GraphNode(_JOB, {"x": _OPS["x"], "z": _OPS["y"]})]
    assert codes_of(verify_graph(nodes)) == ["OFL006"]
    assert codes_of(verify(_JOB, operands={"x": _OPS["x"]})) == ["OFL006"]


def test_ofl006_forward_edge_shape_propagation():
    """A consumer whose forwarded operand can never match: the producer
    computes a (16, 16) @ (16,) matvec -> (16,), but the consumer's
    matching operand is (8, 16)-shaped in its other input."""
    atax = jobs.make_atax(16, 16)
    aops, _ = atax.make_instance(0)
    aops = {k: np.asarray(v) for k, v in aops.items()}
    bad_A = np.zeros((8, 24))        # atax consumer: x must be (24,)
    nodes = [
        GraphNode(atax, aops, name="p"),
        GraphNode(atax, {"A": bad_A, "x": Ref("p")}),
    ]
    diags = verify_graph(nodes)
    assert "OFL006" in codes_of(diags)
    good = [GraphNode(atax, aops, name="p"),
            GraphNode(atax, {"A": np.zeros((8, 16)), "x": Ref("p")}, n=8)]
    assert verify_graph(good) == []


def test_ofl007_width_exceeds_window():
    pol = OffloadPolicy(window=2)
    src = GraphNode(_JOB, _OPS, name="src")
    fan = [GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("src")})
           for _ in range(5)]
    diags = verify_graph([src] + fan, policy=pol, n_units=4)
    assert "OFL007" in codes_of(diags)
    assert all(d.severity is Severity.WARNING
               for d in diags if d.code == "OFL007")
    assert "OFL007" not in codes_of(
        verify_graph([src] + fan[:2], policy=pol, n_units=4))


def test_ofl008_ofl009_ofl010_policy_codes():
    assert codes_of(verify_policy(staging="bogus")) == ["OFL008"]
    assert codes_of(verify_policy(fuse=0)) == ["OFL009"]
    assert codes_of(verify_policy(retry="not-a-retry")) == ["OFL009"]
    assert codes_of(verify_policy(residency="resident",
                                  staging="tree")) == ["OFL010"]
    assert verify_policy(OffloadPolicy()) == []
    # the constructor shims carry the same codes on the raised error
    with pytest.raises(ValueError) as ei:
        OffloadPolicy(info_dist="mulitcast")
    assert ei.value.code == "OFL008"
    assert ei.value.diagnostic.code == "OFL008"
    with pytest.raises(ValueError) as ei:
        RetryPolicy(backoff=0.5)
    assert ei.value.code == "OFL009"
    with pytest.raises(ValueError) as ei:
        OffloadPolicy(residency=Residency.RESIDENT, staging="tree")
    assert ei.value.code == "OFL010"
    # graph policy contradiction: retry on a graph submit
    nodes = [GraphNode(_JOB, _OPS)]
    diags = verify_graph(nodes, policy=OffloadPolicy(retry=RetryPolicy()))
    assert "OFL010" in codes_of(diags)


def test_ofl011_inactive_lease():
    class _Lease:
        lease_id = 7
        clusters = (0, 1)
        active = False

    diags = verify(_JOB, lease=_Lease())
    assert codes_of(diags) == ["OFL011"]
    _Lease.active = True
    assert verify(_JOB, lease=_Lease()) == []


# ---------------------------------------------------------------------------
# property tests: random DAGs, seeded defects
# ---------------------------------------------------------------------------


def _random_dag(rng, n_nodes):
    """A defect-free random DAG over the axpy job (all shapes valid)."""
    nodes = []
    for i in range(n_nodes):
        ops = {"x": _OPS["x"], "y": _OPS["y"]}
        if i and rng.random() < 0.7:
            ops["y"] = Ref(int(rng.integers(0, i)))
        after = []
        if i and rng.random() < 0.3:
            after.append(int(rng.integers(0, i)))
        nodes.append(GraphNode(_JOB, ops, name=f"n{i}", after=after))
    return nodes


@given(st.integers(0, 2**32 - 1), st.integers(2, 10))
@settings(max_examples=40, deadline=None)
def test_defect_free_random_dags_verify_clean(seed, n_nodes):
    rng = np.random.default_rng(seed)
    nodes = _random_dag(rng, n_nodes)
    assert [d for d in verify_graph(nodes, default_width=1)
            if d.severity is Severity.ERROR] == []


@given(st.integers(0, 2**32 - 1), st.integers(3, 10),
       st.sampled_from(["cycle", "dangling", "donated", "mismatch"]))
@settings(max_examples=60, deadline=None)
def test_seeded_defects_report_exact_codes(seed, n_nodes, defect):
    rng = np.random.default_rng(seed)
    nodes = _random_dag(rng, n_nodes)
    victim = int(rng.integers(1, n_nodes))
    expected = {
        "cycle": "OFL001", "dangling": "OFL002",
        "donated": "OFL003", "mismatch": "OFL006",
    }[defect]
    if defect == "cycle":
        # back-edge from an ancestor: victim -> later node
        nodes[victim - 1].operands = dict(nodes[victim - 1].operands)
        nodes[victim - 1].operands["y"] = Ref(f"n{victim}")
        nodes[victim].operands = dict(nodes[victim].operands)
        nodes[victim].operands["y"] = Ref(f"n{victim - 1}")
        nodes[victim].after = ()
        nodes[victim - 1].after = ()
    elif defect == "dangling":
        nodes[victim].operands = dict(nodes[victim].operands)
        nodes[victim].operands["y"] = Ref("no-such-node")
    elif defect == "donated":
        nodes[victim].operands = {"x": _DeletedBuf(), "y": _OPS["y"]}
    else:
        odd = jobs.make_axpy(63)
        oops, _ = odd.make_instance(0)
        nodes[victim] = GraphNode(
            odd, {k: np.asarray(v) for k, v in oops.items()},
            name=f"n{victim}", n=8)
    errors = [d for d in verify_graph(nodes, default_width=1)
              if d.severity is Severity.ERROR]
    assert codes_of(errors) == [expected], errors


def test_session_gate_raises_verification_error(subproc):
    out = subproc("""
        import numpy as np
        from repro.api import Session, GraphNode, GraphError, \\
            VerificationError, Ref
        from repro.core import jobs

        job = jobs.make_axpy(2048)
        ops, _ = job.make_instance(0)
        sess = Session()
        bad = [GraphNode(job, {"x": ops["x"], "y": Ref("b")}, name="a"),
               GraphNode(job, {"x": ops["x"], "y": Ref("a")}, name="b")]
        try:
            sess.submit_graph(bad)
        except VerificationError as e:
            assert e.codes == ("OFL001",), e.codes
            assert isinstance(e, GraphError)        # legacy except clauses
            print("gate", e.diagnostics[0].code)
        # verify=False bypasses the static gate (the runtime still raises)
        loose = Session(verify=False)
        try:
            loose.submit_graph(bad)
        except GraphError as e:
            assert not isinstance(e, VerificationError)
            print("legacy ok")
        """)
    assert "gate OFL001" in out
    assert "legacy ok" in out


def test_verified_graph_runs_bit_identical(subproc):
    out = subproc("""
        import numpy as np
        from repro.api import Session, GraphNode, Ref
        from repro.core import jobs

        job = jobs.make_axpy(2048)
        ops, _ = job.make_instance(0)
        import jax.numpy as jnp
        ops = {k: np.asarray(v, dtype=jnp.zeros(()).dtype)
               for k, v in ops.items()}

        def chain(sess):
            nodes = [GraphNode(job, ops, name="n0")]
            for k in range(1, 6):
                nodes.append(GraphNode(
                    job, {"x": ops["x"], "y": Ref(f"n{k-1}")},
                    name=f"n{k}"))
            return np.asarray(sess.submit_graph(nodes).wait()["n5"])

        a = chain(Session(verify=True))
        b = chain(Session(verify=False))
        print("identical", np.array_equal(a, b))
        """, x64=False)
    assert "identical True" in out


def test_submit_gate_promotes_use_after_donate(subproc):
    """OFL003 fires on *submit* — before staging — not at wait()."""
    out = subproc("""
        import jax
        import numpy as np
        from repro.api import DonatedOperandError, Session
        from repro.core import jobs

        job = jobs.make_axpy(2048)
        ops, _ = job.make_instance(0)
        x = jax.device_put(np.asarray(ops["x"]))
        x.delete()                 # a donating consumer ate the buffer
        sess = Session()
        try:
            sess.submit(job, {"x": x, "y": ops["y"]})
            print("no error")
        except DonatedOperandError as e:
            assert e.code == "OFL003"
            assert e.diagnostic.code == "OFL003"
            # nothing was staged: the gate fired before phase E
            print("pre-dispatch", sess.stats.device_puts == 0)
        """)
    assert "pre-dispatch True" in out


# ---------------------------------------------------------------------------
# sanitizer: one trip test per hazard class + a clean run
# ---------------------------------------------------------------------------


@pytest.fixture
def san():
    s = sanitizer.enable()
    yield s
    sanitizer.disable()


def test_sanitizer_read_after_donate(san):
    buf = object()
    san.track(buf, "staged operand 'x'")
    san.read(buf, "forward")               # live: fine
    san.donate(buf, "operand 'x'")
    with pytest.raises(SanitizerError, match="read-after-donated"):
        san.read(buf, "forward of operand 'x'")
    assert san.violations == 1


def test_sanitizer_read_after_revoke(san):
    buf = object()
    san.track(buf, "resident operand 'y'")
    san.revoke(buf, "resident operand 'y'")
    with pytest.raises(SanitizerError, match="read-after-revoked"):
        san.read(buf, "resident redispatch")
    san.revive(buf, "restaged operand 'y'")
    san.read(buf, "resident redispatch")   # restaged: fine again


def test_sanitizer_issue_order_and_retire(san):
    sb = Scoreboard([[], [0], [1]])
    sb.issue(0)
    sb.issue(1)
    sb.retire(0)
    sb.issue(2)
    sb.retire(2)
    sb.retire(1)
    assert san.violations == 0
    # a scoreboard bypassing readiness would trip the vector clocks
    with pytest.raises(SanitizerError, match="issue order"):
        san.sb_issue(999, 5, (4,))         # producer 4 never issued


def test_sanitizer_issue_clocks_dominate(san):
    sb = Scoreboard([[], [], [0, 1]])
    sb.issue(1)
    sb.issue(0)
    sb.issue(2)
    clocks = san._sb[id(sb)][1]
    assert clocks[2].dominates(clocks[0])
    assert clocks[2].dominates(clocks[1])
    assert not clocks[0].dominates(clocks[1])


def test_sanitizer_scoreboard_id_reuse_starts_fresh(san):
    # CPython recycles a dead scoreboard's address immediately; the
    # fresh scoreboard at that id must not inherit 'retired' state
    # (regression: simulate_graph allocates one Scoreboard per call)
    for _ in range(50):
        sb = Scoreboard([[], [0]])
        sb.issue(0)
        sb.issue(1)
        sb.retire(0)
        sb.retire(1)
        del sb
    assert san.violations == 0


def test_sanitizer_completion_protocol(san):
    from repro.core.completion import CompletionUnit
    u = CompletionUnit(n_units=2)
    u.program(2, job_id=0)
    u.arrive(0, 2)
    u.collect(0)
    with pytest.raises(SanitizerError, match="collected twice"):
        u.collect(0)
    with pytest.raises(SanitizerError, match="never programmed"):
        u.collect(41)
    u.program(2, job_id=5)
    u.cancel(5)
    with pytest.raises(SanitizerError, match="never programmed"):
        u.collect(5)                       # cancel withdrew it


def test_sanitizer_lease_overlap(san):
    san.lease_grant(1, (0, 1, 2), {})
    san.lease_grant(1, (0, 1), {0: 1, 1: 1, 2: 1})     # resize: same id ok
    with pytest.raises(SanitizerError, match="lease-window overlap"):
        san.lease_grant(2, (1, 5), {0: 1, 1: 1})
    from repro.core.fabric import FabricScheduler
    sched = FabricScheduler(num_clusters=8)
    a = sched.request("t1", n=4)
    b = sched.request("t2", n=4)
    sched.release(a)
    sched.release(b)
    assert san.violations == 1             # real grants never overlap


def test_sanitizer_clean_run_records_events(subproc):
    """A graph dispatch under REPRO_SANITIZE=1: events > 0, violations == 0
    (the CI job runs the whole tier-1 suite this way)."""
    out = subproc("""
        import os
        os.environ["REPRO_SANITIZE"] = "1"
        import numpy as np
        from repro.api import Session, GraphNode, Ref
        from repro.analysis import sanitizer
        from repro.core import jobs

        job = jobs.make_axpy(2048)
        ops, _ = job.make_instance(0)
        sess = Session()
        nodes = [GraphNode(job, ops, name="n0"),
                 GraphNode(job, {"x": ops["x"], "y": Ref("n0")}, name="n1")]
        sess.submit_graph(nodes).wait()
        rep = sanitizer.active().report()
        print("events>0", rep["events"] > 0,
              "violations", rep["violations"])
        """)
    assert "events>0 True violations 0" in out


def test_sanitizer_off_by_default():
    assert sanitizer.active() is None or True  # resolved from env once
    # the hooks must be no-ops without REPRO_SANITIZE: a donated read in
    # plain mode raises the runtime's DonatedOperandError, not ours
    sanitizer.disable()
    s = sanitizer.active()
    assert s is None
