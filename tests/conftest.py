"""Shared test helpers.

Device-count hygiene (DESIGN.md §7): this process sees the default single
CPU device.  Tests that need a multi-device mesh or float64 offload jobs run
in subprocesses via :func:`run_subprocess` with their own XLA_FLAGS — the
dry-run's 512-device flag is never set here.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, x64: bool = True,
                   timeout: int = 600) -> str:
    """Run python code in a child with its own device count; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if x64:
        env["JAX_ENABLE_X64"] = "true"
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
