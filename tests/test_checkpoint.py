"""Checkpoint + fault-tolerant restart + elastic rescale (8-device mesh)."""


def test_bitwise_resume_and_elastic(subproc, tmp_path):
    subproc(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.train import TrainConfig, build_train_step
from repro.optim.adamw import adamw_init
from repro.data import DataConfig, SyntheticStream
from repro.dist.sharding import to_shardings
from repro.checkpoint import save, restore, latest_step
from repro.ft.elastic import elastic_restore

cfg = M.reduced(M.get("smollm-360m"))
devs = jax.devices()
mesh = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
dc = DataConfig(vocab_size=cfg.vocab_size, batch_size=8, seq_len=32, seed=7)
stream = SyntheticStream(dc, cfg)
bs = {{k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in stream.batch(0).items()}}
tcfg = TrainConfig(total_steps=20, warmup_steps=2, base_lr=1e-3, microbatches=2)
step_fn, pspecs, ospecs, bspecs = build_train_step(cfg, mesh, tcfg, bs)
params = jax.device_put(M.init_params(jax.random.key(0), cfg), to_shardings(pspecs, mesh))
opt = jax.device_put(adamw_init(params), to_shardings(ospecs, mesh))

for i in range(4):
    b = jax.device_put(stream.batch(i), to_shardings(bspecs, mesh))
    params, opt, m = step_fn(params, opt, b, jnp.asarray(i))

d = r"{tmp_path}"
save(d, 4, {{"params": params, "opt": opt}}, {{"params": pspecs, "opt": ospecs}}, data_index=4)
assert latest_step(d) == 4

# continue 2 steps -> reference loss
for i in range(4, 6):
    b = jax.device_put(stream.batch(i), to_shardings(bspecs, mesh))
    params, opt, m = step_fn(params, opt, b, jnp.asarray(i))
ref = float(m["loss"])

# simulated failure: restore and replay -> bitwise identical
st, di, state = restore(d, mesh, {{"params": pspecs, "opt": ospecs}})
assert (st, di) == (4, 4)
p2, o2 = state["params"], state["opt"]
for i in range(di, 6):
    b = jax.device_put(stream.batch(i), to_shardings(bspecs, mesh))
    p2, o2, m2 = step_fn(p2, o2, b, jnp.asarray(i))
assert float(m2["loss"]) == ref, (float(m2["loss"]), ref)

# elastic: resume the same run on only 2 surviving devices
ks = jax.eval_shape(lambda: jax.random.key(0))
pshapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                         jax.ShapeDtypeStruct(ks.shape, ks.dtype))
st, di, state, mesh2 = elastic_restore(d, devs[:2], pshapes)
step2 = build_train_step(cfg, mesh2, tcfg, bs)[0]
from repro.dist.sharding import batch_specs
b = jax.device_put(stream.batch(di), to_shardings(batch_specs(bs, mesh2), mesh2))
p3, o3, m3 = step2(state["params"], state["opt"], b, jnp.asarray(di))
assert np.isfinite(float(m3["loss"]))
print("OK")
""", devices=8, x64=False)


def test_retention_gc(tmp_path):
    import numpy as np
    from repro.checkpoint import latest_step, restore, save
    state = {"params": {"w": np.arange(4.0)}}
    for step in (1, 2, 3, 4, 5):
        save(str(tmp_path), step, state, keep=2, data_index=step)
    import os
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]
    st, di, got = restore(str(tmp_path))
    assert st == 5 and di == 5
    np.testing.assert_array_equal(got["params"]["w"], np.arange(4.0))
