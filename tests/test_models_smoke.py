"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward + train step on CPU with correct output
shapes and no NaNs; full configs are exercised only via the dry-run.

Also: prefill/decode/full-forward consistency per family, and divisibility
checks that every FULL config's sharded dimensions divide the production
mesh extents (what the sharding rules rely on).
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import models as M
from repro.data.pipeline import DataConfig, SyntheticStream, input_specs

ARCHS = sorted(M.ARCHS)
CALL_EVAL = M.CallConfig(moe_no_drop=True)


def _batch(cfg, B=2, S=16, seed=0, labels=True):
    rng = np.random.default_rng(seed)
    out = {"tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    if labels:
        out["labels"] = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    if cfg.frontend and cfg.frontend.kind == "vision_stub":
        out["patches"] = rng.standard_normal(
            (B, cfg.frontend.n_prefix_tokens, cfg.d_model)).astype(np.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = M.reduced(M.get(arch))
    params = M.init_params(jax.random.key(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = M.forward(params, cfg, batch)
    seq_total = S + (cfg.frontend.n_prefix_tokens
                     if cfg.frontend and cfg.frontend.kind == "vision_stub" else 0)
    assert logits.shape == (B, seq_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, metrics = M.loss_fn(params, cfg, batch)
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gn))
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_forward(arch):
    cfg = dataclasses.replace(M.reduced(M.get(arch)), compute_dtype="float32")
    params = M.init_params(jax.random.key(0), cfg)
    B, S, MAXLEN = 2, 16, 32
    batch = _batch(cfg, B, S, labels=False)
    logits_full, _ = M.forward(params, cfg, batch, CALL_EVAL)
    logits_pre, cache = M.prefill(params, cfg, batch, MAXLEN, CALL_EVAL)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]), np.asarray(logits_full[:, -1]),
        rtol=1e-4, atol=1e-4)

    nxt = np.random.default_rng(1).integers(0, cfg.vocab_size, (B, 1)).astype(np.int32)
    logits_dec, cache = M.decode_step(params, cfg, cache, jnp.asarray(nxt), CALL_EVAL)
    batch2 = dict(batch, tokens=np.concatenate([batch["tokens"], nxt], 1))
    logits_full2, _ = M.forward(params, cfg, batch2, CALL_EVAL)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full2[:, -1]),
        rtol=1e-3, atol=1e-3)
    prefix = (cfg.frontend.n_prefix_tokens
              if cfg.frontend and cfg.frontend.kind == "vision_stub" else 0)
    assert int(cache["pos"]) == S + prefix + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_attention_impl_equivalence(arch):
    """xla vs chunked (vs pallas-interpret for GQA archs) agree."""
    cfg = dataclasses.replace(M.reduced(M.get(arch)), compute_dtype="float32")
    if cfg.family == "ssm":
        pytest.skip("attention-free")
    params = M.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, 2, 24, labels=False)
    lx, _ = M.forward(params, cfg, batch, M.CallConfig(attn_impl="xla", moe_no_drop=True))
    lc, _ = M.forward(params, cfg, batch,
                      M.CallConfig(attn_impl="chunked", attn_chunk=8, moe_no_drop=True))
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lc), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_divisibility(arch):
    """Every TP-sharded flattened dim of the FULL config divides 16 (the
    production model-axis extent) — what DESIGN.md §7 claims."""
    cfg = M.get(arch)
    tp = 16
    assert cfg.d_model % tp == 0 or cfg.d_model < tp, arch
    if cfg.n_heads:
        assert (cfg.n_heads * cfg.head_dim) % tp == 0
        assert (cfg.n_kv_heads * cfg.head_dim) % tp == 0
    if cfg.d_ff:
        assert cfg.d_ff % tp == 0
    assert cfg.vocab_size % tp == 0
    if cfg.moe:
        assert cfg.moe.n_experts % tp == 0, "EP over the model axis"
    if cfg.ssm:
        assert cfg.d_inner % tp == 0
    if cfg.mla:
        assert (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) % tp == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_model_inputs(arch):
    """input_specs() provides a stand-in for every input forward() needs."""
    cfg = M.get(arch)
    for mode in ("train", "prefill", "decode"):
        specs = input_specs(cfg, mode=mode, batch=4, seq=64)
        assert "tokens" in specs
        if mode == "train":
            assert "labels" in specs
        if cfg.frontend and cfg.frontend.kind == "vision_stub" and mode != "decode":
            assert "patches" in specs


def test_param_counts_match_names():
    """Analytic parameter counts land on the checkpoint names."""
    expect = {
        "phi3-medium-14b": (13.0e9, 15.5e9),
        "qwen1.5-110b": (105e9, 115e9),
        "smollm-360m": (0.3e9, 0.4e9),
        "yi-9b": (8.0e9, 9.5e9),
        "llama4-scout-17b-a16e": (100e9, 115e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "paligemma-3b": (2.2e9, 3.0e9),
        "zamba2-2.7b": (2.1e9, 3.0e9),
        "musicgen-large": (2.8e9, 3.6e9),
        "falcon-mamba-7b": (6.5e9, 7.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = M.count_params(M.get(arch))
        assert lo < n < hi, (arch, n)
    # MoE active counts
    assert M.count_params(M.get("llama4-scout-17b-a16e"), True) < 20e9
    assert M.count_params(M.get("deepseek-v2-lite-16b"), True) < 3.5e9


def test_data_pipeline_determinism_and_structure():
    cfg = M.get("smollm-360m")
    dc = DataConfig(vocab_size=cfg.vocab_size, batch_size=4, seq_len=256, seed=3)
    s1, s2 = SyntheticStream(dc, cfg), SyntheticStream(dc, cfg)
    b1, b2 = s1.batch(17), s2.batch(17)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # repeat structure exists (~repeat_prob of positions)
    rep = (b1["tokens"][:, 1:] == b1["tokens"][:, :-1]).mean()
    assert 0.15 < rep < 0.45, rep
