"""The model-driven performance linter (ISSUE-10).

Coverage layers:

* **per-code fixtures** — every ``OFLP1##`` code has a static fixture
  that triggers it, and every fixture also passes the *correctness*
  verifier clean (a perf finding on an invalid submission would be
  advice about a graph that can never run).
* **autofix** — ``perflint.apply`` patches policies / nodes /
  selections; property test that autofixing a random defect-free DAG
  keeps it verify-clean; a subprocess executes autofixed graphs
  bit-identically to the originals on a real mesh.
* **session integration** — ``submit(lint=True)`` findings on the
  handle and in ``explain()``, the ``DiagnosticsLog`` ring buffer
  behind ``Session(diag_limit=)`` (memory-flat under a 10k-record
  loop and through the real submit path), ``lint_session``'s dead-
  residency pass.
* **CLI** — ``python -m repro.lint`` over a tmp corpus: exit codes,
  JSON/SARIF shape, ``--update-baseline`` round trip, ``# repro:
  allow(...)`` suppressions, ``--codes-md`` and the README drift gate.
"""

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import lint as lint_cli
from repro.analysis import CODES, Severity, perflint, verify, verify_graph
from repro.analysis.diagnostics import DiagnosticsLog
from repro.core import jobs, simulator
from repro.core.policy import AUTO, Staging
from repro.core.scoreboard import GraphNode, Ref

REPO = Path(__file__).resolve().parent.parent

_JOB = jobs.make_axpy(2048)
_OPS = {k: np.asarray(v) for k, v in _JOB.make_instance(0)[0].items()}


def codes_of(findings):
    return sorted({f.code for f in findings})


def _serial_reshard():
    return [
        GraphNode(_JOB, _OPS, name="wide"),
        GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("wide")}, name="narrow",
                  clusters=[0, 1, 2, 3]),
        GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("narrow")}, name="tail"),
    ]


# ---------------------------------------------------------------------------
# per-code fixtures (each also verifier-clean)
# ---------------------------------------------------------------------------


def test_oflp101_suboptimal_staging():
    job = jobs.make_atax(64, 4096)
    ops, _ = job.make_instance(0)
    pol = AUTO.pinned(staging=Staging.HOST_FANOUT)
    assert verify(job, policy=pol, operands=ops, n=8) == []
    fs = perflint.lint(job, ops, policy=pol, clusters=list(range(8)))
    assert "OFLP101" in codes_of(fs)
    f = next(f for f in fs if f.code == "OFLP101")
    assert f.delta > 0
    assert f.fix.target == "policy" and f.fix.field == "staging"
    fixed = perflint.suggested_policy(fs, pol)
    assert fixed.staging in (Staging.TREE, Staging.TREE_RESHARD)
    assert pol.diff(fixed) == {"staging": (pol.staging, fixed.staging)}


def test_oflp102_missed_fusion():
    pol = AUTO.pinned(fuse=1)
    assert verify(_JOB, policy=pol, operands=_OPS, n=8) == []
    fs = perflint.lint(jobs.make_axpy(256), policy=pol, batch=16, n=8)
    assert "OFLP102" in codes_of(fs)
    f = next(f for f in fs if f.code == "OFLP102")
    assert f.fix.field == "fuse" and f.fix.value > 1
    # unpinned fuse: the planner already decides, nothing to report
    fs = perflint.lint(jobs.make_axpy(256), policy=AUTO.pinned(
        donate_operands=True), batch=16, n=8)
    assert "OFLP102" not in codes_of(fs)


def test_oflp103_window_below_optimal():
    pol = AUTO.pinned(window=1)
    assert verify(_JOB, policy=pol, operands=_OPS, n=8) == []
    fs = perflint.lint(jobs.make_axpy(256), policy=pol, batch=16, n=8)
    # the same fixture legitimately also trips OFLP107 (donation off on
    # a fused batch) — assert membership, not the exact set
    assert "OFLP103" in codes_of(fs)
    f = next(f for f in fs if f.code == "OFLP103")
    assert f.fix.field == "window" and f.fix.value > 1


def test_oflp104_reshard_on_critical_path():
    nodes = _serial_reshard()
    assert verify_graph(nodes, default_width=8) == []
    fs = perflint.lint_graph(nodes, default_width=8)
    assert codes_of(fs) == ["OFLP104"]
    for f in fs:
        assert f.fix.target == "node"
        assert f.delta > 0
    # applying to a fixpoint converges to a lint-clean graph
    cur = nodes
    for _ in range(8):
        fs = perflint.lint_graph(cur, default_width=8)
        if not fs:
            break
        cur = perflint.apply(fs, nodes=cur).nodes
    assert perflint.lint_graph(cur, default_width=8) == []
    assert verify_graph(cur, default_width=8) == []
    # and the fix is a real cycle win in the discrete-event domain
    before, _ = perflint.graph_jobs(nodes, default_width=8)
    after, _ = perflint.graph_jobs(cur, default_width=8)
    assert (simulator.simulate_graph(after).makespan
            < simulator.simulate_graph(before).makespan)


def test_oflp105_misaligned_selection():
    mis = list(range(1, 9))
    assert verify(_JOB, operands=_OPS, clusters=mis) == []
    assert simulator.selection_requests(mis) > 1
    fs = perflint.lint(_JOB, _OPS, clusters=mis)
    assert "OFLP105" in codes_of(fs)
    fixed = perflint.apply(fs, clusters=mis).clusters
    assert simulator.selection_requests(fixed) == 1
    assert len(fixed) >= 2
    # an aligned pow2 window is already single-request: quiet
    assert "OFLP105" not in codes_of(
        perflint.lint(_JOB, _OPS, clusters=list(range(8))))


def test_oflp107_donation_off_on_dead_buffer():
    fs = perflint.lint(jobs.make_axpy(256), batch=16, n=8)
    assert "OFLP107" in codes_of(fs)
    f = next(f for f in fs if f.code == "OFLP107")
    assert f.fix.field == "donate_operands" and f.fix.value is True
    # donation already on, or an unfused dispatch: quiet
    fs = perflint.lint(jobs.make_axpy(256),
                       policy=AUTO.pinned(donate_operands=True),
                       batch=16, n=8)
    assert "OFLP107" not in codes_of(fs)
    fs = perflint.lint(jobs.make_axpy(256), batch=1, n=8)
    assert "OFLP107" not in codes_of(fs)


def test_clean_auto_submit_has_no_findings():
    assert perflint.lint(_JOB, _OPS, n=8) == []
    # and a clean graph stays clean
    nodes = [GraphNode(_JOB, _OPS, name="a"),
             GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("a")}, name="b")]
    assert perflint.lint_graph(nodes, default_width=8) == []


def test_invalid_submission_returns_no_perf_findings():
    # perf advice about a submission the verifier rejects is noise
    bad = [GraphNode(_JOB, {"x": _OPS["x"], "y": Ref("zz")}, name="a")]
    assert verify_graph(bad, default_width=8) != []
    assert perflint.lint_graph(bad, default_width=8) == []


# ---------------------------------------------------------------------------
# findings + apply mechanics
# ---------------------------------------------------------------------------


def test_finding_payload_round_trip_and_stable_key():
    fs = perflint.lint_graph(_serial_reshard(), default_width=8)
    f = fs[0]
    restored = perflint.PerfFinding.from_payload(f.to_payload())
    assert restored == f
    # keys are stable across model recalibration: no cycle counts
    assert f.key().startswith("OFLP104:")
    assert not re.search(r"\d{3,}", f.key().split(":", 1)[1])


def test_apply_routes_fixes_and_reports_skips():
    nodes = _serial_reshard()
    fs = perflint.lint_graph(nodes, default_width=8)
    applied = perflint.apply(fs, nodes=nodes)
    assert applied.applied and not applied.skipped
    assert applied.nodes is not nodes
    assert nodes[1].clusters == [0, 1, 2, 3]      # input untouched
    # a fix with no matching artifact lands in skipped, loudly
    applied = perflint.apply(fs, policy=AUTO)
    assert not applied.applied and len(applied.skipped) == len(fs)


def test_significance_threshold_suppresses_noise():
    # a single-cluster dispatch has nothing to restage or realign
    assert perflint.lint(_JOB, _OPS, clusters=[0]) == []
    # the gate itself: sub-2% "wins" are inside the model's error bar
    assert not perflint._significant(1000.0, 985.0)
    assert not perflint._significant(10.0, 9.5)   # abs floor of 1 cycle
    assert perflint._significant(1000.0, 900.0)
    assert perflint.MIN_DELTA_FRAC == 0.02


# ---------------------------------------------------------------------------
# property: autofix preserves verifier-cleanliness on random DAGs
# ---------------------------------------------------------------------------

from _hypothesis_compat import given, settings, st  # noqa: E402


def _random_dag(rng, n_nodes):
    widths = ([0, 1, 2, 3], [4, 5, 6, 7], [2, 3, 4, 5], None)
    nodes = []
    for i in range(n_nodes):
        ops = {"x": _OPS["x"], "y": _OPS["y"]}
        if i and rng.random() < 0.7:
            ops["y"] = Ref(int(rng.integers(0, i)))
        nodes.append(GraphNode(_JOB, ops, name=f"n{i}",
                               clusters=widths[int(rng.integers(0, 4))]))
    return nodes


@given(st.integers(0, 2**32 - 1), st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_autofixed_random_dags_stay_verify_clean(seed, n_nodes):
    rng = np.random.default_rng(seed)
    nodes = _random_dag(rng, n_nodes)
    if [d for d in verify_graph(nodes, default_width=8)
            if d.severity is Severity.ERROR]:
        return                                    # not a valid fixture
    fs = perflint.lint_graph(nodes, default_width=8)
    fixed = perflint.apply(fs, nodes=nodes).nodes
    assert [d for d in verify_graph(fixed, default_width=8)
            if d.severity is Severity.ERROR] == []


# ---------------------------------------------------------------------------
# DiagnosticsLog ring buffer
# ---------------------------------------------------------------------------


def test_diaglog_10k_records_memory_flat():
    from repro.analysis import Diagnostic

    log = DiagnosticsLog(limit=256)
    d = Diagnostic("OFLP103", "synthetic", severity=Severity.PERF)
    for _ in range(10_000):
        log.record([d])
    assert len(log) == 256                        # ring bound holds
    assert log.total == 10_000
    assert log.dropped == 9_744
    assert log.counts() == {"OFLP103": 256}
    log.clear()
    assert len(log) == 0 and log.total == 0 and log.dropped == 0
    # limit=0: count-only mode, nothing retained
    log0 = DiagnosticsLog(limit=0)
    log0.record([d, d])
    assert len(log0) == 0 and log0.total == 2 and log0.dropped == 2


def test_session_diag_limit_through_submit_path(subproc):
    out = subproc("""
        from repro.api import AUTO, Session
        from repro.core import jobs

        job = jobs.make_axpy(2048)
        ops, _ = job.make_instance(0)
        sess = Session(diag_limit=16)
        # distinct pinned policies -> distinct lint cache keys -> a
        # recording per submit; a batch-1 fuse pin is clamped to 1 so
        # execution is identical while window=1 keeps OFLP103 firing
        for f in range(1, 41):
            pol = AUTO.pinned(window=1, fuse=f)
            sess.submit(job, ops, policy=pol, lint=True).wait()
        total = sess.diagnostics.total
        assert len(sess.diagnostics) == 16, len(sess.diagnostics)
        assert total > 16
        assert sess.diagnostics.dropped == total - 16
        before = total
        sess.submit(job, ops, policy=AUTO.pinned(window=1, fuse=1),
                    lint=True).wait()
        assert sess.diagnostics.total == before   # cache hit: flat
        print("ring ok", total)
        """)
    assert "ring ok" in out


# ---------------------------------------------------------------------------
# session integration (real mesh)
# ---------------------------------------------------------------------------


def test_submit_lint_findings_and_explain(subproc):
    out = subproc("""
        from repro.api import AUTO, Session
        from repro.core import jobs

        job = jobs.make_axpy(256)
        inst, _ = jobs.make_instances(job, 16)
        sess = Session()
        h = sess.submit(job, inst, policy=AUTO.pinned(window=1), lint=True)
        h.wait()
        codes = sorted({f.code for f in h.findings})
        assert "OFLP103" in codes, codes
        table = h.explain().table()
        assert "perf findings" in table
        assert "OFLP103" in table
        # lint off (the default): no findings recorded on the handle
        h2 = sess.submit(job, inst, policy=AUTO.pinned(window=1))
        h2.wait()
        assert h2.findings == []
        print("explain ok", codes)
        """)
    assert "explain ok" in out


def test_lint_session_dead_residency(subproc):
    out = subproc("""
        from repro.analysis import perflint
        from repro.api import Residency, Session
        from repro.core import jobs

        job = jobs.make_axpy(2048)
        ops, _ = job.make_instance(0)
        sess = Session()
        sess.stage(job, ops, n=8)
        fs = perflint.lint_session(sess)
        assert [f.code for f in fs] == ["OFLP106"], fs
        assert fs[0].fix.target == "stage"
        sess.submit(job, Residency.RESIDENT, n=8).wait()
        assert perflint.lint_session(sess) == []   # redispatched: alive
        print("residency ok")
        """)
    assert "residency ok" in out


def test_autofixed_graphs_execute_bit_identical(subproc):
    out = subproc("""
        import numpy as np
        from repro.analysis import perflint
        from repro.api import GraphNode, Ref, Session
        from repro.core import jobs

        job = jobs.make_axpy(2048)
        base_ops, _ = job.make_instance(0)
        base_ops = {k: np.asarray(v) for k, v in base_ops.items()}
        widths = ([0, 1, 2, 3], [4, 5, 6, 7], [2, 3, 4, 5], None)
        sess = Session()
        checked = 0
        for seed in range(5):
            rng = np.random.default_rng(seed)
            nodes = []
            for i in range(int(rng.integers(2, 6))):
                ops = dict(base_ops)
                if i and rng.random() < 0.7:
                    ops["y"] = Ref(int(rng.integers(0, i)))
                nodes.append(GraphNode(job, ops, name=f"n{i}",
                                       clusters=widths[int(
                                           rng.integers(0, 4))]))
            fs = perflint.lint_graph(nodes,
                                     default_width=len(sess.devices))
            fixed = perflint.apply(fs, nodes=nodes).nodes
            out_a = sess.submit_graph(nodes).wait()
            out_b = sess.submit_graph(fixed).wait()
            for k in out_a:
                a, b = np.asarray(out_a[k]), np.asarray(out_b[k])
                assert a.tobytes() == b.tobytes(), (seed, k)
            checked += len(fs)
        assert checked > 0, "no finding ever fired; fixture too tame"
        print("bit-identical ok", checked)
        """)
    assert "bit-identical ok" in out


# ---------------------------------------------------------------------------
# the CLI
# ---------------------------------------------------------------------------

_TMP_GRAPH = '''
import numpy as np
from repro.core import jobs
from repro.core.scoreboard import GraphNode, Ref

{allow}
def build():
    job = jobs.make_axpy(2048)
    ops, _ = job.make_instance(0)
    ops = {{k: np.asarray(v) for k, v in ops.items()}}
    return {{"serial": [
        GraphNode(job, ops, name="wide"),
        GraphNode(job, {{"x": ops["x"], "y": Ref("wide")}}, name="narrow",
                  clusters=[0, 1, 2, 3]),
        GraphNode(job, {{"x": ops["x"], "y": Ref("narrow")}}, name="tail"),
    ]}}
'''


def _write_corpus(tmp_path, allow=""):
    g = tmp_path / "g.py"
    g.write_text(_TMP_GRAPH.format(allow=allow))
    return g


def test_cli_gate_baseline_round_trip(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(tmp_path)
    _write_corpus(tmp_path)
    argv = ["--graphs", "g.py:build", "--baseline", "bl.json"]
    assert lint_cli.main(argv) == 1               # new findings fail
    assert "[NEW] OFLP104" in capsys.readouterr().out
    assert lint_cli.main(argv + ["--update-baseline"]) == 0
    bl = json.loads((tmp_path / "bl.json").read_text())
    assert sum(bl["findings"].values()) == 2
    assert lint_cli.main(argv) == 0               # baselined now
    assert "[baseline] OFLP104" in capsys.readouterr().out


def test_cli_allow_comment_suppresses(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(tmp_path)
    _write_corpus(tmp_path, allow="# repro: allow(OFLP104, OFLP105)\n")
    assert lint_cli.main(["--graphs", "g.py:build",
                          "--baseline", "bl.json"]) == 0
    out = capsys.readouterr().out
    assert "[allowed] OFLP104" in out
    assert "2 allowed" in out


def test_cli_json_and_sarif_shape(tmp_path, monkeypatch, capsys):
    monkeypatch.chdir(tmp_path)
    _write_corpus(tmp_path)
    lint_cli.main(["--graphs", "g.py:build", "--baseline", "bl.json",
                   "--json", "out.json", "--sarif", "out.sarif"])
    capsys.readouterr()
    j = json.loads((tmp_path / "out.json").read_text())
    assert j["schema"] == 1
    (findings,) = [f for g, f in j["graphs"].items() if g == "g:serial"]
    assert {f["diagnostic"]["code"] for f in findings} == {"OFLP104"}
    s = json.loads((tmp_path / "out.sarif").read_text())
    assert s["version"] == "2.1.0"
    run = s["runs"][0]
    assert {r["id"] for r in run["tool"]["driver"]["rules"]} == set(CODES)
    assert all(r["level"] == "note" for r in run["results"])
    assert all(r["ruleId"] == "OFLP104" for r in run["results"])
    assert run["results"][0]["properties"]["fix"]["field"] == "clusters"


def test_cli_missing_corpus_skips(tmp_path, monkeypatch, capsys):
    monkeypatch.chdir(tmp_path)
    assert lint_cli.main(["--graphs", "nope.py:build",
                          "--baseline", "bl.json"]) == 0
    assert "0 graphs" in capsys.readouterr().out


def test_checked_in_corpus_is_gate_clean(capsys, monkeypatch):
    monkeypatch.chdir(REPO)
    assert lint_cli.main([]) == 0
    out = capsys.readouterr().out
    assert "0 new" in out
    # both accepted-debt mechanisms are exercised by the real corpus
    assert "[allowed] OFLP104" in out
    assert "[baseline] OFLP104" in out


# ---------------------------------------------------------------------------
# generated docs + tooling wiring
# ---------------------------------------------------------------------------


def test_codes_markdown_matches_registry(capsys):
    assert lint_cli.main(["--codes-md"]) == 0
    out = capsys.readouterr().out
    for code, info in CODES.items():
        assert f"`{code}`" in out
        assert info.title in out


def test_readme_code_table_not_drifted():
    readme = (REPO / "README.md").read_text()
    m = re.search(r"<!-- diagnostic-codes:begin -->\n(.*?)\n"
                  r"<!-- diagnostic-codes:end -->", readme, re.S)
    assert m, "README lost its generated diagnostic-codes block"
    assert m.group(1).strip() == lint_cli.codes_markdown().strip(), (
        "README diagnostic table drifted from the registry; regenerate "
        "with `python -m repro.lint --codes-md`")


def test_bench_registry_lists_perflint():
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--list"],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert out.returncode == 0, out.stderr
    row = [ln for ln in out.stdout.splitlines()
           if ln.startswith("perflint")]
    assert row and "bench-smoke" in row[0], out.stdout
