"""Typed policy surface: enums, OffloadPolicy/OffloadConfig validation,
and the legacy-string deprecation shims (ISSUE-4 satellites)."""

import warnings

import pytest

from repro.core import broadcast as bc
from repro.core.policy import (
    AUTO, Completion, InfoDist, OffloadPolicy, Residency, Staging,
)


def _deprecations(records):
    return [w for w in records if issubclass(w.category, DeprecationWarning)]


def test_enum_values_match_legacy_strings():
    """str-mixin enums ARE their legacy spellings: equality, hashing and
    membership against the canonical string tuples keep working."""
    assert tuple(m.value for m in Staging) == bc.STAGING_MODES
    assert Staging.TREE == "tree" and "tree" == Staging.TREE
    assert Staging.TREE in bc.STAGING_MODES
    assert Staging.TREE in bc.TREE_MODES
    assert hash(Staging.HOST_FANOUT) == hash("host_fanout")
    assert InfoDist.MULTICAST == "multicast"
    assert InfoDist.P2P_CHAIN == "p2p_chain"
    assert Completion.UNIT == "unit"
    assert Completion.CENTRAL_COUNTER == "central_counter"
    assert Residency.RESIDENT == "resident"


def test_offload_policy_validation_and_auto():
    # the new surface accepts strings (coerced silently) and enums alike
    p = OffloadPolicy(staging="tree", info_dist="p2p_chain",
                      completion=Completion.CENTRAL_COUNTER)
    assert p.staging is Staging.TREE
    assert p.info_dist is InfoDist.P2P_CHAIN
    for bad in (dict(fuse=0), dict(window=0), dict(depth=0),
                dict(fuse=-2), dict(window="wide")):
        with pytest.raises(ValueError):
            OffloadPolicy(**bad)
    with pytest.raises(ValueError):
        OffloadPolicy(staging="mulitcast")
    with pytest.raises(ValueError):
        OffloadPolicy(residency="sticky")
    # AUTO leaves every decidable field to the planner
    assert AUTO.staging is None and AUTO.fuse is None and AUTO.window is None
    assert not AUTO.decided
    pinned = AUTO.pinned(staging=Staging.TREE, fuse=2, window=1)
    assert pinned.decided and pinned is not AUTO
    # policies hash (estimate-cache keys, dict keys)
    assert hash(pinned) == hash(AUTO.pinned(staging="tree", fuse=2, window=1))


def test_offload_config_validates_every_field():
    """Satellite: info_dist and completion are validated, not just
    staging — a typo raises instead of silently misconfiguring."""
    from repro.core.offload import OffloadConfig

    with pytest.raises(ValueError, match="info_dist"):
        OffloadConfig(info_dist="mulicast")
    with pytest.raises(ValueError, match="completion"):
        OffloadConfig(completion="central-counter")
    with pytest.raises(ValueError, match="staging"):
        OffloadConfig(staging="treee")


def test_offload_config_string_shim_warns_enums_do_not():
    from repro.core.offload import OffloadConfig

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = OffloadConfig(staging="tree")
    assert _deprecations(w), "raw-string staging should deprecation-warn"
    assert cfg.staging is Staging.TREE          # ...but still configure

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        OffloadConfig(staging=Staging.TREE,
                      info_dist=InfoDist.P2P_CHAIN,
                      completion=Completion.CENTRAL_COUNTER)
        OffloadConfig.baseline()
        OffloadConfig.extended()
        OffloadConfig()
    assert not _deprecations(w), "typed construction must stay silent"


def test_offload_config_equality_across_spellings():
    """Coercion normalizes: a legacy-string config and its typed twin are
    the same plan/compile cache key."""
    from repro.core.offload import OffloadConfig

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = OffloadConfig(info_dist="p2p_chain",
                               completion="central_counter",
                               staging="tree")
    typed = OffloadConfig(info_dist=InfoDist.P2P_CHAIN,
                          completion=Completion.CENTRAL_COUNTER,
                          staging=Staging.TREE)
    assert legacy == typed and hash(legacy) == hash(typed)


def test_serve_config_staging_typed():
    from repro.serve import ServeConfig

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = ServeConfig(staging="tree")
    assert _deprecations(w)
    assert cfg.staging is Staging.TREE
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ServeConfig(staging=Staging.TREE_RESHARD)
        ServeConfig()
    assert not _deprecations(w)
    # host_fanout is an offload-runtime measurement device, not a serving
    # mode — still rejected under both spellings
    with pytest.raises(ValueError):
        ServeConfig(staging=Staging.HOST_FANOUT)
    with pytest.raises(ValueError), warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ServeConfig(staging="host_fanout")
    with pytest.raises(ValueError):
        ServeConfig(staging="ttree")
