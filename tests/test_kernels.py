"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [64, 100, 1024, 4096, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_axpy(n, dtype):
    x = jnp.asarray(RNG.standard_normal(n), dtype)
    y = jnp.asarray(RNG.standard_normal(n), dtype)
    got = ops.axpy(x, y, 2.5, impl="pallas")
    want = ref.axpy(x, y, 2.5)
    assert got.shape == want.shape and got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 384, 128),
                                 (100, 70, 36), (17, 300, 129), (512, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(mkn, dtype):
    m, k, n = mkn
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    got = ops.matmul(a, b, impl="pallas")
    want = ref.matmul(a, b)
    assert got.shape == (m, n)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3,
        atol=5e-1 if dtype == jnp.bfloat16 else 1e-2)


@pytest.mark.parametrize("block_m", [64, 128, 256])
def test_matmul_block_sweep(block_m):
    a = jnp.asarray(RNG.standard_normal((192, 256)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((256, 320)), jnp.float32)
    got = ops.matmul(a, b, impl="pallas", block_m=block_m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)),
                               rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("mn", [(256, 128), (100, 64), (512, 256), (33, 100)])
def test_atax(mn):
    m, n = mn
    a = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    got = ops.atax(a, x, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.atax(a, x)),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("mn", [(32, 64), (128, 256), (100, 50), (8, 2)])
def test_covariance(mn):
    m, n = mn
    d = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    got = ops.covariance(d, impl="pallas")
    want = ref.covariance(d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got).T,
                               rtol=1e-5, atol=1e-5)   # symmetry


@pytest.mark.parametrize(
    "bhsd", [(1, 2, 128, 64), (2, 4, 256, 64), (1, 2, 100, 64),
             (1, 8, 128, 128), (1, 1, 384, 80)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(bhsd, causal):
    b, h, s, d = bhsd
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, h, s, d)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, impl="pallas")
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_gqa():
    """KV heads fewer than Q heads (the wrapper's GQA repeat)."""
    q = jnp.asarray(RNG.standard_normal((2, 8, 128, 64)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 128, 64)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 128, 64)), jnp.float32)
    got = ops.attention(q, k, v, causal=True, impl="pallas")
    want = ops.attention(q, k, v, causal=True, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_blocks_sweep():
    q = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    want = ref.attention(q, k, v, causal=True)
    for bq, bkv in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = ops.attention(q, k, v, causal=True, impl="pallas",
                            block_q=bq, block_kv=bkv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


@given(n=st.integers(1, 2048))
@settings(max_examples=20, deadline=None)
def test_axpy_any_length(n):
    """Property: arbitrary (non-aligned) lengths survive pad/unpad."""
    x = jnp.asarray(np.arange(n, dtype=np.float32))
    y = jnp.ones((n,), jnp.float32)
    got = ops.axpy(x, y, -1.0, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), 1.0 - np.arange(n), rtol=1e-6)


@pytest.mark.parametrize("shape", [(1, 64, 128, 16), (2, 100, 64, 16),
                                   (1, 33, 512, 8)])
@pytest.mark.parametrize("chunk", [16, 64])
def test_ssm_scan(shape, chunk):
    from repro.kernels.ssm_scan import ssm_scan
    B, S, D, N = shape
    # decays in (0, 1) keep the recurrence stable, like exp(dt·A) with A<0
    a = jnp.asarray(RNG.uniform(0.7, 0.999, shape), jnp.float32)
    b = jnp.asarray(RNG.standard_normal(shape) * 0.1, jnp.float32)
    c = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    got = ssm_scan(a, b, c, chunk=chunk, interpret=True)
    want = ref.ssm_scan(a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_matches_mamba_block_recurrence():
    """The kernel computes the same recurrence the Mamba-1 block uses."""
    from repro.kernels.ssm_scan import ssm_scan
    from repro.models.ssm import chunked_linear_recurrence
    B, S, D, N = 1, 48, 32, 8
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (B, S, D, N)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((B, S, D, N)) * 0.1, jnp.float32)
    c = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    h, _ = chunked_linear_recurrence(a, b, jnp.zeros((B, D, N)), 16)
    want = jnp.einsum("bsdn,bsn->bsd", h, c)
    got = ssm_scan(a, b, c, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
