"""The analytical runtime model (paper §5.6, eqs. 1–6 + our v2 extension)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import jobs, model
from repro.core.phases import Phase

NS = (1, 2, 4, 8, 16, 32)


def test_eq5_exact():
    """The structural model reduces to eq. 5 verbatim:
    t̂(n) = 400 + N/4 + 2.47·N/(8n)  (whenever chunks fill a port beat)."""
    for N in (256, 512, 1024, 4096, 16384):
        for n in NS:
            if N < 8 * n:
                continue
            got = model.predict_total(jobs.axpy_spec(N), n)
            want = model.axpy_closed_form(n, N)
            assert got == pytest.approx(want, abs=1e-6), (N, n)


def test_eq5_constant_decomposition():
    """400 = [A+B+C+D+H+I]_mc (161) + E/F/G constants (239)."""
    const = model.offload_constant(model.DEFAULT_PARAMS, arg_words=5)
    assert sum(const.values()) == pytest.approx(161.0)
    mb = model.predict(jobs.axpy_spec(1024), 1)
    assert mb.terms[Phase.B] == pytest.approx(47.0)


def test_eq6_functional_form():
    """Our structural ATAX model has exactly the eq.-6 term structure:
    C + a·N·M + b·N/n + N(1+M)/8 · n  (paper coefficients a=3.98, 2.9, 1/8)."""
    M = N = 256   # keeps every per-cluster transfer >= one 64 B port beat
    base = model.predict_total(jobs.atax_spec(M, N), 1)
    for n in (2, 4, 8, 16, 32):
        got = model.predict_total(jobs.atax_spec(M, N), n)
        # subtract the closed-form n-dependence; the remainder must be the
        # n-independent constant: C + 3.98·N·M
        linear = N * (1 + M) / 8.0 * (n - 1)          # E broadcast term delta
        par = (1.9 + 1.0) * (N / n - N) / 8.0          # F+G parallel delta
        assert got - base == pytest.approx(linear + par, rel=1e-6), n


def test_paper_closed_forms_match_ours_in_shape():
    """Against eq. 6 verbatim: identical slope terms, constant offset only
    (the paper's 566 bundles per-job host code we do not decompose)."""
    M = N = 512   # >= one port beat per cluster chunk at n=32
    for n in (2, 8, 32):
        ours = (model.predict_total(jobs.atax_spec(M, N), n)
                - model.predict_total(jobs.atax_spec(M, N), 1))
        paper = (model.atax_closed_form_paper(n, N, M)
                 - model.atax_closed_form_paper(1, N, M))
        assert ours == pytest.approx(paper, rel=1e-6)


def test_fig12_validation_under_15pct():
    """fig. 12: relative error consistently below 15 % (paper regime)."""
    cases = {
        "axpy": (jobs.axpy_spec, [(64,), (128,), (256,), (512,), (1024,)]),
        "atax": (jobs.atax_spec, [(32, 32), (64, 64), (128, 128), (512, 512)]),
        "matmul": (lambda s: jobs.matmul_spec(s, s, s), [(8,), (16,), (32,), (64,)]),
        "covariance": (lambda s: jobs.covariance_spec(s, 2 * s), [(16,), (32,), (64,)]),
        "montecarlo": (jobs.montecarlo_spec, [(4096,), (16384,), (65536,)]),
        "bfs": (jobs.bfs_spec, [(64,), (256,), (1024,)]),
    }
    for name, (mk, sizes) in cases.items():
        pts = model.validate(mk, sizes, NS)
        err = model.max_rel_error(pts)
        assert err < 0.15, (name, err)


def test_model_v2_beats_v1_at_saturation():
    """Beyond-paper: the port-drain bound keeps error <6 % even where the
    eq.-4 composition breaks (large N·n, §5.5 G coupling)."""
    sizes = [(1024,), (4096,), (16384,)]
    v1 = model.max_rel_error(model.validate(jobs.axpy_spec, sizes, NS))
    v2 = model.max_rel_error(
        model.validate(jobs.axpy_spec, sizes, NS,
                       predictor=model.predict_total_v2))
    assert v2 < 0.06
    assert v2 <= v1


def test_offload_decision():
    """§5.6: the model drives the how-many-clusters decision."""
    n_small, _ = model.optimal_clusters(lambda: jobs.axpy_spec(64))
    n_large, _ = model.optimal_clusters(lambda: jobs.axpy_spec(65536))
    # tiny jobs stop scaling once per-cluster chunks hit the port-beat floor
    assert n_small <= 8
    assert n_large == 32
    # binary decision: a long host runtime favours offload, a tiny one not
    yes, _, t = model.should_offload(jobs.axpy_spec(4096), host_cycles=1e9)
    no, _, _ = model.should_offload(jobs.axpy_spec(64), host_cycles=10.0)
    assert yes and not no


@given(N=st.integers(64, 65536), n=st.sampled_from(NS))
@settings(max_examples=100)
def test_model_positive_and_monotone_in_N(N, n):
    t = model.predict_total(jobs.axpy_spec(N), n)
    t2 = model.predict_total(jobs.axpy_spec(N + 64), n)
    assert t > 0 and t2 >= t


@given(n=st.sampled_from(NS))
@settings(max_examples=20)
def test_v2_never_below_composition_bound_parts(n):
    spec = jobs.axpy_spec(2048)
    assert model.predict_total_v2(spec, n) >= model.port_bound(spec, n) - 1e-9
    assert model.predict_total_v2(spec, n) >= model.predict_total(spec, n) - 1e-9
