"""Scoreboard / InflightWindow property tests (pure host-side, no jax).

Drives the out-of-order issue engine with synthetic random DAG
topologies: issue order must always be a topological order, the
protocol must reject every illegal transition with a typed
:class:`GraphError`, and the ``CompletionUnit`` must survive
out-of-order arrival interleaved with ``cancel()`` and deferred-IRQ
replay when driven through the scoreboard path (ISSUE-8 satellite).
"""

import collections
import random

import pytest

from repro.core.completion import CompletionUnit
from repro.core.scoreboard import (
    ISSUED,
    RETIRED,
    WAITING,
    GraphError,
    GraphNode,
    InflightWindow,
    Ref,
    Scoreboard,
    resolve_graph,
)


def _random_deps(rng, n, max_deps=3):
    """Random DAG as per-node predecessor lists (edges point backward)."""
    return [
        sorted(rng.sample(range(i), k=rng.randint(0, min(i, max_deps))))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# resolve_graph: names, refs, typed errors
# ---------------------------------------------------------------------------


def test_resolve_graph_names_refs_and_after():
    nodes = [
        GraphNode(job=None, operands={"x": 1.0, "y": 2.0}, name="a"),
        GraphNode(job=None, operands={"x": Ref("a"), "y": 3.0}, name="b"),
        GraphNode(job=None, operands={"x": Ref(0), "y": Ref("b")},
                  after=["a"]),
    ]
    deps, data_edges = resolve_graph(nodes)
    assert deps == [[], [0], [0, 1]]
    assert data_edges == [[], [(0, "x")], [(0, "x"), (1, "y")]]


def test_resolve_graph_duplicate_ref_keeps_both_edges():
    # One entry per dataflow edge: reading the same producer through two
    # operands is two edges (the self-scaling chain y <- a*y + y does this).
    nodes = [
        GraphNode(job=None, operands={"x": 1.0, "y": 2.0}),
        GraphNode(job=None, operands={"x": Ref(0), "y": Ref(0)}),
    ]
    deps, data_edges = resolve_graph(nodes)
    assert deps == [[], [0]]                      # dedup for ordering
    assert data_edges[1] == [(0, "x"), (0, "y")]  # both edges kept


@pytest.mark.parametrize("nodes, match", [
    ([], "empty graph"),
    ([GraphNode(job=None, operands={}, name="a"),
      GraphNode(job=None, operands={}, name="a")], "duplicate node name"),
    ([GraphNode(job=None, operands={"x": Ref("ghost")})], "unknown node name"),
    ([GraphNode(job=None, operands={"x": Ref(5)})], "outside"),
    ([GraphNode(job=None, operands={"x": Ref(0)})], "depends on itself"),
    ([GraphNode(job=None, operands={}, after=[0])], "depends on itself"),
])
def test_resolve_graph_errors(nodes, match):
    with pytest.raises(GraphError, match=match):
        resolve_graph(nodes)


def test_graph_error_is_value_error():
    assert issubclass(GraphError, ValueError)


# ---------------------------------------------------------------------------
# Scoreboard protocol
# ---------------------------------------------------------------------------


def test_cycle_detection():
    with pytest.raises(GraphError, match="cycle"):
        Scoreboard([[1], [0]])
    with pytest.raises(GraphError, match="cycle"):
        Scoreboard([[2], [0], [1]])


def test_out_of_range_and_self_dep():
    with pytest.raises(GraphError, match="out-of-range"):
        Scoreboard([[3]])
    with pytest.raises(GraphError, match="itself"):
        Scoreboard([[0]])


def test_issue_protocol_violations():
    sb = Scoreboard([[], [0]])
    with pytest.raises(GraphError, match="not ready"):
        sb.issue(1)                     # predecessor unissued
    with pytest.raises(GraphError, match="cannot retire"):
        sb.retire(0)                    # never issued
    sb.issue(0)
    with pytest.raises(GraphError, match="already issued"):
        sb.issue(0)                     # double issue
    sb.retire(0)
    with pytest.raises(GraphError, match="cannot retire"):
        sb.retire(0)                    # double retire
    with pytest.raises(GraphError, match="already retired"):
        sb.issue(0)


def test_dispatch_based_readiness_not_completion_based():
    # A consumer becomes issuable the moment its producer is ISSUED (async
    # dispatch chains device-side) — retirement is not required.
    sb = Scoreboard([[], [0]])
    assert sb.ready() == [0]
    sb.issue(0)
    assert sb.state[0] == ISSUED and sb.ready() == [1]
    sb.issue(1)                         # producer still in flight
    assert sb.inflight == 2 and sb.all_issued and not sb.all_retired
    sb.retire(1)                        # out-of-order retirement is legal
    sb.retire(0)
    assert sb.all_retired and sb.retire_order == [1, 0]


def test_pending_readers_rename_query():
    # diamond: 0 feeds 1 and 2; 3 joins.
    sb = Scoreboard([[], [0], [0], [1, 2]])
    sb.issue(0)
    assert sb.pending_readers(0) == 2   # both arms still unissued: rename
    sb.issue(1)
    assert sb.pending_readers(0) == 1   # arm 2 still reads node 0
    sb.issue(2)
    assert sb.pending_readers(0) == 0   # safe to donate in place now
    sb.issue(3)
    assert sb.sinks() == [3]


def test_random_dags_issue_order_is_topological():
    for seed in range(30):
        rng = random.Random(seed)
        deps = _random_deps(rng, rng.randint(1, 40))
        sb = Scoreboard(deps)
        window = rng.randint(1, 6)
        inflight = collections.deque()
        while not sb.all_retired:
            ready = sb.ready()
            if ready and len(inflight) < window and rng.random() < 0.7:
                i = rng.choice(ready)
                sb.issue(i)
                inflight.append(i)
            elif inflight:
                sb.retire(inflight.popleft())
        # issue order is a topological order of the DAG
        pos = {i: k for k, i in enumerate(sb.issue_order)}
        for i, d in enumerate(deps):
            for p in d:
                assert pos[p] < pos[i], (seed, p, i)
        assert sorted(sb.issue_order) == list(range(len(deps)))
        assert sorted(sb.retire_order) == list(range(len(deps)))
        assert sb.max_inflight <= window
        assert sb.inflight == 0
        # pending_readers fully drained
        assert all(sb.pending_readers(i) == 0 for i in range(len(deps)))


# ---------------------------------------------------------------------------
# InflightWindow
# ---------------------------------------------------------------------------


def test_inflight_window_drains_oldest_and_counts_stalls():
    win = InflightWindow(2)
    drained = []
    win.push("a"), win.push("b")
    win.make_room(drained.append)       # full: drains oldest
    assert drained == ["a"] and win.stalls == 1
    win.push("c")
    win.make_room(drained.append)
    assert drained == ["a", "b"] and win.stalls == 2
    assert win.drain_all(lambda h: h) == ["c"]
    assert len(win) == 0
    win.make_room(drained.append)       # room available: no stall
    assert win.stalls == 2


def test_inflight_window_rejects_zero_limit():
    with pytest.raises(ValueError, match="window limit"):
        InflightWindow(0)


# ---------------------------------------------------------------------------
# Satellite: CompletionUnit.collect under out-of-order arrival interleaved
# with cancel() and deferred-IRQ replay, driven through the scoreboard path.
# ---------------------------------------------------------------------------


def test_completion_unit_ooo_collect_cancel_replay_over_random_dags():
    """Property test: random DAG topologies drive Scoreboard + a shared
    CompletionUnit exactly the way the graph dispatcher does (job k and
    k + n_units share a unit copy; oldest-first drain keeps reuse legal).

    Interleavings exercised every round:
      * out-of-order arrival: a random subset of in-flight jobs completes
        (fires or defers its IPI) before the oldest job collects;
      * deferred-IRQ replay: those early completions queue behind the
        pending cause and are parked by ``collect`` for later jobs;
      * cancel(): ~25% of dispatches lose an arrival, get cancelled
        (missing count observed) and are re-programmed on the same unit
        copy — the replay path must never resurrect the cancelled cause.
    """
    for seed in range(25):
        rng = random.Random(1000 + seed)
        deps = _random_deps(rng, rng.randint(2, 24))
        sb = Scoreboard(deps)
        unit = CompletionUnit(n_units=rng.randint(1, 4))
        win = collections.deque()       # (node, job_id, n_clusters)
        next_job = 0
        arrived = set()                 # job ids whose IPI already fired
        cancelled_replayed = 0
        while not sb.all_retired:
            ready = sb.ready()
            if ready and len(win) < unit.n_units and rng.random() < 0.7:
                i = rng.choice(ready)
                jid, next_job = next_job, next_job + 1
                nc = rng.randint(1, 8)
                unit.program(nc, jid)
                if nc > 1 and rng.random() < 0.25:
                    # fault: straggler never arrives -> cancel + resubmit
                    unit.arrive(jid, nc - 1)
                    assert unit.cancel(jid) == 1
                    unit.program(nc, jid)   # replay on the same unit copy
                    cancelled_replayed += 1
                sb.issue(i)
                win.append((i, jid, nc))
            elif win:
                # out-of-order completion: a random in-flight suffix
                # finishes before the oldest job is collected
                for (_, jj, nn) in rng.sample(list(win),
                                              rng.randint(1, len(win))):
                    if jj not in arrived:
                        unit.arrive(jj, nn)   # fires or defers the IPI
                        arrived.add(jj)
                i, jid, nc = win.popleft()    # retire the oldest (unit reuse)
                if jid not in arrived:
                    unit.arrive(jid, nc)
                    arrived.add(jid)
                unit.collect(jid)             # parks other causes
                sb.retire(i)
        assert sb.all_retired
        assert unit.outstanding() == {}       # every register drained
        # every parked cause was eventually claimed by its own collect
        assert unit._collected == set(), seed
        assert unit.pending_cause() is None, seed
        assert cancelled_replayed >= 0        # path exercised across seeds


def test_completion_unit_cancel_purges_racing_completion():
    """A completion that raced the cancel (cause pending or deferred)
    must not be collected by a later job reusing the unit copy."""
    unit = CompletionUnit(n_units=1)
    unit.program(4, job_id=0)
    unit.arrive(0, 4)                   # completes: cause 0 pending
    unit.cancel(0)                      # deadline tripped after the race
    assert unit.pending_cause() is None
    unit.program(4, job_id=1)
    unit.arrive(1, 4)
    unit.collect(1)                     # must see cause 1, not stale 0
    # deferred variant: cause 0 pending, cause 1 deferred, cancel 1
    unit.program(2, job_id=0)
    unit.arrive(0, 2)
    unit.program(3, job_id=1)
    unit.arrive(1, 3)                   # deferred behind cause 0
    unit.cancel(1)
    unit.collect(0)
    assert unit.pending_cause() is None
