"""``hypothesis`` with a deterministic fallback.

Test modules import ``given`` / ``settings`` / ``st`` from here.  With
hypothesis installed (``pip install -r requirements-dev.txt``) this is a
pure re-export.  Without it, a miniature shim enumerates a handful of
deterministic examples per strategy (bounds, midpoints, a few seeded
draws) and ``given`` runs the test over a capped cartesian product — so
property tests still exercise their code paths instead of the whole module
being skipped at collection.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import itertools
    import random

    _MAX_CASES = 32

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    def _draws(inner: _Strategy, rng: random.Random, k: int):
        return [rng.choice(inner.examples) for _ in range(k)]

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            rng = random.Random((min_value, max_value).__hash__())
            vals = {min_value, max_value, (min_value + max_value) // 2}
            vals.update(rng.randint(min_value, max_value) for _ in range(5))
            return _Strategy(sorted(vals))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(list(elements))

        @staticmethod
        def lists(inner, min_size=0, max_size=10):
            rng = random.Random(1)
            out = []
            for size in {min_size, max(min_size, 1), min(max_size, 3),
                         min(max_size, 7)}:
                out.append(_draws(inner, rng, size))
            return _Strategy(out)

        @staticmethod
        def sets(inner, min_size=0, max_size=10):
            rng = random.Random(2)
            out = []
            for size in {min_size, max(min_size, 1), min(max_size, 3),
                         min(max_size, len(inner.examples))}:
                s, guard = set(), 0
                while len(s) < size and guard < 50 * (size + 1):
                    s.add(rng.choice(inner.examples))
                    guard += 1
                if len(s) >= min_size:
                    out.append(s)
            return _Strategy(out)

        @staticmethod
        def permutations(values):
            rng = random.Random(3)
            vals = list(values)
            out = [list(vals), list(reversed(vals))]
            for _ in range(4):
                p = list(vals)
                rng.shuffle(p)
                out.append(p)
            return _Strategy(out)

    def given(*arg_strats, **kw_strats):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*call_args, **call_kwargs):
                pools = [s.examples for s in arg_strats]
                pools += [s.examples for s in kw_strats.values()]
                names = list(kw_strats)
                n_pos = len(arg_strats)
                for combo in itertools.islice(
                        itertools.product(*pools), _MAX_CASES):
                    kw = dict(call_kwargs)
                    kw.update(zip(names, combo[n_pos:]))
                    fn(*call_args, *combo[:n_pos], **kw)
            # strategy-bound params are filled here, not by pytest fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return decorate

    def settings(*_a, **_k):
        def decorate(fn):
            return fn
        return decorate
