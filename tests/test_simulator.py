"""The cycle-accurate simulator against the paper's published anchors."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import jobs, simulator
from repro.core.params import DEFAULT_PARAMS as P

NS = (1, 2, 4, 8, 16, 32)


def test_baseline_overhead_at_one_cluster_is_242():
    """§5.2: average offload overhead at 1 cluster ≈ 242 cycles (σ=65)."""
    vals = [simulator.offload_overhead(mk().spec, 1, "baseline")
            for mk in jobs.PAPER_JOBS.values()]
    assert abs(np.mean(vals) - 242.0) < 10.0
    assert all(abs(v - 242.0) < 65.0 for v in vals)


def test_overhead_grows_with_clusters():
    """fig. 7: overhead consistently increases with the cluster count."""
    for mk in jobs.PAPER_JOBS.values():
        ov = [simulator.offload_overhead(mk().spec, n, "baseline") for n in NS]
        assert ov[-1] > ov[0] * 1.5, mk().spec.name
        # app spread grows with n (paper: σ=256 at 32 clusters)
    at32 = [simulator.offload_overhead(mk().spec, 32, "baseline")
            for mk in jobs.PAPER_JOBS.values()]
    assert max(at32) > 1000.0            # paper max: 1146 on 32-cluster matmul
    assert np.std(at32) > 150.0


def test_multicast_beats_baseline_everywhere():
    for mk in jobs.PAPER_JOBS.values():
        for n in NS:
            base = simulator.simulate(mk().spec, n, "baseline").total
            ext = simulator.simulate(mk().spec, n, "multicast").total
            ideal = simulator.simulate(mk().spec, n, "ideal").total
            assert ideal <= ext <= base, (mk().spec.name, n)


def test_restoration_bands():
    """§5.4: extensions restore >70 % of the ideal speedup everywhere; the
    Amdahl class (axpy/mc/matmul) reaches 70–9x %, the broadcast class
    (atax/cov/bfs) 85 %+."""
    for name, mk in jobs.PAPER_JOBS.items():
        for n in (8, 16, 32):
            _, _, restored = simulator.speedups(mk().spec, n)
            assert restored > 0.70, (name, n, restored)
            if name in ("atax", "covariance", "bfs"):
                assert restored > 0.85, (name, n, restored)


def test_max_achieved_speedup_near_2_3x():
    """Conclusion: 'up to 2.3× speedups on offloaded applications'."""
    best = max(
        simulator.simulate(mk().spec, n, "baseline").total
        / simulator.simulate(mk().spec, n, "multicast").total
        for mk in jobs.PAPER_JOBS.values() for n in NS
    )
    assert 2.0 < best < 2.7, best


def test_axpy_minimum_disappears_with_extensions():
    """§5.4 / fig. 9: the baseline AXPY runtime has a global minimum in n;
    the multicast curve decreases monotonically (Amdahl-aligned)."""
    spec = jobs.axpy_spec(1024)
    base = [simulator.simulate(spec, n, "baseline").total for n in NS]
    ext = [simulator.simulate(spec, n, "multicast").total for n in NS]
    assert min(base) < base[-1], "baseline should have an interior minimum"
    assert all(b > a for a, b in zip(ext[1:], ext[:-1])), "ext must decrease"


def test_wakeup_multicast_constant_47():
    """§5.5 B: multicast wakeup = 47 cycles for every cluster."""
    res = simulator.simulate(jobs.axpy_spec(1024), 16, "multicast")
    stats = res.phase_stats()[simulator.Phase.B]
    assert stats.min == stats.max == pytest.approx(47.0)


def test_wakeup_baseline_linear():
    """§5.5 B: baseline wakeup min ≈ multicast, max grows linearly."""
    res = simulator.simulate(jobs.axpy_spec(1024), 32, "baseline")
    stats = res.phase_stats()[simulator.Phase.B]
    assert stats.min == pytest.approx(47.0)
    assert stats.max == pytest.approx(8 + 31 * 25 + 39)


def test_phase_e_port_drain():
    """§5.5 E: with simultaneous starts the max phase-E runtime includes the
    time to move the entire job input (eq. 1)."""
    N = 1024
    res = simulator.simulate(jobs.axpy_spec(N), 8, "multicast")
    stats = res.phase_stats()[simulator.Phase.E]
    want = 53 + 55 + 2 * N * 8 / 64
    assert stats.max == pytest.approx(want, rel=0.02)


@given(n=st.sampled_from(NS), N=st.sampled_from([256, 1024, 4096]))
@settings(max_examples=60, deadline=None)
def test_modes_order_invariant(n, N):
    """Property: ideal ≤ multicast ≤ baseline for any (n, N)."""
    spec = jobs.axpy_spec(N)
    t = {m: simulator.simulate(spec, n, m).total
         for m in ("ideal", "multicast", "baseline")}
    assert t["ideal"] <= t["multicast"] <= t["baseline"]


@given(n=st.integers(1, 32))
@settings(max_examples=40, deadline=None)
def test_sim_total_positive_and_finite(n):
    for mk in (jobs.make_axpy, jobs.make_bfs):
        spec = mk().spec
        for mode in simulator.MODES:
            t = simulator.simulate(spec, n, mode).total
            assert np.isfinite(t) and t > 0


# ---------------------------------------------------------------------------
# Dependent job graphs (ISSUE-8): event model vs closed-form bounds
# ---------------------------------------------------------------------------


def _chain(N, K=8, clusters=8):
    """Self-scaling chain y <- a*y + y: both operands read the previous
    node's result (two dataflow edges per link)."""
    spec = jobs.axpy_spec(N)
    sel = tuple(range(clusters))
    return [simulator.GraphJob(spec=spec, clusters=sel,
                               deps=(i - 1, i - 1) if i else (),
                               out_bytes=N * 8)
            for i in range(K)]


def test_graph_chain_model_error_under_15pct():
    """§6 contract extended to graphs: closed-form critical-path bound vs
    the dependency-aware event model, < 15 % on K=8 chains across sizes."""
    for N in (256, 1024, 2048, 4096, 16384):
        nodes = _chain(N)
        ev = simulator.simulate_graph(nodes, window=4)
        cf = simulator.graph_critical_path(nodes)
        err = simulator.model_error(cf, ev.makespan)
        assert err < 0.15, (N, cf, ev.makespan)


def test_graph_chain_beats_isolated_baseline():
    """The dag acceptance bar: a K=8 dependent chain through the graph
    path costs <= 0.6x the chained submit+wait baseline (every edge
    bouncing d2h + h2d through the host)."""
    nodes = _chain(2048)
    ev = simulator.simulate_graph(nodes, window=4)
    iso = simulator.isolated_graph_cycles(nodes)
    assert ev.makespan / iso <= 0.6, (ev.makespan, iso)


def test_graph_diamond_arms_overlap():
    """Independent diamond arms on disjoint selections issue concurrently:
    makespan ~ critical path, strictly under the arms-serialized variant."""
    spec = jobs.axpy_spec(8192)
    nb = 8192 * 8
    c8, left, right = tuple(range(8)), tuple(range(4)), tuple(range(4, 8))
    diamond = [
        simulator.GraphJob(spec=spec, clusters=c8, out_bytes=nb),
        simulator.GraphJob(spec=spec, clusters=left, deps=(0,),
                           out_bytes=nb),
        simulator.GraphJob(spec=spec, clusters=right, deps=(0,),
                           out_bytes=nb),
        simulator.GraphJob(spec=spec, clusters=c8, deps=(1, 2),
                           out_bytes=nb),
    ]
    ev = simulator.simulate_graph(diamond, window=4)
    cf = simulator.graph_critical_path(diamond)
    assert simulator.model_error(cf, ev.makespan) < 0.15
    serial = [diamond[0], diamond[1],
              simulator.GraphJob(spec=spec, clusters=right, deps=(0, 1),
                                 out_bytes=nb),
              diamond[3]]
    evs = simulator.simulate_graph(serial, window=4)
    assert ev.makespan < evs.makespan * 0.85, (ev.makespan, evs.makespan)
    assert ev.issue_order[0] == 0 and ev.issue_order[-1] == 3


def test_forward_model_tracks_event_forward():
    """Closed-form per-hop forward cost vs the discrete-event edge model:
    aliasing is free in both, every other flavor agrees within 15 %."""
    for nbytes in (2048, 65536, 1 << 20):
        assert simulator.simulate_forward(nbytes, range(8), range(8)) == 0.0
        assert simulator.forward_model(nbytes, range(8), range(8)) == 0.0
        for src, dst, rep in [([0], [4, 5], False),
                              ([0, 1], range(4, 8), True),
                              (range(4), range(8), True)]:
            ev = simulator.simulate_forward(nbytes, src, dst, replicate=rep)
            cf = simulator.forward_model(nbytes, src, dst, replicate=rep)
            assert ev > 0.0
            assert simulator.model_error(cf, ev) < 0.15, (nbytes, src, dst)


def test_graph_window_bounds_inflight():
    """The event model respects the completion-unit window: max in-flight
    never exceeds it, and widening the window never hurts the makespan."""
    spec = jobs.axpy_spec(1024)
    independent = [simulator.GraphJob(spec=spec, clusters=(i,),
                                      out_bytes=1024 * 8)
                   for i in range(8)]
    t1 = simulator.simulate_graph(independent, window=1).makespan
    t4 = simulator.simulate_graph(independent, window=4).makespan
    t8 = simulator.simulate_graph(independent, window=8).makespan
    assert t1 >= t4 >= t8
    assert t8 < t1                       # overlap actually bought cycles
    with pytest.raises(ValueError):
        simulator.simulate_graph(independent, window=0)
    with pytest.raises(ValueError):
        simulator.simulate_graph([])
