"""The cycle-accurate simulator against the paper's published anchors."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import jobs, simulator
from repro.core.params import DEFAULT_PARAMS as P

NS = (1, 2, 4, 8, 16, 32)


def test_baseline_overhead_at_one_cluster_is_242():
    """§5.2: average offload overhead at 1 cluster ≈ 242 cycles (σ=65)."""
    vals = [simulator.offload_overhead(mk().spec, 1, "baseline")
            for mk in jobs.PAPER_JOBS.values()]
    assert abs(np.mean(vals) - 242.0) < 10.0
    assert all(abs(v - 242.0) < 65.0 for v in vals)


def test_overhead_grows_with_clusters():
    """fig. 7: overhead consistently increases with the cluster count."""
    for mk in jobs.PAPER_JOBS.values():
        ov = [simulator.offload_overhead(mk().spec, n, "baseline") for n in NS]
        assert ov[-1] > ov[0] * 1.5, mk().spec.name
        # app spread grows with n (paper: σ=256 at 32 clusters)
    at32 = [simulator.offload_overhead(mk().spec, 32, "baseline")
            for mk in jobs.PAPER_JOBS.values()]
    assert max(at32) > 1000.0            # paper max: 1146 on 32-cluster matmul
    assert np.std(at32) > 150.0


def test_multicast_beats_baseline_everywhere():
    for mk in jobs.PAPER_JOBS.values():
        for n in NS:
            base = simulator.simulate(mk().spec, n, "baseline").total
            ext = simulator.simulate(mk().spec, n, "multicast").total
            ideal = simulator.simulate(mk().spec, n, "ideal").total
            assert ideal <= ext <= base, (mk().spec.name, n)


def test_restoration_bands():
    """§5.4: extensions restore >70 % of the ideal speedup everywhere; the
    Amdahl class (axpy/mc/matmul) reaches 70–9x %, the broadcast class
    (atax/cov/bfs) 85 %+."""
    for name, mk in jobs.PAPER_JOBS.items():
        for n in (8, 16, 32):
            _, _, restored = simulator.speedups(mk().spec, n)
            assert restored > 0.70, (name, n, restored)
            if name in ("atax", "covariance", "bfs"):
                assert restored > 0.85, (name, n, restored)


def test_max_achieved_speedup_near_2_3x():
    """Conclusion: 'up to 2.3× speedups on offloaded applications'."""
    best = max(
        simulator.simulate(mk().spec, n, "baseline").total
        / simulator.simulate(mk().spec, n, "multicast").total
        for mk in jobs.PAPER_JOBS.values() for n in NS
    )
    assert 2.0 < best < 2.7, best


def test_axpy_minimum_disappears_with_extensions():
    """§5.4 / fig. 9: the baseline AXPY runtime has a global minimum in n;
    the multicast curve decreases monotonically (Amdahl-aligned)."""
    spec = jobs.axpy_spec(1024)
    base = [simulator.simulate(spec, n, "baseline").total for n in NS]
    ext = [simulator.simulate(spec, n, "multicast").total for n in NS]
    assert min(base) < base[-1], "baseline should have an interior minimum"
    assert all(b > a for a, b in zip(ext[1:], ext[:-1])), "ext must decrease"


def test_wakeup_multicast_constant_47():
    """§5.5 B: multicast wakeup = 47 cycles for every cluster."""
    res = simulator.simulate(jobs.axpy_spec(1024), 16, "multicast")
    stats = res.phase_stats()[simulator.Phase.B]
    assert stats.min == stats.max == pytest.approx(47.0)


def test_wakeup_baseline_linear():
    """§5.5 B: baseline wakeup min ≈ multicast, max grows linearly."""
    res = simulator.simulate(jobs.axpy_spec(1024), 32, "baseline")
    stats = res.phase_stats()[simulator.Phase.B]
    assert stats.min == pytest.approx(47.0)
    assert stats.max == pytest.approx(8 + 31 * 25 + 39)


def test_phase_e_port_drain():
    """§5.5 E: with simultaneous starts the max phase-E runtime includes the
    time to move the entire job input (eq. 1)."""
    N = 1024
    res = simulator.simulate(jobs.axpy_spec(N), 8, "multicast")
    stats = res.phase_stats()[simulator.Phase.E]
    want = 53 + 55 + 2 * N * 8 / 64
    assert stats.max == pytest.approx(want, rel=0.02)


@given(n=st.sampled_from(NS), N=st.sampled_from([256, 1024, 4096]))
@settings(max_examples=60, deadline=None)
def test_modes_order_invariant(n, N):
    """Property: ideal ≤ multicast ≤ baseline for any (n, N)."""
    spec = jobs.axpy_spec(N)
    t = {m: simulator.simulate(spec, n, m).total
         for m in ("ideal", "multicast", "baseline")}
    assert t["ideal"] <= t["multicast"] <= t["baseline"]


@given(n=st.integers(1, 32))
@settings(max_examples=40, deadline=None)
def test_sim_total_positive_and_finite(n):
    for mk in (jobs.make_axpy, jobs.make_bfs):
        spec = mk().spec
        for mode in simulator.MODES:
            t = simulator.simulate(spec, n, mode).total
            assert np.isfinite(t) and t > 0
