"""ServeEngine end-to-end on a multi-device mesh (subprocess)."""


def test_generate_greedy_deterministic(subproc):
    subproc("""
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.data import DataConfig, SyntheticStream
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
eng = ServeEngine(cfg, params, mesh, ServeConfig(batch=4, max_len=40))
stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size, batch_size=4,
                                    seq_len=12, seed=1), cfg)
prompts = stream.batch(0)["tokens"]
out1 = eng.generate(prompts, 8)
out2 = eng.generate(prompts, 8)
assert out1.shape == (4, 8)
np.testing.assert_array_equal(out1, out2)   # greedy => deterministic
assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()
print("OK")
""", devices=8, x64=False, timeout=900)


def test_generate_matches_stepwise_decode(subproc):
    """Engine output == manual prefill+decode_step greedy loop."""
    subproc("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = dataclasses.replace(M.reduced(M.get("yi-9b")), compute_dtype="float32")
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params_dev = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
prompts = np.random.default_rng(3).integers(0, cfg.vocab_size, (4, 10)).astype(np.int32)
eng = ServeEngine(cfg, params_dev, mesh, ServeConfig(batch=4, max_len=32))
got = eng.generate(prompts, 6)

call = M.CallConfig(moe_no_drop=True)
logits, cache = M.prefill(params, cfg, {"tokens": prompts}, 32, call)
tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
want = []
for _ in range(6):
    want.append(np.asarray(tok))
    logits, cache = M.decode_step(params, cfg, cache, tok[:, None], call)
    tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
np.testing.assert_array_equal(got, np.stack(want, 1))
print("OK")
""", devices=8, x64=False, timeout=900)


def test_decode_modes_agree_and_stay_device_resident(subproc):
    """host / step / chunk modes emit identical greedy tokens; the resident
    modes do zero per-step host->device token transfers and the chunk mode
    amortizes dispatch to one XLA launch per chunk."""
    subproc("""
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
prompts = np.random.default_rng(7).integers(0, cfg.vocab_size, (4, 9)).astype(np.int32)

outs, engines = {}, {}
for mode in ("host", "step", "chunk"):
    eng = ServeEngine(cfg, params, mesh,
                      ServeConfig(batch=4, max_len=40, decode_mode=mode,
                                  decode_chunk=3))
    outs[mode] = eng.generate(prompts, 8)
    engines[mode] = eng
np.testing.assert_array_equal(outs["host"], outs["step"])
np.testing.assert_array_equal(outs["step"], outs["chunk"])
assert engines["host"].stats["h2d_token_puts"] == 8
assert engines["step"].stats["h2d_token_puts"] == 0
assert engines["chunk"].stats["h2d_token_puts"] == 0
# first-token sample + 7 decode steps -> 1 + (2 chunks of 3 + 1 remainder)
assert engines["chunk"].stats["xla_dispatches"] == 4
assert engines["step"].stats["xla_dispatches"] == 8
assert all(e.stats["tokens_emitted"] == 8 for e in engines.values())
print("OK")
""", devices=8, x64=False, timeout=900)


def test_temperature_sampling_device_resident(subproc):
    """Temperature sampling inside the jitted step: step and chunk modes
    follow the same key trajectory, and repeated runs are reproducible."""
    subproc("""
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (4, 6)).astype(np.int32)

outs = {}
for mode in ("step", "chunk"):
    eng = ServeEngine(cfg, params, mesh,
                      ServeConfig(batch=4, max_len=32, temperature=0.7,
                                  decode_mode=mode, decode_chunk=4))
    a = eng.generate(prompts, 9)
    b = eng.generate(prompts, 9)
    np.testing.assert_array_equal(a, b)       # fixed seed => reproducible
    outs[mode] = a
np.testing.assert_array_equal(outs["step"], outs["chunk"])
assert (outs["step"] >= 0).all() and (outs["step"] < cfg.vocab_size).all()
print("OK")
""", devices=8, x64=False, timeout=900)
