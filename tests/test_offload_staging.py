"""Hierarchical broadcast staging end-to-end (subprocess, 8-device mesh):
O(1) host-link bytes via the tree, donation interplay, stream slot staging,
request-driven selections, and serve-engine weight placement."""


def test_tree_staging_one_upload_per_operand_any_n(subproc):
    """THE acceptance assertion: replicated-operand staging via the tree
    performs exactly 1 host->device upload per operand regardless of n,
    while host-fanout moves n copies — asserted via h2d_bytes/d2d_bytes."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime

job = jobs.make_covariance(32, 64)      # one replicated operand
operands, expected = job.make_instance(0)
size = operands["data"].nbytes
ARGS = 8 * 8                            # (8,) float64 job args, replicated

for n in (1, 2, 4, 8):
    rt = OffloadRuntime(config=OffloadConfig(staging="tree"))
    got = rt.offload(job, operands, n=n).wait()
    assert np.allclose(got, expected), n
    # exactly one host-link upload per operand + one for the args: O(1) in n
    assert rt.stats.h2d_bytes == size + ARGS, (n, rt.stats.h2d_bytes)
    assert rt.stats.d2d_bytes == (size + ARGS) * (n - 1), n
    assert rt.stats.tree_stages == 2

    rt_hf = OffloadRuntime(config=OffloadConfig(staging="host_fanout"))
    got = rt_hf.offload(job, operands, n=n).wait()
    assert np.allclose(got, expected), n
    assert rt_hf.stats.h2d_bytes == (size + ARGS) * n, n   # O(n) baseline
    assert rt_hf.stats.d2d_bytes == 0
print("OK")
""")


def test_all_staging_modes_bit_identical_across_jobs(subproc):
    """Every paper kernel with replicated operands produces bit-identical
    results under direct / host_fanout / tree / tree_reshard staging."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime, STAGING_MODES

for name in ("matmul", "atax", "covariance", "bfs"):
    mk = jobs.PAPER_JOBS[name]
    job = mk() if name != "bfs" else mk(64)
    operands, expected = job.make_instance(3)
    ref = None
    for mode in STAGING_MODES:
        rt = OffloadRuntime(config=OffloadConfig(staging=mode))
        got = rt.offload(job, operands, n=4).wait()
        assert np.allclose(got, expected, rtol=1e-9, atol=1e-9), (name, mode)
        if ref is None:
            ref = got
        assert np.array_equal(ref, got), (name, mode)
print("OK")
""")


def test_sharded_operands_unaffected_by_staging_mode(subproc):
    """Sharded operands cross the host link once per dispatch in every
    mode (each device only receives its shard): axpy's h2d is mode-free."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime

job = jobs.make_axpy(2048)
operands, expected = job.make_instance(0)
size = sum(v.nbytes for v in operands.values())
ARGS = 8 * 8
for mode in ("direct", "tree", "host_fanout"):
    rt = OffloadRuntime(config=OffloadConfig(staging=mode))
    got = rt.offload(job, operands, n=8).wait()
    assert np.allclose(got, expected)
    # args are replicated (mode-dependent); the operands are not
    op_h2d = rt.stats.h2d_bytes - (ARGS if mode == "tree" else ARGS * 8)
    assert op_h2d == size, (mode, op_h2d)
print("OK")
""")


def test_donation_tree_restage_snapshots_at_root_only(subproc):
    """A donated dispatch consumes tree-staged buffers; the plan restages
    through the same tree — one host upload per operand, not n — and the
    host snapshot is immune to caller mutation."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime

rt = OffloadRuntime(config=OffloadConfig(donate_operands=True,
                                         staging="tree"))
job = jobs.make_covariance(32, 64)
operands, expected = job.make_instance(1)
size = operands["data"].nbytes
r0 = rt.offload(job, operands, n=8).wait()
operands["data"][:] = 0.0               # caller mutation must not leak in
h0 = rt.stats.h2d_bytes
r1 = rt.offload(job, "resident", n=8).wait()
r2 = rt.offload(job, "resident", n=8).wait()
assert np.array_equal(r0, r1) and np.array_equal(r1, r2)
assert np.allclose(r0, expected)
# two donation restages, each exactly ONE root upload (O(1) host link)
assert rt.stats.h2d_bytes - h0 == 2 * size, rt.stats.h2d_bytes - h0
assert rt.stats.donation_restages == 2
assert len(rt._compiled) == 1           # and still zero recompiles
print("OK")
""")


def test_stream_slot_staging_via_tree(subproc):
    """OffloadStream routes double-buffered slot staging through the tree:
    per-job host-link bytes stay O(1) while the pipeline overlap (slots,
    window) is preserved; results match the sequential reference.  With
    donation on, consumed slot buffers never corrupt later submits."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime
from repro.core.stream import OffloadStream

job = jobs.make_covariance(16, 32)
insts, exps = jobs.make_instances(job, 6, seed0=0)
size = insts[0]["data"].nbytes
ARGS = 8 * 8

rt = OffloadRuntime(n_units=4)
stream = OffloadStream(rt, job, n=8, staging="tree")
outs = stream.map(insts)
for got, exp in zip(outs, exps):
    assert np.allclose(got, exp)
assert stream.stats["submitted"] == 6
# 6 slot stagings x 1 root upload each, + the args staged once
assert rt.stats.h2d_bytes == 6 * size + ARGS, rt.stats.h2d_bytes
assert rt.stats.d2d_bytes == (6 * size + ARGS) * 7

# donation + slot reuse: slot buffers are single-use, donated dispatches
# consume them, and every later submit stages fresh — results stay exact
rtd = OffloadRuntime(config=OffloadConfig(donate_operands=True,
                                          staging="tree"), n_units=2)
sd = OffloadStream(rtd, job, n=8, depth=2)
for rep in range(2):                    # slots 0/1 reused across reps
    outs = sd.map(insts)
    for got, exp in zip(outs, exps):
        assert np.allclose(got, exp), rep
assert rtd.stats.donation_restages == 0   # slots never self-heal, by design
print("OK")
""")


def test_request_and_explicit_cluster_selections(subproc):
    """Tree staging follows the multicast selection: an address-mask
    request and a non-power-of-two explicit set both stage O(1)."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core import multicast as mc
from repro.core.offload import OffloadConfig, OffloadRuntime

job = jobs.make_covariance(32, 64)
operands, expected = job.make_instance(2)
size = operands["data"].nbytes
ARGS = 8 * 8

rt = OffloadRuntime(config=OffloadConfig(staging="tree"))
req = mc.encode_cluster_selection([1, 3, 5, 7], num_clusters=8)
got = rt.offload(job, operands, request=req).wait()
assert np.allclose(got, expected)
assert rt.stats.h2d_bytes == size + ARGS
assert rt.stats.d2d_bytes == (size + ARGS) * 3

rt2 = OffloadRuntime(config=OffloadConfig(staging="tree"))
got = rt2.offload(job, operands, clusters=[0, 1, 2, 5, 6]).wait()
assert np.allclose(got, expected)
assert rt2.stats.h2d_bytes == size + ARGS
assert rt2.stats.d2d_bytes == (size + ARGS) * 4
print("OK")
""")


def test_fused_batch_shares_one_tree(subproc):
    """offload_fused stages the stacked batch through one tree: h2d is the
    stacked size once, regardless of cluster count."""
    subproc("""
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadConfig, OffloadRuntime

job = jobs.make_matmul(16, 16, 16)
B = 4
insts, exps = jobs.make_instances(job, B, seed0=0)
rt = OffloadRuntime(config=OffloadConfig(staging="tree"))
outs = rt.offload_fused(job, insts, n=8).wait_each()
for got, exp in zip(outs, exps):
    assert np.allclose(got, exp)
stacked_B = B * insts[0]["B"].nbytes    # replicated operand, tree-staged
stacked_A = B * insts[0]["A"].nbytes    # sharded operand, one pass anyway
args = B * 8 * 8                        # (B, 8) fused job args, replicated
assert rt.stats.h2d_bytes == stacked_B + stacked_A + args
assert rt.stats.d2d_bytes == (stacked_B + args) * 7
assert rt.stats.tree_stages == 2
print("OK")
""")


def test_serve_place_params_tree(subproc):
    """ServeEngine weight placement and prefill inserts through the tree:
    bit-identical generations, replicated leaves uploaded once."""
    subproc("""
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
host_params = jax.device_get(M.init_params(jax.random.key(0), cfg))
prompts = np.random.default_rng(0).integers(
    0, cfg.vocab_size, (4, 12)).astype(np.int32)

outs, stats = {}, {}
for staging in ("direct", "tree", "tree_reshard"):
    eng = ServeEngine(cfg, host_params, mesh,
                      ServeConfig(batch=4, max_len=48, staging=staging,
                                  prefill_bucket=8))
    eng.place_params(host_params)
    stats[staging] = dict(eng.stats)
    outs[staging] = eng.generate(prompts, 8)
    reqs = [(prompts[i, :6 + i], 4) for i in range(3)]
    outs[staging + "/many"] = np.concatenate(
        eng.generate_many(reqs, arrival_steps=[0, 1, 3]))

for key in ("tree", "tree_reshard"):
    np.testing.assert_array_equal(outs["direct"], outs[key])
    np.testing.assert_array_equal(outs["direct/many"], outs[key + "/many"])
    # replicated leaves cross the host link once instead of 8x, so the
    # tree placement strictly undercuts direct placement's h2d bytes
    assert stats[key]["h2d_bytes"] < stats["direct"]["h2d_bytes"]
    assert stats[key]["d2d_bytes"] > 0
print("OK")
""", devices=8, x64=False, timeout=900)


def test_forward_and_d2h_byte_counters_exact(subproc):
    """ISSUE-8 satellite: PlanStats d2h and per-edge forwarding byte
    counters are *exact* across every forward flavor — alias (0 bytes),
    reshard (nbytes once), replicated tree fan-out (n x nbytes) — and
    ``wait()`` charges d2h exactly once per fetched result."""
    subproc("""
import numpy as np
from repro.core.jobs import make_axpy, make_covariance
from repro.core.scoreboard import GraphNode, Ref
from repro.core.session import Session

# -- alias: same selection/sharding edge crosses zero fabric bytes ------
axpy = make_axpy(2048)
ops, _ = axpy.make_instance(0)
s = Session()
gh = s.submit_graph([
    GraphNode(axpy, ops, name="p", clusters=[0, 1]),
    GraphNode(axpy, {"x": ops["x"], "y": Ref("p")}, name="c",
              clusters=[0, 1]),
])
out = gh.wait()
assert gh.forwarded[(0, 1, "y")] == 0          # aliased, not copied
assert s.stats.forwards == 1
assert s.stats.forward_bytes == 0
assert s.stats.d2h_bytes == out["c"].nbytes    # exactly the fetched sink

# -- reshard: sharded consumer on a different selection: nbytes once ----
s2 = Session()
gh2 = s2.submit_graph([
    GraphNode(axpy, ops, name="p", clusters=[0]),
    GraphNode(axpy, {"x": ops["x"], "y": Ref("p")}, name="c",
              clusters=[4, 5]),
])
out2 = gh2.wait()
assert gh2.forwarded[(0, 1, "y")] == ops["y"].nbytes
assert s2.stats.forward_bytes == ops["y"].nbytes
assert s2.stats.d2h_bytes == out2["c"].nbytes
assert np.array_equal(np.asarray(out["c"]), np.asarray(out2["c"]))

# -- replicated consumer: PR-3 tree fan-out, n x nbytes, h2d untouched --
cov = make_covariance(32, 32)                  # (32,32) -> (32,32)
cops, _ = cov.make_instance(0)
s3 = Session()
h2d_probe = Session()
gh3 = s3.submit_graph([
    GraphNode(cov, cops, name="p", clusters=[0, 1]),
    GraphNode(cov, {"data": Ref("p")}, name="c", clusters=[4, 5, 6, 7]),
])
out3 = gh3.wait()
nbytes = cops["data"].nbytes
assert gh3.forwarded[(0, 1, "data")] == 4 * nbytes, gh3.forwarded
exp = np.asarray(out3["c"])
centred = cops["data"] - cops["data"].mean(axis=1, keepdims=True)
ref = centred @ centred.T / (cops["data"].shape[1] - 1)
centred2 = ref - ref.mean(axis=1, keepdims=True)
assert np.allclose(exp, centred2 @ centred2.T / (ref.shape[1] - 1))
# the forwarded operand never crossed the host link: the graph's h2d
# exceeds a lone producer's staging by the consumer's job-args upload
# only — strictly less than one copy of the operand
lone = h2d_probe.submit(cov, cops, clusters=[0, 1]); lone.wait()
args_only = s3.stats.h2d_bytes - h2d_probe.stats.h2d_bytes
assert 0 <= args_only < nbytes, (args_only, nbytes)
assert s3.stats.d2d_bytes == 4 * nbytes            # fan-out rode the tree
assert s3.stats.d2h_bytes == out3["c"].nbytes

# -- d2h idempotency: re-wait and result() never re-charge --------------
gh3.wait(); gh3.result("c")
assert s3.stats.d2h_bytes == out3["c"].nbytes
s.drain(); s2.drain(); s3.drain(); h2d_probe.drain()
print("OK")
""")
