"""Dependent job graphs end-to-end (subprocess, 8-device mesh).

ISSUE-8 acceptance: a K-deep chain submitted as a graph keeps every
intermediate result on-fabric (``d2h_bytes`` proves exactly 0 bytes of
intermediate fetch), diamond arms overlap across disjoint cluster
selections, donation graphs rename forwarded buffers (WAR break), a
cross-lease graph forwards producer results without the producer's lease
ever touching the host link, and graph execution is bit-identical to
sequential submit/wait — including a property test over random DAG
topologies.
"""


def test_chain_intermediates_never_fetched_bit_identical(subproc):
    """THE acceptance assertion: K=8 dependent chain fetches exactly the
    final result — intermediate d2h bytes are 0 — and matches sequential
    submit/wait execution bit-for-bit."""
    subproc("""
import numpy as np
from repro.core.jobs import make_axpy
from repro.core.scoreboard import GraphNode, Ref
from repro.core.session import Session

job = make_axpy(2048)
ops, _ = job.make_instance(0)
K = 8

s = Session()
nodes = [GraphNode(job, ops, name="n0")]
for k in range(1, K):
    nodes.append(GraphNode(job, {"x": ops["x"], "y": Ref(f"n{k-1}")},
                           name=f"n{k}"))
gh = s.submit_graph(nodes)
out = gh.wait()
assert sorted(out) == [f"n{K-1}"]          # only the sink is fetched
final = out[f"n{K-1}"]

st = s.stats
# d2h is EXACTLY the final result: intermediates moved 0 host-link bytes
assert st.d2h_bytes == final.nbytes, (st.d2h_bytes, final.nbytes)
assert st.forwards == K - 1                # one d2d forward per edge
# same-sharding producer->consumer forwards alias: 0 fabric bytes
assert st.forward_bytes == 0, st.forward_bytes
# h2d staged the chain root's operands plus each link's fresh x only
assert st.h2d_bytes < K * (ops["x"].nbytes + ops["y"].nbytes)

# sequential submit/wait chain: K host round trips, bit-identical values
s2 = Session()
y = dict(ops)
for k in range(K):
    r = s2.submit(job, y).wait()
    y = {"x": ops["x"], "y": r}
assert np.array_equal(np.asarray(final), np.asarray(r))
assert s2.stats.d2h_bytes == K * r.nbytes  # the baseline the graph kills

# wait() is idempotent and result() agrees
again = gh.wait()
assert np.array_equal(np.asarray(again[f"n{K-1}"]), np.asarray(final))
assert np.array_equal(np.asarray(gh.result(f"n{K-1}")), np.asarray(final))
assert s.stats.d2h_bytes == final.nbytes   # no re-fetch on either call
s.drain(); s2.drain()
print("OK")
""")


def test_diamond_arms_overlap_and_forward_bytes(subproc):
    """Diamond across disjoint cluster selections: both arms in flight
    concurrently, each cross-selection edge's forwarded bytes recorded
    exactly, join result correct."""
    subproc("""
import numpy as np
from repro.core.jobs import make_axpy
from repro.core.scoreboard import GraphNode, Ref
from repro.core.session import Session

job = make_axpy(2048)
ops, _ = job.make_instance(0)
s = Session()
nodes = [GraphNode(job, ops, name="src"),
         GraphNode(job, {"x": ops["x"], "y": Ref("src")}, name="l",
                   clusters=[0, 1, 2, 3]),
         GraphNode(job, {"x": ops["x"], "y": Ref("src")}, name="r",
                   clusters=[4, 5, 6, 7]),
         GraphNode(job, {"x": Ref("l"), "y": Ref("r")}, name="join")]
gh = s.submit_graph(nodes)
out = gh.wait()
assert sorted(out) == ["join"]

a = 2.5
src = a * ops["x"] + ops["y"]
exp = a * (a * ops["x"] + src) + (a * ops["x"] + src)
assert np.allclose(out["join"], exp)

# arms overlapped: the scoreboard had both issued before either retired
assert gh.max_inflight >= 2, gh.max_inflight
# issue order is topological: src first, join last
order = gh.issue_order
assert order[0] == 0 and order[-1] == 3, order

# every cross-selection edge reshards: exact logical d2d bytes per edge
nbytes = ops["y"].nbytes
for edge in [(0, 1, "y"), (0, 2, "y"), (1, 3, "x"), (2, 3, "y")]:
    assert gh.forwarded[edge] == nbytes, (edge, gh.forwarded)
assert s.stats.d2h_bytes == out["join"].nbytes   # intermediates on-fabric
s.drain()
print("OK")
""")


def test_after_ordering_fetch_override_and_errors(subproc):
    """``after=`` ordering sugar, ``fetch=`` override, and the typed
    GraphError surface (cycles, unknown refs, retry policy, bad nodes)."""
    subproc("""
import numpy as np
from repro.core.jobs import make_axpy
from repro.core.policy import OffloadPolicy, RetryPolicy
from repro.core.scoreboard import GraphError, GraphNode, Ref
from repro.core.session import Session

job = make_axpy(512)
ops, _ = job.make_instance(0)
s = Session()

# after= on submit(): disjoint selections insert a completion barrier
h1 = s.submit(job, ops, clusters=[0, 1])
h2 = s.submit(job, ops, clusters=[4, 5], after=[h1])
assert np.allclose(h2.wait(), 2.5 * ops["x"] + ops["y"])
h1.wait()

# pure ordering edge inside a graph + fetch=True on an intermediate
nodes = [GraphNode(job, ops, name="a"),
         GraphNode(job, {"x": ops["x"], "y": Ref("a")}, name="b",
                   fetch=True),
         GraphNode(job, {"x": ops["x"], "y": Ref("b")}, name="c",
                   after=["a"], fetch=False)]
gh = s.submit_graph(nodes)
out = gh.wait()
assert sorted(out) == ["b"]            # fetch overrides the sink default
assert gh.issue_order == [0, 1, 2]
# fetch=False sink still retrievable on demand
exp_b = 2.5 * ops["x"] + (2.5 * ops["x"] + ops["y"])
assert np.allclose(out["b"], exp_b)
assert np.allclose(gh.result("c"), 2.5 * ops["x"] + exp_b)

# typed error surface
def expect(err, fn):
    try:
        fn()
    except err as e:
        return e
    raise AssertionError(f"expected {err.__name__}")

expect(GraphError, lambda: s.submit_graph([]))
expect(GraphError, lambda: s.submit_graph(["not a node"]))
expect(GraphError, lambda: s.submit_graph(
    [GraphNode(job, {"x": ops["x"], "y": Ref("ghost")})]))
expect(GraphError, lambda: s.submit_graph(
    [GraphNode(job, ops, name="a", after=["b"]),
     GraphNode(job, ops, name="b", after=["a"])]))        # cycle
expect(GraphError, lambda: s.submit_graph(
    [GraphNode(job, ops)],
    policy=OffloadPolicy(retry=RetryPolicy(max_attempts=2))))
s.drain()
print("OK")
""")


def test_donation_graph_renames_and_donated_reuse_error(subproc):
    """WAR/WAW hazards under donation: forwarded buffers with pending
    readers are renamed (copied) before a donating consumer eats them,
    execution stays bit-identical to sequential, and reusing a donated
    operand raises the typed DonatedOperandError from wait()."""
    subproc("""
import dataclasses
import numpy as np
from repro.core.jobs import make_axpy
from repro.core.offload import (DonatedOperandError, OffloadConfig,
                                OffloadRuntime)
from repro.core.policy import OffloadPolicy
from repro.core.scoreboard import GraphNode, Ref
from repro.core.session import Session

job = make_axpy(2048)
ops, _ = job.make_instance(0)
cfg = dataclasses.replace(OffloadConfig.extended(), donate_operands=True)
pol = OffloadPolicy(donate_operands=True)

s = Session(runtime=OffloadRuntime(config=cfg))
nodes = [GraphNode(job, ops, name="n0"),
         GraphNode(job, {"x": Ref("n0"), "y": Ref("n0")}, name="n1"),
         GraphNode(job, {"x": ops["x"], "y": Ref("n1")}, name="n2")]
gh = s.submit_graph(nodes, policy=pol)
out = gh.wait()
# n0 is read twice (WAR) and n1 once by a donating consumer (WAW):
# every forwarded buffer was renamed instead of consumed in place
assert s.stats.renames >= 3, s.stats.renames
a = 2.5
r = a * ops["x"] + ops["y"]
r = a * r + r
r = a * ops["x"] + r
assert np.allclose(out["n2"], r)

# bit-identical to the sequential donating path
s2 = Session(runtime=OffloadRuntime(
    config=dataclasses.replace(OffloadConfig.extended(),
                               donate_operands=True)))
r0 = s2.submit(job, ops, policy=pol).wait()
r1 = s2.submit(job, {"x": r0, "y": r0}, policy=pol).wait()
r2 = s2.submit(job, {"x": ops["x"], "y": r1}, policy=pol).wait()
assert np.array_equal(np.asarray(out["n2"]), np.asarray(r2))

# typed error: a consumer reusing a donated (deleted) device buffer
rt3 = OffloadRuntime(config=dataclasses.replace(
    OffloadConfig.extended(), donate_operands=True))
s3 = Session(runtime=rt3)
ha = s3.submit(job, ops, policy=pol)
val = [p for _, p in ha._parts][0].result
val.delete()                  # a donating consumer ate the buffer
try:
    ha.wait()
    raise AssertionError("expected DonatedOperandError")
except DonatedOperandError:
    pass
try:                          # idempotent: the error is sticky, not UB
    ha.wait()
    raise AssertionError("expected DonatedOperandError on re-wait")
except DonatedOperandError:
    pass
s.drain(); s2.drain()
print("OK")
""")


def test_cross_lease_graph_producer_lease_never_fetches(subproc):
    """A graph spanning two fabric leases forwards the producer's result
    device-to-device across leases: the producer session's d2h stays 0."""
    subproc("""
import numpy as np
from repro.core.fabric import FabricScheduler
from repro.core.jobs import make_axpy
from repro.core.scoreboard import GraphError, GraphNode, Ref

job = make_axpy(2048)
ops, _ = job.make_instance(0)
sched = FabricScheduler()
sa = sched.session("a", 4)
sb = sched.session("b", 4)
nodes = [GraphNode(job, ops, name="src", session=sa),
         GraphNode(job, {"x": ops["x"], "y": Ref("src")}, name="consume",
                   session=sb)]
gh = sched.submit_graph(nodes)
out = gh.wait()
exp = 2.5 * ops["x"] + (2.5 * ops["x"] + ops["y"])
assert np.allclose(out["consume"], exp)
assert sa.stats.d2h_bytes == 0          # producer result never fetched
assert sb.stats.d2h_bytes == out["consume"].nbytes
assert gh.forwarded[(0, 1, "y")] == ops["y"].nbytes   # cross-lease reshard

# scheduler-level convenience needs at least one session-pinned node
try:
    sched.submit_graph([GraphNode(job, ops)])
    raise AssertionError("expected GraphError")
except GraphError:
    pass
sa.close(); sb.close()
print("OK")
""")


def test_random_dag_graphs_bit_equal_to_sequential(subproc):
    """Satellite property test: random DAG topologies (random fan-in,
    cluster selections, and shared producers) executed via submit_graph
    are bit-equal to sequential submit/wait execution, while
    intermediates still move zero host-link bytes on the graph path and
    the in-flight window stays bounded by the completion-unit copies."""
    subproc("""
import random
import numpy as np
from repro.core.jobs import make_axpy
from repro.core.scoreboard import GraphNode, Ref
from repro.core.session import Session

job = make_axpy(512)
for seed in range(4):
    rng = random.Random(seed)
    ops, _ = job.make_instance(seed)
    n_nodes = rng.randint(3, 9)
    deps, nodes, sels = [], [], []
    for i in range(n_nodes):
        # random contiguous selection whose size divides the axpy length
        w = rng.choice([1, 2, 4, 8])
        s0 = rng.randint(0, 8 - w)
        sel = list(range(s0, s0 + w))
        pick = lambda: (Ref(rng.randrange(i)) if i and rng.random() < 0.6
                        else None)
        x, y = pick(), pick()
        d = []
        if isinstance(x, Ref): d.append(x.node)
        if isinstance(y, Ref): d.append(y.node)
        deps.append(d)
        nodes.append(GraphNode(
            job,
            {"x": x if x is not None else ops["x"],
             "y": y if y is not None else ops["y"]},
            clusters=sel, fetch=True))
        sels.append(sel)

    s = Session()
    gh = s.submit_graph(nodes)
    out = gh.wait()

    # issue order respected the DAG
    pos = {i: k for k, i in enumerate(gh.issue_order)}
    for i, d in enumerate(deps):
        for p in d:
            assert pos[p] < pos[i], (seed, p, i)
    assert gh.max_inflight <= s.runtime().unit.n_units

    # d2h on the graph path is exactly the fetched results, nothing more
    assert s.stats.d2h_bytes == sum(out[i].nbytes for i in range(n_nodes))

    # sequential execution: host round trip between every producer pair
    s2 = Session()
    seq = []
    for i, nd in enumerate(nodes):
        operands = {k: (np.asarray(seq[v.node]) if isinstance(v, Ref)
                        else v)
                    for k, v in nd.operands.items()}
        seq.append(s2.submit(job, operands, clusters=sels[i]).wait())
    for i in range(n_nodes):
        assert np.array_equal(np.asarray(out[i]), np.asarray(seq[i])), (
            seed, i)
    s.drain(); s2.drain()
print("OK")
""")
