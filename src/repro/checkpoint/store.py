"""Sharded checkpointing: npz payloads + JSON manifest, elastic restore.

No orbax/tensorstore offline, so this substrate is built from scratch:

* ``save``: atomically writes (tmp dir + rename) a manifest (pytree
  structure, shapes, dtypes, logical PartitionSpecs, step, data index) and
  one npz per top-level group.  Arrays are gathered host-side — the
  single-host CI path; the manifest records the sharding so a multi-host
  writer can shard the payload the same way.
* ``restore``: rebuilds the pytree and ``device_put``s every leaf with the
  sharding derived from the *current* mesh — the mesh may have a different
  device count than the writer's (**elastic restart**): specs are logical,
  so re-laying-out on 2 devices what was written from 8 is just a different
  NamedSharding.  Divisibility fallbacks re-apply automatically.
* ``latest_step`` / retention: keep-last-k garbage collection.

Determinism contract with the data pipeline: the manifest stores the next
data index; resuming replays exactly the batches a non-failed run would
have seen (tested bit-for-bit in tests/test_checkpoint.py).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

MANIFEST = "manifest.json"


def _flatten(tree: Pytree, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Pytree:
    tree: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def _spec_to_json(spec: P) -> list:
    out = []
    for dim in spec:
        if dim is None:
            out.append(None)
        elif isinstance(dim, tuple):
            out.append(list(dim))
        else:
            out.append(dim)
    return out


def save(
    directory: str,
    step: int,
    state: Dict[str, Pytree],          # e.g. {"params": ..., "opt": ...}
    specs: Optional[Dict[str, Pytree]] = None,
    data_index: int = 0,
    keep: int = 3,
) -> str:
    """Write checkpoint for `step`; returns the checkpoint path."""
    ckpt = os.path.join(directory, f"step_{step:08d}")
    tmp = ckpt + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest: Dict[str, Any] = {
        "step": step, "data_index": data_index, "groups": {}, "specs": {},
    }
    for group, tree in state.items():
        flat = _flatten(tree)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        np.savez(os.path.join(tmp, f"{group}.npz"), **arrays)
        manifest["groups"][group] = {
            k: {"shape": list(a.shape), "dtype": str(a.dtype)}
            for k, a in arrays.items()
        }
        if specs and group in specs:
            sflat = _flatten(specs[group])
            manifest["specs"][group] = {
                k: _spec_to_json(s) for k, s in sflat.items()
            }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)
    _gc(directory, keep)
    return ckpt


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    mesh: Optional[Mesh] = None,
    specs: Optional[Dict[str, Pytree]] = None,
    step: Optional[int] = None,
) -> Tuple[int, int, Dict[str, Pytree]]:
    """-> (step, data_index, state).  Elastic: lays out on the given mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt, MANIFEST)) as f:
        manifest = json.load(f)
    state: Dict[str, Pytree] = {}
    for group in manifest["groups"]:
        with np.load(os.path.join(ckpt, f"{group}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        if mesh is not None and specs is not None and group in specs:
            sflat = _flatten(specs[group])
            placed = {}
            for k, arr in flat.items():
                spec = sflat.get(k, P())
                placed[k] = jax.device_put(arr, NamedSharding(mesh, spec))
            flat = placed
        state[group] = _unflatten(flat)
    return manifest["step"], manifest["data_index"], state


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d))
