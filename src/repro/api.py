"""``repro.api`` — the one import for the predictive offload session API.

The stable, snapshot-tested public surface of the framework (see
``tests/test_api_surface.py``): typed policies, the unified
:class:`Session` submit path, the model-driven ``AUTO`` planner, the
prediction contract (:func:`estimate` / :func:`predict_staging`,
paper §6, error < 15 %), the serving engine, and the multi-tenant
fabric scheduler (:class:`FabricScheduler` / :class:`ClusterLease` /
:class:`ServeTenant` — sessions hold leases on cluster windows instead
of the whole mesh; see the README's "Fabric scheduler" section), and the
fault-tolerance substrate (:class:`FaultPlan` / :class:`FaultInjector` /
:class:`RetryPolicy` — deterministic fault injection, model-driven
deadlines, and the resubmit → backup-window → lease-failover escalation
ladder; README "Fault tolerance"), and the overload substrate
(revocable leases via :meth:`FabricScheduler.preempt`, SLO admission
with the typed :class:`Overloaded` error, and the graceful-degradation
ladder; README "Preemption & overload"), and dependent job graphs
(:meth:`Session.submit_graph` over :class:`GraphNode`/:class:`Ref` —
scoreboarded out-of-order dispatch with device-to-device result
forwarding; README "Dependent job graphs"), and the static analysis
surface (:func:`verify` / :func:`verify_graph` / :func:`verify_policy`
reporting typed :class:`Diagnostic`\\ s with stable ``OFL###`` codes,
the :class:`VerificationError` submit gate, and the
``REPRO_SANITIZE=1`` hazard sanitizer; README "Static verification &
sanitizer"), and the model-driven perf linter (``Session(lint=True)``
/ :func:`repro.analysis.perflint.lint_graph` emitting ``OFLP1##``
:class:`PerfFinding`\\ s with machine-applicable autofix, the
:class:`DiagnosticsLog` ring buffer behind ``Session(diag_limit=)``,
and the ``python -m repro.lint`` CLI with SARIF/JSON export and
baselines; README "Performance linting").

Quickstart::

    from repro.api import AUTO, Residency, Session
    from repro.core import jobs

    sess = Session()                      # every local device
    job = jobs.make_covariance(512, 256)
    instances, _ = jobs.make_instances(job, 16)

    print(sess.estimate(job, batch=16))   # predicted phase breakdown
    handle = sess.submit(job, instances)  # AUTO: tree staging, fused,
    results = handle.wait()               #       pipelined window
    print(handle.explain())               # predicted vs measured

Legacy surface (``offload(job, "resident")``, string ``via=`` /
``staging=`` modes, direct ``OffloadStream`` / ``offload_fused``) keeps
working behind :class:`DeprecationWarning` shims; the README's "Session
API" section has the migration table.
"""

from repro.analysis import (
    Diagnostic,
    DiagnosticsLog,
    Fix,
    PerfFinding,
    SanitizerError,
    Severity,
    UnknownDiagnosticCode,
    VerificationError,
    explain,
    lint,
    lint_graph,
    verify,
    verify_graph,
    verify_policy,
)
from repro.core.fabric import (
    ClusterLease,
    FabricHealth,
    FabricScheduler,
    LeaseError,
    LeaseUnavailable,
    Overloaded,
    PendingLease,
    SchedulerPolicy,
    Tenant,
)
from repro.core.faults import (
    CompletionTimeout,
    FaultError,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    SessionHealth,
    deadline_cycles,
    predict_recovery,
)
from repro.core.jobs import PAPER_JOBS, PaperJob, make_instances
from repro.core.multicast import MulticastRequest
from repro.core.offload import (
    DonatedOperandError,
    JobHandle,
    OffloadConfig,
    OffloadRuntime,
    PlanStats,
)
from repro.core.policy import (
    AUTO,
    Completion,
    InfoDist,
    OffloadPolicy,
    Residency,
    RetryPolicy,
    Staging,
    TenantKind,
)
from repro.core.scoreboard import (
    GraphError,
    GraphNode,
    Ref,
    Scoreboard,
)
from repro.core.session import (
    Estimate,
    Explain,
    GraphHandle,
    PlanDecision,
    Planner,
    ReliableHandle,
    Session,
    SessionHandle,
    estimate,
    predict_staging,
)
from repro.ft import BackupOffload, StepWatchdog, WatchdogConfig, elastic_restore
from repro.serve import ServeConfig, ServeEngine, ServeTenant

__all__ = [
    "AUTO",
    "BackupOffload",
    "ClusterLease",
    "Completion",
    "CompletionTimeout",
    "Diagnostic",
    "DiagnosticsLog",
    "DonatedOperandError",
    "Estimate",
    "Explain",
    "FabricHealth",
    "FabricScheduler",
    "FaultError",
    "FaultInjector",
    "FaultKind",
    "FaultPlan",
    "FaultSpec",
    "Fix",
    "GraphError",
    "GraphHandle",
    "GraphNode",
    "InfoDist",
    "JobHandle",
    "LeaseError",
    "LeaseUnavailable",
    "MulticastRequest",
    "OffloadConfig",
    "OffloadPolicy",
    "OffloadRuntime",
    "Overloaded",
    "PAPER_JOBS",
    "PaperJob",
    "PendingLease",
    "PerfFinding",
    "PlanDecision",
    "PlanStats",
    "Planner",
    "Ref",
    "ReliableHandle",
    "Residency",
    "RetryPolicy",
    "SanitizerError",
    "SchedulerPolicy",
    "Scoreboard",
    "ServeConfig",
    "ServeEngine",
    "ServeTenant",
    "Session",
    "SessionHandle",
    "SessionHealth",
    "Severity",
    "Staging",
    "StepWatchdog",
    "Tenant",
    "TenantKind",
    "UnknownDiagnosticCode",
    "VerificationError",
    "WatchdogConfig",
    "deadline_cycles",
    "elastic_restore",
    "estimate",
    "explain",
    "lint",
    "lint_graph",
    "make_instances",
    "predict_recovery",
    "predict_staging",
    "verify",
    "verify_graph",
    "verify_policy",
]
