"""Training substrate: step builder with microbatching + sharded AdamW."""
from repro.train.step import TrainConfig, build_train_step, train_step_fn
__all__ = ["TrainConfig", "build_train_step", "train_step_fn"]
