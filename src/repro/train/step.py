"""Train-step builder: grads (+microbatch accumulation) + AdamW, sharded.

``build_train_step`` returns a jitted function with explicit in/out
shardings derived from the sharding rules; the same builder serves the
multi-pod dry-run (lower/compile on ShapeDtypeStructs) and the real CPU
training examples.  Every step is an offloaded job in the paper's sense:
the launcher dispatches it through the OffloadRuntime's multicast path —
per-step scalars (step index, LR) ride replicated (phase A/B multicast), and
the loss psum doubles as the completion-unit arrival reduction (phase H).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import batch_specs, dp_axes, param_specs, to_shardings
from repro.models.config import ModelConfig
from repro.models.model import CallConfig, init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import linear_warmup_cosine

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1
    adamw: AdamWConfig = AdamWConfig()
    call: CallConfig = CallConfig()


def make_loss(cfg: ModelConfig, call: CallConfig):
    def f(params, batch):
        total, _ = loss_fn(params, cfg, batch, call)
        return total
    return f


def grads_with_microbatching(
    cfg: ModelConfig, call: CallConfig, microbatches: int
) -> Callable:
    """Gradient accumulation: scan over microbatch slices, f32 accumulators.
    Deferring the optimizer to the end overlaps per-microbatch compute with
    the (GSPMD-inserted) gradient reductions."""
    lf = make_loss(cfg, call)

    def gfn(params: Pytree, batch: Dict) -> Tuple[jnp.ndarray, Pytree]:
        if microbatches <= 1:
            return jax.value_and_grad(lf)(params, batch)

        def slice_mb(i, x):
            mb = x.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            loss_acc, g_acc = carry
            mb = jax.tree.map(lambda x: slice_mb(i, x), batch)
            loss, g = jax.value_and_grad(lf)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(
            body, (jnp.float32(0.0), zeros), jnp.arange(microbatches))
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda x: x * inv, g)

    return gfn


def train_step_fn(cfg: ModelConfig, tcfg: TrainConfig):
    gfn = grads_with_microbatching(cfg, tcfg.call, tcfg.microbatches)

    def step_fn(params: Pytree, opt_state: Pytree, batch: Dict,
                step: jnp.ndarray):
        loss, grads = gfn(params, batch)
        lr = linear_warmup_cosine(
            step, base_lr=tcfg.base_lr, warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps)
        params, opt_state, om = adamw_update(
            grads, opt_state, params, lr, tcfg.adamw)
        metrics = {"loss": loss, "lr": lr, **om,
                   "arrivals": jnp.float32(1.0)}  # completion-unit arrival
        return params, opt_state, metrics

    return step_fn


def build_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    tcfg: TrainConfig,
    batch_shapes: Dict[str, jax.ShapeDtypeStruct],
    donate: bool = True,
):
    """-> (jitted step, param_sharding, opt_sharding, batch_sharding).

    The jitted step has fully explicit in/out shardings so both the dry-run
    (AOT lower/compile) and real execution use the same program.
    """
    key_spec = jax.eval_shape(lambda: jax.random.key(0))
    pshapes = jax.eval_shape(
        functools.partial(init_params, cfg=cfg),
        jax.ShapeDtypeStruct(key_spec.shape, key_spec.dtype),
    )
    pspecs = param_specs(pshapes, mesh)
    oshapes = jax.eval_shape(lambda p: adamw_init(p, tcfg.adamw), pshapes)
    ospecs = {
        "mu": pspecs, "nu": pspecs, "count": P(),
    }
    bspecs = batch_specs(batch_shapes, mesh)

    step = train_step_fn(cfg, tcfg)
    jitted = jax.jit(
        step,
        in_shardings=(
            to_shardings(pspecs, mesh),
            to_shardings(ospecs, mesh),
            to_shardings(bspecs, mesh),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(
            to_shardings(pspecs, mesh),
            to_shardings(ospecs, mesh),
            NamedSharding(mesh, P()),
        ),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, pspecs, ospecs, bspecs
