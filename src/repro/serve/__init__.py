"""Serving substrate: batched prefill/decode engine + continuous batching."""
from repro.serve.engine import (
    ServeConfig, ServeEngine, ServeTenant, build_ragged_step,
    build_serve_step,
)
__all__ = ["ServeConfig", "ServeEngine", "ServeTenant", "build_ragged_step",
           "build_serve_step"]
