"""Batched serving engine: prefill + greedy/temperature decode loop.

``build_serve_step`` produces the jitted one-token decode program (the
dry-run's ``serve_step``) with explicit cache shardings; ``ServeEngine``
drives it host-side with batched requests, async dispatch (multiple
outstanding steps — the paper's multiple-outstanding-jobs pattern, §4.3),
and completion tracking through the CompletionUnit.

Decode fast path (the framework's own offload-overhead fix): the seed
engine's loop was a per-token host round-trip — fetch logits, sample on the
host, ``device_put`` the sampled token back.  That is exactly the phase-A/E
per-job tax the paper kills, so the engine now keeps the token resident:

* ``decode_mode="step"`` (default) — sampling (greedy and temperature, with
  the per-step ``fold_in``) runs *inside* the jitted step; the token and the
  PRNG key never leave the device between steps.  Zero host->device
  transfers per decoded token.
* ``decode_mode="chunk"`` — a ``jax.lax.scan`` over ``decode_chunk`` steps
  amortizes dispatch to **one** XLA launch per chunk; the CompletionUnit
  accounts one job per chunk (the paper's job granularity knob).  A
  trailing remainder shorter than the chunk runs through the single-step
  program, so only two programs are ever compiled.
* ``decode_mode="host"`` — the seed's host-round-trip loop, kept as the
  measurable "before" for ``benchmarks/offload_wallclock.py``.

``ServeEngine.stats`` counts per-token host->device transfers and XLA
dispatches so tests and benchmarks can assert the fast-path properties.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.completion import CompletionUnit
from repro.dist.sharding import batch_specs, cache_specs, param_specs, to_shardings
from repro.models.config import ModelConfig
from repro.models.model import (
    CallConfig, decode_step, init_cache, init_params, prefill,
)

Pytree = Any


def _serve_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    """-> (param specs, cache specs, token NamedSharding)."""
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    cspecs = cache_specs(cache_shapes, mesh)
    key_spec = jax.eval_shape(lambda: jax.random.key(0))
    pshapes = jax.eval_shape(
        lambda k: init_params(k, cfg),
        jax.ShapeDtypeStruct(key_spec.shape, key_spec.dtype))
    pspecs = param_specs(pshapes, mesh)
    tok_sharding = NamedSharding(
        mesh, batch_specs(
            {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}, mesh
        )["tokens"])
    return pspecs, cspecs, tok_sharding


def build_serve_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                     call: CallConfig = CallConfig(moe_no_drop=True),
                     shardings=None):
    """-> (jitted decode step, cache shardings).  tokens: (B, 1) -> logits."""
    pspecs, cspecs, tok_sharding = (
        shardings or _serve_shardings(cfg, mesh, batch, max_len))

    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens, call)

    jitted = jax.jit(
        step,
        in_shardings=(
            to_shardings(pspecs, mesh),
            to_shardings(cspecs, mesh),
            tok_sharding,
        ),
        out_shardings=(
            NamedSharding(mesh, P()),
            to_shardings(cspecs, mesh),
        ),
        donate_argnums=(1,),
    )
    return jitted, cspecs, pspecs


def _sampler(temperature: float):
    """(logits (B, V), key) -> (B,) int32, traced inside the jitted step."""
    def sample(logits, key):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
    return sample


def _decode_sample_body(cfg: ModelConfig, temperature: float,
                        call: CallConfig):
    """The one decode+sample step both resident builders share: decode a
    (B, 1) token, fold the key with the step index, sample the next token.
    Sharing this body is what keeps the single-step and chunk programs on
    the identical key trajectory."""
    sample = _sampler(temperature)

    def body(params, cache, tok, key, i):
        logits, cache = decode_step(params, cfg, cache, tok, call)
        lg = logits[:, 0] if logits.ndim == 3 else logits
        key = jax.random.fold_in(key, i)
        return sample(lg, key), key, cache

    return body


def build_sampling_step(cfg: ModelConfig, mesh: Mesh, batch: int,
                        max_len: int, temperature: float,
                        call: CallConfig = CallConfig(moe_no_drop=True),
                        shardings=None):
    """Device-resident decode+sample: one jitted program per token.

    (params, cache, tok (B,1), key, idx) ->
        (next tok (B,1), key', idx+1, cache').
    The per-step ``fold_in(key, idx)`` happens inside the program; nothing
    crosses the host boundary between steps.
    """
    pspecs, cspecs, tok_sharding = (
        shardings or _serve_shardings(cfg, mesh, batch, max_len))
    body = _decode_sample_body(cfg, temperature, call)
    repl = NamedSharding(mesh, P())

    def step(params, cache, tok, key, idx):
        nxt, key, cache = body(params, cache, tok, key, idx)
        return nxt[:, None], key, idx + 1, cache

    jitted = jax.jit(
        step,
        in_shardings=(
            to_shardings(pspecs, mesh), to_shardings(cspecs, mesh),
            tok_sharding, repl, repl,
        ),
        out_shardings=(tok_sharding, repl, repl, to_shardings(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return jitted, cspecs, tok_sharding


def build_decode_chunk(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                       temperature: float, chunk: int,
                       call: CallConfig = CallConfig(moe_no_drop=True),
                       shardings=None):
    """``lax.scan`` multi-token decode: ``chunk`` tokens per XLA dispatch.

    (params, cache, tok (B,1), key, idx0) ->
        (toks (B, chunk), tok', key', idx0+chunk, cache').
    Step i of the scan folds the key with ``idx0 + i`` — identical key
    trajectory to the single-step program (both run the shared
    ``_decode_sample_body``), so mixing chunked and single-step dispatch
    (e.g. for a remainder) is sampling-equivalent.
    """
    pspecs, cspecs, tok_sharding = (
        shardings or _serve_shardings(cfg, mesh, batch, max_len))
    step_body = _decode_sample_body(cfg, temperature, call)
    repl = NamedSharding(mesh, P())

    def chunk_fn(params, cache, tok, key, idx0):
        def body(carry, i):
            tok, key, cache = carry
            nxt, key, cache = step_body(params, cache, tok, key, i)
            return (nxt[:, None], key, cache), nxt

        (tok, key, cache), toks = jax.lax.scan(
            body, (tok, key, cache), idx0 + jnp.arange(chunk))
        return toks.T, tok, key, idx0 + chunk, cache    # toks: (B, chunk)

    jitted = jax.jit(
        chunk_fn,
        in_shardings=(
            to_shardings(pspecs, mesh), to_shardings(cspecs, mesh),
            tok_sharding, repl, repl,
        ),
        out_shardings=(repl, tok_sharding, repl, repl,
                       to_shardings(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return jitted, cspecs, tok_sharding


@dataclasses.dataclass
class ServeConfig:
    batch: int = 8
    max_len: int = 256
    temperature: float = 0.0         # 0 = greedy
    seed: int = 0
    decode_mode: str = "step"        # "step" | "chunk" | "host" (legacy)
    decode_chunk: int = 8            # tokens per dispatch in "chunk" mode


class ServeEngine:
    """Static-batch decode engine with per-slot generation state."""

    def __init__(self, cfg: ModelConfig, params: Pytree, mesh: Mesh,
                 scfg: ServeConfig, call: CallConfig = CallConfig(moe_no_drop=True)):
        self.cfg, self.scfg, self.call = cfg, scfg, call
        self.mesh = mesh
        self.params = params
        # one _serve_shardings resolution shared by every program builder
        self._shardings = _serve_shardings(cfg, mesh, scfg.batch, scfg.max_len)
        self._tok_sharding = self._shardings[2]
        self.step_fn, self.cspecs, _ = build_serve_step(
            cfg, mesh, scfg.batch, scfg.max_len, call,
            shardings=self._shardings)
        self.unit = CompletionUnit(n_units=8)
        self._jobid = 0
        self._sampled_step = None      # built lazily per decode mode
        self._chunk_fn = None
        self._first_fn = None
        self.stats = {"h2d_token_puts": 0, "xla_dispatches": 0,
                      "tokens_emitted": 0}

    # -- program cache -----------------------------------------------------------

    def _get_sampled_step(self):
        if self._sampled_step is None:
            self._sampled_step, _, _ = build_sampling_step(
                self.cfg, self.mesh, self.scfg.batch, self.scfg.max_len,
                self.scfg.temperature, self.call, shardings=self._shardings)
        return self._sampled_step

    def _get_chunk_fn(self):
        if self._chunk_fn is None:
            self._chunk_fn, _, _ = build_decode_chunk(
                self.cfg, self.mesh, self.scfg.batch, self.scfg.max_len,
                self.scfg.temperature, self.scfg.decode_chunk, self.call,
                shardings=self._shardings)
        return self._chunk_fn

    # -- generation ---------------------------------------------------------------

    def generate(self, prompts: np.ndarray, n_new: int,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None
                 ) -> np.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, n_new) generated ids."""
        b = prompts.shape[0]
        assert b == self.scfg.batch, (b, self.scfg.batch)
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = prefill(
            self.params, self.cfg, batch, self.scfg.max_len, self.call)
        # prefill leaves cache layout to XLA; reshard once to the decode
        # step's cache sharding (phase-E staging, in offload terms)
        cache = jax.device_put(cache, to_shardings(self.cspecs, self.mesh))
        key = jax.random.key(self.scfg.seed)
        mode = self.scfg.decode_mode
        if mode not in ("host", "step", "chunk"):
            raise ValueError(f"decode_mode {mode!r} not in host/step/chunk")
        if mode == "host":
            return self._generate_host_loop(logits, cache, key, n_new)
        return self._generate_resident(logits, cache, key, n_new)

    def _generate_resident(self, logits, cache, key, n_new: int) -> np.ndarray:
        """Device-resident decode: the token never visits the host."""
        if self._first_fn is None:
            sample = _sampler(self.scfg.temperature)
            self._first_fn = jax.jit(lambda lg, k: sample(lg, k)[:, None],
                                     out_shardings=self._tok_sharding)
        tok = self._first_fn(logits[:, -1], key)
        # the prefill-token sample is a real XLA launch emitting token 0
        # (host mode samples it eagerly inside its first loop iteration)
        self.stats["xla_dispatches"] += 1
        self.stats["tokens_emitted"] += 1
        idx = jnp.int32(0)         # fold index, carried on device thereafter
        toks = [tok]
        steps = n_new - 1
        done = 0
        use_chunk = (self.scfg.decode_mode == "chunk"
                     and self.scfg.decode_chunk > 1)
        if use_chunk:
            chunk_fn = self._get_chunk_fn()
            c = self.scfg.decode_chunk
            while steps - done >= c:
                job = self._dispatch_begin()
                ys, tok, key, idx, cache = chunk_fn(
                    self.params, cache, tok, key, idx)
                self._dispatch_end(job, tokens=c)
                toks.append(ys)
                done += c
        if done < steps:
            step_fn = self._get_sampled_step()
            while done < steps:
                job = self._dispatch_begin()
                tok, key, idx, cache = step_fn(
                    self.params, cache, tok, key, idx)
                self._dispatch_end(job, tokens=1)
                toks.append(tok)
                done += 1
        out = np.concatenate([np.asarray(t) for t in toks], axis=1)
        assert out.shape[1] == n_new, (out.shape, n_new)
        return out

    def _generate_host_loop(self, logits, cache, key, n_new: int) -> np.ndarray:
        """The seed path: host-side sampling + per-step token device_put."""
        sample = _sampler(self.scfg.temperature)
        out = []
        tok = sample(logits[:, -1], key)
        for i in range(n_new):
            out.append(tok)
            job = self._dispatch_begin()
            tok_dev = jax.device_put(tok[:, None], self._tok_sharding)
            self.stats["h2d_token_puts"] += 1
            logits, cache = self.step_fn(self.params, cache, tok_dev)
            key = jax.random.fold_in(key, i)
            tok = sample(logits[:, 0] if logits.ndim == 3 else logits, key)
            self._dispatch_end(job, tokens=1)
        return np.stack([np.asarray(t) for t in out], axis=1)

    # -- completion accounting (one offloaded job per dispatch) -------------------

    def _dispatch_begin(self) -> int:
        job = self._jobid
        self._jobid += 1
        self.unit.program(1, job)
        return job

    def _dispatch_end(self, job: int, tokens: int) -> None:
        self.unit.arrive(job, 1)   # the step's fused arrival reduction
        self.unit.collect(job)
        self.stats["xla_dispatches"] += 1
        self.stats["tokens_emitted"] += tokens
