"""Batched serving engine: prefill + greedy/temperature decode loop.

``build_serve_step`` produces the jitted one-token decode program (the
dry-run's ``serve_step``) with explicit cache shardings; ``ServeEngine``
drives it host-side with batched requests, async dispatch (multiple
outstanding steps — the paper's multiple-outstanding-jobs pattern, §4.3),
and completion tracking through the CompletionUnit.

Decode fast path (the framework's own offload-overhead fix): the seed
engine's loop was a per-token host round-trip — fetch logits, sample on the
host, ``device_put`` the sampled token back.  That is exactly the phase-A/E
per-job tax the paper kills, so the engine now keeps the token resident:

* ``decode_mode="step"`` (default) — sampling (greedy and temperature, with
  the per-step ``fold_in``) runs *inside* the jitted step; the token and the
  PRNG key never leave the device between steps.  Zero host->device
  transfers per decoded token.
* ``decode_mode="chunk"`` — a ``jax.lax.scan`` over ``decode_chunk`` steps
  amortizes dispatch to **one** XLA launch per chunk; the CompletionUnit
  accounts one job per chunk (the paper's job granularity knob).  A
  trailing remainder shorter than the chunk runs through the single-step
  program, so only two programs are ever compiled.
* ``decode_mode="host"`` — the seed's host-round-trip loop, kept as the
  measurable "before" for ``benchmarks/offload_wallclock.py``.

Continuous batching (``generate_many``): the static engine pays one full
fixed-shape batch per ``generate`` call — a half-empty batch decodes at
full-batch cost, and a queued request waits for the whole previous batch
to finish.  ``generate_many`` instead runs a slot scheduler over the fixed
decode batch: variable-length prompts are admitted into free slots as they
arrive (a bucketed prefill of ``prompt[:-1]`` is scattered into the slot's
cache rows; the last prompt token becomes the slot's pending decode
token), every step advances *all* occupied slots through one
``decode_step_ragged`` dispatch (per-slot cache positions, so slots at
different generation depths share the program), finished slots retire via
the done-mask and immediately refill from the queue.  The decode batch
stays full under streaming traffic — the offload-stream idea applied to
serving.

``ServeEngine.stats`` counts per-token host->device transfers and XLA
dispatches so tests and benchmarks can assert the fast-path properties.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import broadcast as bc
from repro.core.completion import CompletionUnit
from repro.core.fabric import (
    ClusterLease, FabricScheduler, LeaseUnavailable, Tenant,
)
from repro.core.policy import Staging, TenantKind, coerce_enum
from repro.dist.sharding import batch_specs, cache_specs, param_specs, to_shardings
from repro.models.config import ModelConfig
from repro.models.model import (
    CallConfig, decode_step, decode_step_ragged, init_cache, init_params,
    prefill,
)

Pytree = Any


class _ByteCounter:
    """Duck-typed stats sink for :mod:`repro.core.broadcast` byte counters."""

    def __init__(self):
        self.h2d_bytes = 0
        self.d2d_bytes = 0


def _serve_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    """-> (param specs, cache specs, token NamedSharding)."""
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    cspecs = cache_specs(cache_shapes, mesh)
    key_spec = jax.eval_shape(lambda: jax.random.key(0))
    pshapes = jax.eval_shape(
        lambda k: init_params(k, cfg),
        jax.ShapeDtypeStruct(key_spec.shape, key_spec.dtype))
    pspecs = param_specs(pshapes, mesh)
    tok_sharding = NamedSharding(
        mesh, batch_specs(
            {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}, mesh
        )["tokens"])
    return pspecs, cspecs, tok_sharding


def build_serve_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                     call: CallConfig = CallConfig(moe_no_drop=True),
                     shardings=None):
    """-> (jitted decode step, cache shardings).  tokens: (B, 1) -> logits."""
    pspecs, cspecs, tok_sharding = (
        shardings or _serve_shardings(cfg, mesh, batch, max_len))

    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens, call)

    jitted = jax.jit(
        step,
        in_shardings=(
            to_shardings(pspecs, mesh),
            to_shardings(cspecs, mesh),
            tok_sharding,
        ),
        out_shardings=(
            NamedSharding(mesh, P()),
            to_shardings(cspecs, mesh),
        ),
        donate_argnums=(1,),
    )
    return jitted, cspecs, pspecs


def _sampler(temperature: float):
    """(logits (B, V), key) -> (B,) int32, traced inside the jitted step."""
    def sample(logits, key):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
    return sample


def _decode_sample_body(cfg: ModelConfig, temperature: float,
                        call: CallConfig):
    """The one decode+sample step both resident builders share: decode a
    (B, 1) token, fold the key with the step index, sample the next token.
    Sharing this body is what keeps the single-step and chunk programs on
    the identical key trajectory."""
    sample = _sampler(temperature)

    def body(params, cache, tok, key, i):
        logits, cache = decode_step(params, cfg, cache, tok, call)
        lg = logits[:, 0] if logits.ndim == 3 else logits
        key = jax.random.fold_in(key, i)
        return sample(lg, key), key, cache

    return body


def build_sampling_step(cfg: ModelConfig, mesh: Mesh, batch: int,
                        max_len: int, temperature: float,
                        call: CallConfig = CallConfig(moe_no_drop=True),
                        shardings=None):
    """Device-resident decode+sample: one jitted program per token.

    (params, cache, tok (B,1), key, idx) ->
        (next tok (B,1), key', idx+1, cache').
    The per-step ``fold_in(key, idx)`` happens inside the program; nothing
    crosses the host boundary between steps.
    """
    pspecs, cspecs, tok_sharding = (
        shardings or _serve_shardings(cfg, mesh, batch, max_len))
    body = _decode_sample_body(cfg, temperature, call)
    repl = NamedSharding(mesh, P())

    def step(params, cache, tok, key, idx):
        nxt, key, cache = body(params, cache, tok, key, idx)
        return nxt[:, None], key, idx + 1, cache

    jitted = jax.jit(
        step,
        in_shardings=(
            to_shardings(pspecs, mesh), to_shardings(cspecs, mesh),
            tok_sharding, repl, repl,
        ),
        out_shardings=(tok_sharding, repl, repl, to_shardings(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return jitted, cspecs, tok_sharding


def build_decode_chunk(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                       temperature: float, chunk: int,
                       call: CallConfig = CallConfig(moe_no_drop=True),
                       shardings=None):
    """``lax.scan`` multi-token decode: ``chunk`` tokens per XLA dispatch.

    (params, cache, tok (B,1), key, idx0) ->
        (toks (B, chunk), tok', key', idx0+chunk, cache').
    Step i of the scan folds the key with ``idx0 + i`` — identical key
    trajectory to the single-step program (both run the shared
    ``_decode_sample_body``), so mixing chunked and single-step dispatch
    (e.g. for a remainder) is sampling-equivalent.
    """
    pspecs, cspecs, tok_sharding = (
        shardings or _serve_shardings(cfg, mesh, batch, max_len))
    step_body = _decode_sample_body(cfg, temperature, call)
    repl = NamedSharding(mesh, P())

    def chunk_fn(params, cache, tok, key, idx0):
        def body(carry, i):
            tok, key, cache = carry
            nxt, key, cache = step_body(params, cache, tok, key, i)
            return (nxt[:, None], key, cache), nxt

        (tok, key, cache), toks = jax.lax.scan(
            body, (tok, key, cache), idx0 + jnp.arange(chunk))
        return toks.T, tok, key, idx0 + chunk, cache    # toks: (B, chunk)

    jitted = jax.jit(
        chunk_fn,
        in_shardings=(
            to_shardings(pspecs, mesh), to_shardings(cspecs, mesh),
            tok_sharding, repl, repl,
        ),
        out_shardings=(repl, tok_sharding, repl, repl,
                       to_shardings(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return jitted, cspecs, tok_sharding


def build_ragged_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                      temperature: float,
                      call: CallConfig = CallConfig(moe_no_drop=True),
                      shardings=None):
    """Continuous-batching decode step: per-slot positions + done-mask.

    (params, cache, tok (B,1), pos_b (B,), active (B,), key, idx) ->
        (next tok (B,1), pos_b', key', idx+1, cache').
    Each occupied slot writes/attends its own cache position (see
    ``decode_step_ragged``); free slots (``active == 0``) hold their
    position so their writes stay confined to one stale cell, which the
    next prefill-insert overwrites.
    """
    pspecs, cspecs, tok_sharding = (
        shardings or _serve_shardings(cfg, mesh, batch, max_len))
    sample = _sampler(temperature)
    repl = NamedSharding(mesh, P())

    def step(params, cache, tok, pos_b, active, key, idx):
        logits, cache = decode_step_ragged(params, cfg, cache, tok, pos_b,
                                           call)
        lg = logits[:, 0] if logits.ndim == 3 else logits
        key = jax.random.fold_in(key, idx)
        nxt = sample(lg, key)
        pos_b = pos_b + active.astype(pos_b.dtype)
        return nxt[:, None], pos_b, key, idx + 1, cache

    jitted = jax.jit(
        step,
        in_shardings=(
            to_shardings(pspecs, mesh), to_shardings(cspecs, mesh),
            tok_sharding, repl, repl, repl, repl,
        ),
        out_shardings=(tok_sharding, repl, repl, repl,
                       to_shardings(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return jitted, cspecs, tok_sharding


@dataclasses.dataclass
class ServeConfig:
    batch: int = 8
    max_len: int = 256
    temperature: float = 0.0         # 0 = greedy
    seed: int = 0
    decode_mode: str = "step"        # "step" | "chunk" | "host" (legacy)
    decode_chunk: int = 8            # tokens per dispatch in "chunk" mode
    prefill_bucket: int = 16         # generate_many pads prefills to this
                                     # granularity (bounds compile count)
    staging: Staging = Staging.DIRECT  # replicated-placement strategy for
                                     # weight placement and prefill inserts:
                                     # DIRECT | TREE | TREE_RESHARD
                                     # (repro.core.policy.Staging; the
                                     # serialized host_fanout baseline is an
                                     # offload-runtime measurement device,
                                     # not a serving mode).  Raw strings are
                                     # accepted with a DeprecationWarning.

    def __post_init__(self):
        self.staging = coerce_enum(Staging, self.staging, "staging",
                                   warn_legacy=True)
        if self.staging is Staging.HOST_FANOUT:
            valid = tuple(m.value for m in Staging if m is not Staging.HOST_FANOUT)
            raise ValueError(f"staging {self.staging.value!r} not in {valid}")


class ServeEngine:
    """Static-batch decode engine with per-slot generation state.

    ``params`` may be device-resident (already placed on the mesh) or a
    host pytree; in the latter case call :meth:`place_params` before
    generating — it places the weights under ``scfg.staging`` (the tree
    modes send every replicated leaf over the host link once) and
    records the link bytes in ``stats``.  Skipping it still works (jit
    re-places host params per dispatch) but bypasses the configured
    staging strategy and its byte accounting.
    """

    def __init__(self, cfg: ModelConfig, params: Pytree, mesh: Mesh,
                 scfg: ServeConfig, call: CallConfig = CallConfig(moe_no_drop=True),
                 cluster_ids: Optional[Sequence[int]] = None):
        self.cfg, self.scfg, self.call = cfg, scfg, call
        self.mesh = mesh
        self.params = params
        # the engine's fabric window (global cluster ids, one per mesh
        # device): a lease-holding engine derives its weight-placement
        # fan-out tree from the real placement, so cross-quadrant edges
        # are what the lease actually pays
        self.cluster_ids = (None if cluster_ids is None
                            else tuple(int(c) for c in cluster_ids))
        # one _serve_shardings resolution shared by every program builder
        self._shardings = _serve_shardings(cfg, mesh, scfg.batch, scfg.max_len)
        self._tok_sharding = self._shardings[2]
        self.step_fn, self.cspecs, _ = build_serve_step(
            cfg, mesh, scfg.batch, scfg.max_len, call,
            shardings=self._shardings)
        self.unit = CompletionUnit(n_units=8)
        self._jobid = 0
        self._sampled_step = None      # built lazily per decode mode
        self._chunk_fn = None
        self._first_fn = None
        self._ragged_step = None       # continuous-batching programs
        self._insert_fn = None
        self._prefill_fn = None
        self._stager: Optional[bc.TreeStager] = None   # hierarchical staging
        self.stats = {"h2d_token_puts": 0, "xla_dispatches": 0,
                      "tokens_emitted": 0, "prefill_inserts": 0,
                      "requests_retired": 0, "batch_padded_rows": 0,
                      "h2d_bytes": 0, "d2d_bytes": 0}

    # -- program cache -----------------------------------------------------------

    def _get_sampled_step(self):
        if self._sampled_step is None:
            self._sampled_step, _, _ = build_sampling_step(
                self.cfg, self.mesh, self.scfg.batch, self.scfg.max_len,
                self.scfg.temperature, self.call, shardings=self._shardings)
        return self._sampled_step

    def _get_chunk_fn(self):
        if self._chunk_fn is None:
            self._chunk_fn, _, _ = build_decode_chunk(
                self.cfg, self.mesh, self.scfg.batch, self.scfg.max_len,
                self.scfg.temperature, self.scfg.decode_chunk, self.call,
                shardings=self._shardings)
        return self._chunk_fn

    def _get_ragged_step(self):
        if self._ragged_step is None:
            self._ragged_step, _, _ = build_ragged_step(
                self.cfg, self.mesh, self.scfg.batch, self.scfg.max_len,
                self.scfg.temperature, self.call, shardings=self._shardings)
        return self._ragged_step

    def _get_insert_fn(self):
        if self._insert_fn is None:
            cshard = to_shardings(self.cspecs, self.mesh)

            def ins(cache, k_rows, v_rows, slot):
                k = jax.lax.dynamic_update_slice(
                    cache["k"], k_rows.astype(cache["k"].dtype),
                    (0, slot, 0, 0))
                v = jax.lax.dynamic_update_slice(
                    cache["v"], v_rows.astype(cache["v"].dtype),
                    (0, slot, 0, 0))
                return dict(cache, k=k, v=v)

            self._insert_fn = jax.jit(ins, out_shardings=cshard,
                                      donate_argnums=(0,))
        return self._insert_fn

    def _get_prefill_fn(self):
        # jit caches one program per prefill bucket length
        if self._prefill_fn is None:
            self._prefill_fn = jax.jit(
                lambda p, toks: prefill(p, self.cfg, {"tokens": toks},
                                        self.scfg.max_len, self.call))
        return self._prefill_fn

    # -- hierarchical staging (weight placement + prefill inserts) ----------------

    def _get_stager(self) -> bc.TreeStager:
        if self._stager is None:
            self._stager = bc.TreeStager(list(self.mesh.devices.flat),
                                         cluster_ids=self.cluster_ids)
        return self._stager

    def _put_replicated(self, arr: np.ndarray):
        """Replicated placement under ``scfg.staging``, link bytes counted."""
        sharding = NamedSharding(self.mesh, P())
        if self.scfg.staging in bc.TREE_MODES:
            counted = _ByteCounter()
            out = self._get_stager().put_replicated(
                arr, sharding, reshard=self.scfg.staging == "tree_reshard",
                stats=counted)
            self.stats["h2d_bytes"] += counted.h2d_bytes
            self.stats["d2d_bytes"] += counted.d2d_bytes
            return out
        self.stats["h2d_bytes"] += bc.placement_bytes(arr, sharding)
        return jax.device_put(arr, sharding)

    def place_params(self, host_params: Pytree) -> Pytree:
        """Place host-side parameters onto the mesh and adopt them.

        Under ``staging="tree"`` every fully replicated leaf (1-D scales,
        biases, anything the sharding rules could not split) crosses the
        host link once and fans out device-to-device along the broadcast
        tree; sharded leaves take the direct path.  ``stats["h2d_bytes"]``
        / ``stats["d2d_bytes"]`` record the logical link traffic either
        way, so tests can assert the O(n) -> O(1) weight-placement claim.
        """
        shardings = to_shardings(self._shardings[0], self.mesh)
        counted = _ByteCounter()
        if self.scfg.staging in bc.TREE_MODES:
            placed = bc.place_pytree(
                host_params, shardings, self._get_stager(),
                reshard=self.scfg.staging == "tree_reshard", stats=counted)
        else:
            def put(leaf, sh):
                counted.h2d_bytes += bc.placement_bytes(np.asarray(leaf), sh)
                return jax.device_put(leaf, sh)
            placed = jax.tree_util.tree_map(put, host_params, shardings)
        self.stats["h2d_bytes"] += counted.h2d_bytes
        self.stats["d2d_bytes"] += counted.d2d_bytes
        self.params = placed
        return placed

    # -- generation ---------------------------------------------------------------

    def generate(self, prompts: np.ndarray, n_new: int,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None
                 ) -> np.ndarray:
        """prompts: (b, S_prompt) int32 -> (b, n_new) generated ids.

        ``b`` may be any size up to the configured batch: a sub-batch is
        padded to ``scfg.batch`` (repeating the last prompt row, so every
        padded row is a valid token sequence) and the output sliced back —
        the fixed-shape programs never see a new batch size, so no
        recompile.  Batch rows are computed independently, so padding does
        not change the real rows' tokens.
        """
        b = prompts.shape[0]
        if b > self.scfg.batch:
            raise ValueError(
                f"batch {b} exceeds configured batch {self.scfg.batch}")
        if b < self.scfg.batch:
            pad = self.scfg.batch - b
            self.stats["batch_padded_rows"] += pad
            prompts = np.concatenate(
                [prompts, np.broadcast_to(
                    prompts[-1:], (pad,) + prompts.shape[1:])], axis=0)
            if extra_inputs:
                extra_inputs = {
                    k: np.concatenate(
                        [v, np.broadcast_to(
                            np.asarray(v)[-1:], (pad,) + np.asarray(v).shape[1:])],
                        axis=0)
                    for k, v in extra_inputs.items()}
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = prefill(
            self.params, self.cfg, batch, self.scfg.max_len, self.call)
        # prefill leaves cache layout to XLA; reshard once to the decode
        # step's cache sharding (phase-E staging, in offload terms)
        cache = jax.device_put(cache, to_shardings(self.cspecs, self.mesh))
        key = jax.random.key(self.scfg.seed)
        mode = self.scfg.decode_mode
        if mode not in ("host", "step", "chunk"):
            raise ValueError(f"decode_mode {mode!r} not in host/step/chunk")
        if mode == "host":
            out = self._generate_host_loop(logits, cache, key, n_new)
        else:
            out = self._generate_resident(logits, cache, key, n_new)
        return out[:b]

    def _generate_resident(self, logits, cache, key, n_new: int) -> np.ndarray:
        """Device-resident decode: the token never visits the host."""
        if self._first_fn is None:
            sample = _sampler(self.scfg.temperature)
            self._first_fn = jax.jit(lambda lg, k: sample(lg, k)[:, None],
                                     out_shardings=self._tok_sharding)
        tok = self._first_fn(logits[:, -1], key)
        # the prefill-token sample is a real XLA launch emitting token 0
        # (host mode samples it eagerly inside its first loop iteration)
        self.stats["xla_dispatches"] += 1
        self.stats["tokens_emitted"] += 1
        idx = jnp.int32(0)         # fold index, carried on device thereafter
        toks = [tok]
        steps = n_new - 1
        done = 0
        use_chunk = (self.scfg.decode_mode == "chunk"
                     and self.scfg.decode_chunk > 1)
        if use_chunk:
            chunk_fn = self._get_chunk_fn()
            c = self.scfg.decode_chunk
            while steps - done >= c:
                job = self._dispatch_begin()
                ys, tok, key, idx, cache = chunk_fn(
                    self.params, cache, tok, key, idx)
                self._dispatch_end(job, tokens=c)
                toks.append(ys)
                done += c
        if done < steps:
            step_fn = self._get_sampled_step()
            while done < steps:
                job = self._dispatch_begin()
                tok, key, idx, cache = step_fn(
                    self.params, cache, tok, key, idx)
                self._dispatch_end(job, tokens=1)
                toks.append(tok)
                done += 1
        out = np.concatenate([np.asarray(t) for t in toks], axis=1)
        assert out.shape[1] == n_new, (out.shape, n_new)
        return out

    def _generate_host_loop(self, logits, cache, key, n_new: int) -> np.ndarray:
        """The seed path: host-side sampling + per-step token device_put."""
        sample = _sampler(self.scfg.temperature)
        out = []
        tok = sample(logits[:, -1], key)
        for i in range(n_new):
            out.append(tok)
            job = self._dispatch_begin()
            tok_dev = jax.device_put(tok[:, None], self._tok_sharding)
            self.stats["h2d_token_puts"] += 1
            logits, cache = self.step_fn(self.params, cache, tok_dev)
            key = jax.random.fold_in(key, i)
            tok = sample(logits[:, 0] if logits.ndim == 3 else logits, key)
            self._dispatch_end(job, tokens=1)
        return np.stack([np.asarray(t) for t in out], axis=1)

    # -- continuous batching -------------------------------------------------------

    def generate_many(self, requests: Sequence[Tuple[np.ndarray, int]],
                      arrival_steps: Optional[Sequence[int]] = None
                      ) -> List[np.ndarray]:
        """Continuous batching over ``requests`` = [(prompt, n_new), ...].

        Prompts are variable-length 1-D int32 arrays.  Requests are
        admitted into free slots of the fixed decode batch in arrival
        order; each decode step advances every occupied slot through one
        ``decode_step_ragged`` dispatch; a slot that has emitted its
        ``n_new`` tokens retires and refills from the queue.  Returns the
        (n_new_r,) generated ids per request, in request order.

        ``arrival_steps`` (optional, same length) gives each request the
        earliest decode step at which it may be admitted — an arrival
        trace for throughput benchmarks; steps where the batch is entirely
        idle are skipped, not decoded.

        Greedy outputs are schedule-independent: batch rows are computed
        independently, so a request's tokens do not depend on which other
        requests it shares the batch with (temperature sampling shares one
        key trajectory across the batch and is reproducible per schedule,
        not per request).
        """
        if (self.cfg.family in ("ssm", "hybrid") or self.cfg.mla
                or self.cfg.frontend):
            raise NotImplementedError(
                "continuous batching requires the plain attention family "
                "(ragged per-slot cache positions; modality-prefix "
                "frontends would shift every slot's positions)")
        scfg = self.scfg
        reqs = [(np.asarray(p, np.int32).ravel(), int(m))
                for p, m in requests]
        R = len(reqs)
        arrivals = ([0] * R if arrival_steps is None
                    else [int(a) for a in arrival_steps])
        if len(arrivals) != R:
            raise ValueError(
                f"{len(arrivals)} arrival steps for {R} requests")
        for prompt, m in reqs:
            if prompt.size < 1:
                raise ValueError("empty prompt")
            if m < 1:
                raise ValueError(f"n_new must be >= 1, got {m}")
            if prompt.size - 1 + m > scfg.max_len:
                raise ValueError(
                    f"prompt ({prompt.size}) + n_new ({m}) exceeds "
                    f"max_len {scfg.max_len}")

        step_fn = self._get_ragged_step()
        B = scfg.batch
        cache = jax.device_put(init_cache(self.cfg, B, scfg.max_len),
                               to_shardings(self.cspecs, self.mesh))
        tok = jax.device_put(jnp.zeros((B, 1), jnp.int32),
                             self._tok_sharding)
        pos_b = jnp.zeros((B,), jnp.int32)
        active = jnp.zeros((B,), jnp.int32)
        key = jax.random.key(scfg.seed)
        idx = jnp.zeros((), jnp.int32)

        slots: List[Optional[Dict[str, int]]] = [None] * B
        free = list(range(B))
        order = sorted(range(R), key=lambda r: (arrivals[r], r))
        queue: collections.deque = collections.deque()
        step_log: List[Tuple[Any, List[Tuple[int, int]]]] = []
        t = 0
        pi = 0
        while pi < R or queue or any(s is not None for s in slots):
            while pi < R and arrivals[order[pi]] <= t:
                queue.append(order[pi])
                pi += 1
            # prefill-insert: refill free slots from the queue
            while queue and free:
                r = queue.popleft()
                j = free.pop(0)
                cache, tok, pos_b, active = self._insert(
                    cache, tok, pos_b, active, j, reqs[r][0])
                slots[j] = {"req": r, "remaining": reqs[r][1]}
            if all(s is None for s in slots):
                t = arrivals[order[pi]]     # batch idle: skip to next arrival
                continue
            # one resident decode step advances every occupied slot
            job = self._dispatch_begin()
            tok, pos_b, key, idx, cache = step_fn(
                self.params, cache, tok, pos_b, active, key, idx)
            live = [(j, s["req"]) for j, s in enumerate(slots)
                    if s is not None]
            self._dispatch_end(job, tokens=len(live))
            step_log.append((tok, live))
            for j, s in enumerate(slots):
                if s is None:
                    continue
                s["remaining"] -= 1
                if s["remaining"] == 0:     # done-mask: retire the slot
                    slots[j] = None
                    free.append(j)
                    free.sort()
                    active = active.at[j].set(0)
                    self.stats["requests_retired"] += 1
            t += 1

        # tokens stayed device-resident throughout; one drain at the end
        fetched = jax.device_get([tk for tk, _ in step_log])
        results: List[List[int]] = [[] for _ in range(R)]
        for tk_host, (_, live) in zip(fetched, step_log):
            for j, r in live:
                results[r].append(tk_host[j, 0])
        return [np.asarray(seq, np.int32) for seq in results]

    def _insert(self, cache, tok, pos_b, active, slot: int,
                prompt: np.ndarray):
        """Admit ``prompt`` into ``slot``: bucketed prefill of
        ``prompt[:-1]`` scattered into the slot's cache rows; the last
        prompt token becomes the slot's pending decode token at position
        ``len(prompt) - 1``."""
        s = int(prompt.size)
        if s > 1:
            bucket = max(1, self.scfg.prefill_bucket)
            # bucketed up, but never past the cache length
            sb = min(-(-(s - 1) // bucket) * bucket, self.scfg.max_len)
            padded = np.zeros((1, sb), np.int32)
            padded[0, :s - 1] = prompt[:-1]
            # the bucketed prompt is replicated input to the prefill
            # program; tree staging sends it over the host link once
            _, pcache = self._get_prefill_fn()(self.params,
                                               self._put_replicated(padded))
            cache = self._get_insert_fn()(cache, pcache["k"], pcache["v"],
                                          np.int32(slot))
        tok = tok.at[slot, 0].set(int(prompt[-1]))
        self.stats["h2d_token_puts"] += 1   # the pending prompt token
        pos_b = pos_b.at[slot].set(s - 1)
        active = active.at[slot].set(1)
        self.stats["prefill_inserts"] += 1
        return cache, tok, pos_b, active

    # -- completion accounting (one offloaded job per dispatch) -------------------

    def _dispatch_begin(self) -> int:
        job = self._jobid
        self._jobid += 1
        self.unit.program(1, job)
        return job

    def _dispatch_end(self, job: int, tokens: int) -> None:
        self.unit.arrive(job, 1)   # the step's fused arrival reduction
        self.unit.collect(job)
        self.stats["xla_dispatches"] += 1
        self.stats["tokens_emitted"] += tokens


class ServeTenant:
    """A lease-holding serve tenant: elastic grow/shrink between bursts.

    The pre-scheduler engine owned its mesh for the process lifetime —
    idle decode capacity was dead capacity.  A ``ServeTenant`` instead
    holds a *floor* lease on the :class:`~repro.core.fabric.
    FabricScheduler` and, per decode burst (one ``generate`` /
    ``generate_many`` call), grows toward its preferred ``burst`` size
    using whatever clusters are free, shrinking back to the floor when
    the burst completes — bursty offload tenants get the head-room
    between bursts, exactly the serve/offload fabric split of the PR-5
    scheduler.

    One :class:`ServeEngine` is kept per distinct lease window (the
    scheduler's in-place resizing makes the windows recur), so weight
    placement and compiled programs are warm across burst cycles at the
    cost of one engine per window actually seen.
    """

    def __init__(self, scheduler: FabricScheduler, cfg: ModelConfig,
                 host_params: Pytree, scfg: ServeConfig, *,
                 tenant: str = "serve",
                 floor: int = 1,
                 burst: Optional[int] = None,
                 call: CallConfig = CallConfig(moe_no_drop=True)):
        if floor < 1:
            raise ValueError(f"floor must be >= 1, got {floor}")
        self.scheduler = scheduler
        self.cfg, self.scfg, self.call = cfg, scfg, call
        self.host_params = host_params
        self.floor = floor
        self.burst = scheduler.num_clusters if burst is None else burst
        if self.burst < floor:
            raise ValueError(
                f"burst size {self.burst} below the floor {floor}")
        self.lease: ClusterLease = scheduler.request(
            Tenant(tenant, kind=TenantKind.SERVE), n=floor)
        # the scheduler's overload ladder shrinks elastic serve leases
        # (back to, then below, this floor) before revoking anything
        scheduler.register_elastic(self.lease, floor)
        self._engines: Dict[Tuple[int, ...], ServeEngine] = {}

    def _engine(self) -> ServeEngine:
        key = self.lease.clusters
        eng = self._engines.get(key)
        if eng is None:
            devs = self.lease.devices
            mesh = Mesh(np.asarray(devs).reshape(len(devs), 1),
                        ("data", "model"))
            eng = ServeEngine(self.cfg, self.host_params, mesh, self.scfg,
                              self.call, cluster_ids=key)
            eng.place_params(self.host_params)
            self._engines[key] = eng
        return eng

    def _sync(self) -> None:
        # a fabric failover (FabricScheduler.fail_clusters) replaces the
        # lease object in place — same id, healthy window — leaving this
        # tenant's reference stale; refresh it before keying any
        # scheduler call (or engine cache) on the window
        cur = self.scheduler.current_lease(self.lease)
        if cur is not None and cur is not self.lease:
            self.lease = cur
        # overload pressure may have shrunk the floor itself (graceful
        # degradation); adopt the scheduler's view so _grow/_shrink
        # target the degraded floor instead of fighting the ladder
        floor = self.scheduler.elastic_floor(self.lease)
        if floor is not None and floor != self.floor:
            self.floor = floor

    def _grow(self) -> None:
        self._sync()
        # the global free count is an upper bound; the free space may be
        # fragmented into windows smaller than it, so walk the target
        # down until a contiguous grow (or relocation) fits — a burst
        # takes the largest window available, never fails the generate
        headroom = len(self.scheduler.free_clusters())
        target = max(self.floor, min(self.burst, self.lease.n + headroom))
        while target > self.lease.n:
            try:
                self.lease = self.scheduler.resize(self.lease, target)
                return
            except LeaseUnavailable:
                target -= 1

    def _shrink(self) -> None:
        self._sync()
        if self.lease.n > self.floor:
            self.lease = self.scheduler.resize(self.lease, self.floor)
        elif self.lease.n < self.floor:
            # a failover or the overload ladder left the lease under the
            # floor; growing back is best-effort while pressure persists
            try:
                self.lease = self.scheduler.resize(self.lease, self.floor)
            except LeaseUnavailable:
                pass

    def generate(self, prompts: np.ndarray, n_new: int,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None
                 ) -> np.ndarray:
        """One decode burst: grow the lease, generate, shrink back."""
        self._grow()
        try:
            return self._engine().generate(prompts, n_new, extra_inputs)
        finally:
            self._shrink()

    def generate_many(self, requests: Sequence[Tuple[np.ndarray, int]],
                      arrival_steps: Optional[Sequence[int]] = None
                      ) -> List[np.ndarray]:
        """One continuous-batching burst under the elastic lease."""
        self._grow()
        try:
            return self._engine().generate_many(requests, arrival_steps)
        finally:
            self._shrink()

    @property
    def windows(self) -> Tuple[Tuple[int, ...], ...]:
        """Every lease window this tenant has served a burst on (each
        backs one warm engine), smallest first."""
        return tuple(sorted(self._engines, key=len))

    @property
    def peak_burst(self) -> int:
        """The widest burst window served so far (clusters)."""
        return max((len(w) for w in self._engines), default=self.lease.n)

    @property
    def stats(self) -> Dict[str, int]:
        """Engine counters summed across every lease window served."""
        agg: Dict[str, int] = {}
        for eng in self._engines.values():
            for k, v in eng.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def close(self) -> None:
        """Release the floor lease (the tenant leaves the fabric)."""
        self._sync()
        self.scheduler.unregister_elastic(self.lease)
        if self.lease.active:
            self.lease.release()
