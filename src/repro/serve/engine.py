"""Batched serving engine: prefill + greedy/temperature decode loop.

``build_serve_step`` produces the jitted one-token decode program (the
dry-run's ``serve_step``) with explicit cache shardings; ``ServeEngine``
drives it host-side with batched requests, async dispatch (multiple
outstanding steps — the paper's multiple-outstanding-jobs pattern, §4.3),
and completion tracking through the CompletionUnit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.completion import CompletionUnit
from repro.dist.sharding import batch_specs, cache_specs, param_specs, to_shardings
from repro.models.config import ModelConfig
from repro.models.model import (
    CallConfig, decode_step, init_cache, init_params, prefill,
)

Pytree = Any


def build_serve_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                     call: CallConfig = CallConfig(moe_no_drop=True)):
    """-> (jitted decode step, cache shardings).  tokens: (B, 1) -> logits."""
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    cspecs = cache_specs(cache_shapes, mesh)
    key_spec = jax.eval_shape(lambda: jax.random.key(0))
    pshapes = jax.eval_shape(
        lambda k: init_params(k, cfg),
        jax.ShapeDtypeStruct(key_spec.shape, key_spec.dtype))
    pspecs = param_specs(pshapes, mesh)

    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens, call)

    tok_sharding = NamedSharding(
        mesh, batch_specs(
            {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}, mesh
        )["tokens"])
    jitted = jax.jit(
        step,
        in_shardings=(
            to_shardings(pspecs, mesh),
            to_shardings(cspecs, mesh),
            tok_sharding,
        ),
        out_shardings=(
            NamedSharding(mesh, P()),
            to_shardings(cspecs, mesh),
        ),
        donate_argnums=(1,),
    )
    return jitted, cspecs, pspecs


@dataclasses.dataclass
class ServeConfig:
    batch: int = 8
    max_len: int = 256
    temperature: float = 0.0         # 0 = greedy
    seed: int = 0


class ServeEngine:
    """Static-batch decode engine with per-slot generation state."""

    def __init__(self, cfg: ModelConfig, params: Pytree, mesh: Mesh,
                 scfg: ServeConfig, call: CallConfig = CallConfig(moe_no_drop=True)):
        self.cfg, self.scfg, self.call = cfg, scfg, call
        self.mesh = mesh
        self.params = params
        self.step_fn, self.cspecs, _ = build_serve_step(
            cfg, mesh, scfg.batch, scfg.max_len, call)
        self.unit = CompletionUnit(n_units=8)
        self._jobid = 0

    def generate(self, prompts: np.ndarray, n_new: int,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None
                 ) -> np.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, n_new) generated ids."""
        b = prompts.shape[0]
        assert b == self.scfg.batch, (b, self.scfg.batch)
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = prefill(
            self.params, self.cfg, batch, self.scfg.max_len, self.call)
        # prefill leaves cache layout to XLA; reshard once to the decode
        # step's cache sharding (phase-E staging, in offload terms)
        cache = jax.device_put(cache, to_shardings(self.cspecs, self.mesh))
        key = jax.random.key(self.scfg.seed)
        from jax.sharding import NamedSharding
        from repro.dist.sharding import batch_specs as _bs
        tok_sh = NamedSharding(self.mesh, _bs(
            {"t": jax.ShapeDtypeStruct((self.scfg.batch, 1), jnp.int32)},
            self.mesh)["t"])
        out = []
        tok = self._sample(logits[:, -1], key)
        for i in range(n_new):
            out.append(tok)
            job = self._jobid
            self._jobid += 1
            self.unit.program(1, job)
            tok_dev = jax.device_put(tok[:, None], tok_sh)
            logits, cache = self.step_fn(self.params, cache, tok_dev)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits[:, 0] if logits.ndim == 3 else logits, key)
            self.unit.arrive(job, 1)   # step's fused arrival reduction
            assert self.unit.clear() == job
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)
