"""Elastic rescale: resume a run on a different device count.

The checkpoint stores logical PartitionSpecs, and the data pipeline is a
pure function of (seed, index), so rescaling is:

  1. build a new mesh over the surviving devices,
  2. re-derive the shardings for that mesh (divisibility fallbacks re-apply),
  3. restore the checkpoint with those shardings,
  4. continue from the recorded step/data index.

Global batch stays constant (per-device batch grows when devices shrink), so
the loss trajectory is unchanged up to reduction order (asserted bit-level
for matched reduction shapes in tests/test_checkpoint.py).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import store
from repro.dist.sharding import param_specs

Pytree = Any


def make_data_mesh(devices: Optional[Sequence[jax.Device]] = None) -> Mesh:
    devs = list(devices if devices is not None else jax.devices())
    return Mesh(np.asarray(devs), ("data",))


def elastic_restore(
    directory: str,
    devices: Sequence[jax.Device],
    param_shapes: Pytree,
    step: Optional[int] = None,
) -> Tuple[int, int, Dict[str, Pytree], Mesh]:
    """-> (step, data_index, state laid out on the new mesh, mesh)."""
    mesh = make_data_mesh(devices)
    pspecs = param_specs(param_shapes, mesh)
    specs = {"params": pspecs, "opt": {"mu": pspecs, "nu": pspecs,
                                       "count": jax.sharding.PartitionSpec()}}
    step, data_index, state = store.restore(directory, mesh, specs, step)
    return step, data_index, state, mesh
