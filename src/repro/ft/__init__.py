"""Fault tolerance: watchdog, straggler mitigation, elastic rescale.

The deterministic fault-injection substrate and the session-level
escalation ladder live in :mod:`repro.core.faults` (re-exported from
``repro.api``); this package carries the wallclock-domain companions —
the step watchdog, speculative backup offload, and elastic restore.
"""

from repro.ft.straggler import BackupOffload, StepWatchdog, WatchdogConfig
from repro.ft.elastic import elastic_restore

__all__ = ["BackupOffload", "StepWatchdog", "WatchdogConfig",
           "elastic_restore"]
