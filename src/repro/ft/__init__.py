"""Fault tolerance: watchdog, straggler mitigation, elastic rescale."""

from repro.ft.straggler import BackupOffload, StepWatchdog
from repro.ft.elastic import elastic_restore

__all__ = ["BackupOffload", "StepWatchdog", "elastic_restore"]
