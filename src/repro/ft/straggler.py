"""Straggler mitigation + step watchdog.

Two mechanisms, both built on the paper's completion machinery:

* ``StepWatchdog`` — host-side deadline on job completion.  The completion
  unit tells the host *which* job is late and how many arrivals are missing
  (``CompletionUnit.outstanding()``), turning "the step hangs" into an
  actionable signal: reissue, rescale, or abort.  Deadlines adapt to a
  rolling latency percentile, so slow-but-progressing steps are not killed.
* ``BackupOffload`` — speculative re-execution for the offload runtime: a
  job is dispatched to a primary cluster subset and, if the watchdog trips,
  re-dispatched to a disjoint backup subset (selected with the paper's
  address-mask encoding); the first completion wins.  This is the classical
  backup-worker defence, expressed in offload-runtime terms.

Failure injection for tests is deterministic: a ``delay_hook`` delays the
host's observation of completion, simulating a straggling cluster without
real nondeterminism.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.jobs import PaperJob
from repro.core.offload import JobHandle, OffloadRuntime


@dataclasses.dataclass
class WatchdogConfig:
    deadline_factor: float = 3.0      # × rolling p50 latency
    min_deadline_s: float = 0.05
    history: int = 32


class StepWatchdog:
    """Rolling-latency deadline tracker for dispatched jobs/steps.

    ``estimate`` seeds the cold-start deadline from a model prediction
    (e.g. a §6 ``Session.estimate`` converted to this watchdog's time
    unit): before any latency history exists the deadline is
    ``deadline_factor × estimate``.  Without a seed the cold deadline is
    unbounded — lateness is undecidable with neither a model nor a
    history, so nothing trips (the old ``min_deadline_s * 10`` magic
    guessed instead).
    """

    def __init__(self, cfg: Optional[WatchdogConfig] = None,
                 estimate: Optional[float] = None):
        # a fresh config per instance: a shared default instance would
        # alias cfg mutations across every watchdog in the process
        self.cfg = cfg if cfg is not None else WatchdogConfig()
        self.estimate = estimate
        self._lat: List[float] = []

    def deadline(self) -> float:
        if not self._lat:
            if self.estimate is not None:
                return max(self.cfg.min_deadline_s,
                           self.cfg.deadline_factor * self.estimate)
            return float("inf")
        p50 = float(np.median(self._lat))
        return max(self.cfg.min_deadline_s, self.cfg.deadline_factor * p50)

    def observe(self, latency_s: float) -> None:
        self._lat.append(latency_s)
        if len(self._lat) > self.cfg.history:
            self._lat.pop(0)

    def is_late(self, started_at: float, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return (now - started_at) > self.deadline()


class BackupOffload:
    """Speculative backup execution over disjoint cluster subsets."""

    def __init__(self, runtime: OffloadRuntime,
                 watchdog: Optional[StepWatchdog] = None,
                 delay_hook: Optional[Callable[[JobHandle], float]] = None):
        self.rt = runtime
        self.watchdog = watchdog or StepWatchdog()
        self.delay_hook = delay_hook or (lambda h: 0.0)
        self.reissues = 0

    def run(self, job: PaperJob, seed: int, primary: Sequence[int],
            backup: Sequence[int]):
        """Offload to `primary`; if the observation is late, race `backup`."""
        if set(primary) & set(backup):
            raise ValueError("primary and backup cluster sets must be disjoint")
        operands, expected = job.make_instance(seed)
        t0 = time.monotonic()
        h1 = self.rt.offload(job, operands, clusters=list(primary))
        # Deterministic straggler simulation: the hook returns an artificial
        # extra latency for this handle (0 = healthy).
        simulated = self.delay_hook(h1)
        late = self.watchdog.is_late(t0 - simulated, now=time.monotonic())
        if late:
            self.reissues += 1
            h2 = self.rt.offload(job, operands, clusters=list(backup))
            result = h2.wait()
            # The primary's eventual arrivals must not corrupt the unit: the
            # runtime tracked it under its own job id.
            h1.wait()
        else:
            result = h1.wait()
        self.watchdog.observe(time.monotonic() - t0 - simulated)
        return result, expected
