"""Chunked linear-recurrence (SSM scan) Pallas TPU kernel.

The falcon-mamba / zamba2 train cells are memory-bound on materialized
(B, chunk, d_inner, d_state) state tiles (EXPERIMENTS.md §Roofline): the XLA
path writes every per-step state to HBM at fusion boundaries.  This kernel
keeps the recurrence state in VMEM and emits only the (B, S, d_inner)
contraction output — the same substitution the flash kernel makes for
attention.

Computes, per (batch, channel-block):

    h_t = a_t ⊙ h_{t-1} + b_t          h ∈ R^{d_blk × N}
    y_t = Σ_n h_t[:, n] · c_t[n]       y ∈ R^{d_blk}

Grid: (B, d_inner/block_d, S/chunk) with the sequence axis innermost —
the (block_d, N) state carries across chunk steps in a VMEM scratch
accumulator, never touching HBM.  Inside a chunk the recurrence runs as an
fori_loop over time steps on VMEM-resident tiles (the TPU adaptation of the
CUDA selective-scan kernel's shared-memory tiling; a log-depth associative
formulation is a further hillclimb).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, pad_to, round_up


def _ssm_kernel(a_ref, b_ref, c_ref, y_ref, h_ref, *, chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        # a, b: (1, chunk, d_blk, N); c: (1, chunk, N)
        a_t = a_ref[0, t]                        # (d_blk, N)
        b_t = b_ref[0, t]
        c_t = c_ref[0, t]                        # (N,)
        h = a_t * h + b_t
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def ssm_scan(
    a: jnp.ndarray,     # (B, S, D, N) decay
    b: jnp.ndarray,     # (B, S, D, N) input
    c: jnp.ndarray,     # (B, S, N)    output projection
    *,
    block_d: int = 512,
    chunk: int = 64,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """-> y (B, S, D) with y_t = Σ_n h_t[d, n] c_t[n]."""
    if interpret is None:
        interpret = interpret_default()
    B, S, D, N = a.shape
    bd = min(block_d, round_up(D, 8))
    Dp = round_up(D, bd)
    Sp = round_up(S, chunk)
    # pad decays with 1 and inputs with 0 so padded steps hold state
    a2 = jnp.pad(a, ((0, 0), (0, Sp - S), (0, Dp - D), (0, 0)),
                 constant_values=1.0)
    b2 = pad_to(b, (B, Sp, Dp, N))
    c2 = pad_to(c, (B, Sp, N))

    y = pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=(B, Dp // bd, Sp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, bd, N), lambda bi, di, si: (bi, si, di, 0)),
            pl.BlockSpec((1, chunk, bd, N), lambda bi, di, si: (bi, si, di, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, di, si: (bi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Dp), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(a2, b2, c2)
    return y[:, :S, :D]
