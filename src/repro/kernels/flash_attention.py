"""Causal flash attention Pallas TPU kernel (online softmax, O(S) memory).

The framework's phase-F hot spot: 32k-token prefill is quadratic in HBM
traffic with naive attention; this kernel streams (block_q × block_kv) score
tiles through VMEM with the standard online-softmax recurrence, so the S×S
score matrix never materializes.

TPU adaptation notes:
  * running max/denominator are kept as (block_q, 128) f32 VMEM scratch with
    replicated lanes (TPU vector layouts want the 128-lane grain; a (bq, 1)
    scalar column would be re-laid-out on every op);
  * masks are built from 2-D ``broadcasted_iota`` (1-D iota does not lower on
    TPU); KV padding beyond the true sequence length is masked the same way;
  * tiles strictly above the causal diagonal are skipped via ``pl.when`` on
    the grid indices — with the kv-innermost grid this prunes ~half the work
    at no bookkeeping cost (block shapes are the §Perf hillclimbing knob).

GQA is handled by the wrapper in ops.py (KV heads are repeated to query
heads before the call; XLA fuses the broadcast into the block gather).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, min_tile, pad_to, round_up

NEG_INF = -1e30
LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, kv_steps: int, block_q: int, block_kv: int, sm_scale: float,
    causal: bool, skv_real: int,
):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        # Skip tiles strictly above the causal diagonal.
        live = ki * block_kv <= qi * block_q + block_q - 1
    else:
        live = ki >= 0  # always

    @pl.when(live)
    def _tile():
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bkv, d)
        v = v_ref[0].astype(jnp.float32)           # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

        cols = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        mask = cols < skv_real                     # KV padding never attends
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            mask = mask & (rows >= cols)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0:1]                      # (bq, 1), lanes replicated
        l_prev = l_ref[:, 0:1]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)                      # (bq, bkv)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ki == kv_steps - 1)
    def _flush():
        l = l_ref[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)             # fully-masked (padded) rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret")
)
def flash_attention(
    q: jnp.ndarray,          # (B, H, Sq, D)
    k: jnp.ndarray,          # (B, H, Skv, D)
    v: jnp.ndarray,          # (B, H, Skv, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = interpret_default()
    if q.ndim != 4 or k.shape != v.shape or q.shape[:2] != k.shape[:2]:
        raise ValueError(f"flash shapes q={q.shape} k={k.shape} v={v.shape}")
    b, h, sq, d = q.shape
    skv = k.shape[2]
    sub, _ = min_tile(q.dtype)
    bq = min(block_q, round_up(sq, sub))
    bkv = min(block_kv, round_up(skv, sub))
    sqp, skvp = round_up(sq, bq), round_up(skv, bkv)
    dp = round_up(d, LANES)
    sm_scale = 1.0 / (d ** 0.5)

    qp = pad_to(q.reshape(b * h, sq, d), (b * h, sqp, dp))
    kp = pad_to(k.reshape(b * h, skv, d), (b * h, skvp, dp))
    vp = pad_to(v.reshape(b * h, skv, d), (b * h, skvp, dp))
    kv_steps = skvp // bkv
    grid = (b * h, sqp // bq, kv_steps)

    kernel = functools.partial(
        _flash_kernel,
        kv_steps=kv_steps,
        block_q=bq,
        block_kv=bkv,
        sm_scale=sm_scale,
        causal=causal,
        skv_real=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dp), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bkv, dp), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, bkv, dp), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dp), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, dp), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :d].reshape(b, h, sq, d)
