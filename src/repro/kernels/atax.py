"""ATAX Pallas TPU kernel: y = Aᵀ (A x)  (PolyBench, paper §5.1).

TPU adaptation: instead of the paper's two-pass Snitch mapping (duplicated
A·x, then distributed Aᵀ·tmp), the kernel fuses both matvecs into one sweep
over row blocks of A — each (bm, N) block computes its tmp chunk on the MXU
and immediately accumulates its rank-bm update Aᵀ_blk · tmp_blk into the
output held in a VMEM accumulator.  A is read exactly once from HBM (the
paper's mapping reads it twice), halving the memory-roofline term.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, min_tile, pad_to, round_up


def _atax_kernel(a_ref, x_ref, y_ref, acc_ref, *, m_steps: int):
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_blk = a_ref[...]
    # tmp_blk = A_blk @ x : (bm,)  — keep 2-D (bm, 1) for the MXU.
    tmp = jnp.dot(a_blk, x_ref[...].T, preferred_element_type=jnp.float32)
    # rank-bm update: y += A_blkᵀ @ tmp_blk : (1, N)
    acc_ref[...] += jnp.dot(tmp.T, a_blk, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == m_steps - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def atax(
    a: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = interpret_default()
    if a.ndim != 2 or x.ndim != 1 or a.shape[1] != x.shape[0]:
        raise ValueError(f"atax shapes {a.shape}, {x.shape}")
    m, n = a.shape
    sub, lane = min_tile(a.dtype)
    bm = min(block_m, round_up(m, sub))
    mp = round_up(m, bm)
    np_ = round_up(n, lane)
    a2 = pad_to(a, (mp, np_))
    x2 = pad_to(x, (np_,)).reshape(1, np_)
    m_steps = mp // bm

    y2 = pl.pallas_call(
        functools.partial(_atax_kernel, m_steps=m_steps),
        grid=(m_steps,),
        in_specs=[
            pl.BlockSpec((bm, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, np_), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, np_), jnp.float32)],
        interpret=interpret,
    )(a2, x2)
    return y2[0, :n]
