"""Pallas TPU kernels for the paper's compute hot spots + framework hot spots.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), with ops.py as the
jit'd entry points (impl switch pallas/xla/auto) and ref.py the pure-jnp
oracles.  All kernels are validated against ref.py with interpret=True on CPU
(tests/test_kernels.py) and target TPU tiling (MXU 128×128, (8,128) VREGs).
"""

from repro.kernels import ops, ref
from repro.kernels.atax import atax
from repro.kernels.axpy import axpy
from repro.kernels.covariance import covariance
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.ssm_scan import ssm_scan

__all__ = ["atax", "axpy", "covariance", "flash_attention", "matmul", "ops", "ref", "ssm_scan"]
