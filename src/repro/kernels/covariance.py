"""Covariance Pallas TPU kernel: cov(M×M) of an M×N data matrix (PolyBench).

TPU adaptation: a SYRK-shaped kernel.  The row means are computed by a cheap
first pass (pure XLA — it is bandwidth-trivial); the Pallas kernel then
computes centred(i)·centred(j)ᵀ output tiles on the MXU, streaming (bm, N)
row panels of the data through VMEM.  Grid is 2-D over output tiles; the
row panels are re-read N_tiles times, which is the roofline-optimal choice
whenever M ≤ VMEM panel budget (napkin math in benchmarks/kernel_bench.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, min_tile, pad_to, round_up


def _cov_kernel(ci_ref, cj_ref, o_ref, *, denom: float):
    o_ref[...] = (
        jnp.dot(ci_ref[...], cj_ref[...].T, preferred_element_type=jnp.float32)
        / denom
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def covariance(
    data: jnp.ndarray,
    *,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = interpret_default()
    if data.ndim != 2:
        raise ValueError(f"covariance wants (M, N), got {data.shape}")
    m, n = data.shape
    if n < 2:
        raise ValueError("need at least 2 samples")
    sub, lane = min_tile(data.dtype)
    bm = min(block_m, round_up(m, sub))
    mp = round_up(m, bm)
    np_ = round_up(n, lane)

    centred = data - jnp.mean(data, axis=1, keepdims=True)
    # Zero-padding the sample axis is safe: padded columns contribute 0 to the
    # dot products; padded rows produce discarded tiles.
    c2 = pad_to(centred.astype(data.dtype), (mp, np_))
    steps = mp // bm

    out = pl.pallas_call(
        functools.partial(_cov_kernel, denom=float(n - 1)),
        grid=(steps, steps),
        in_specs=[
            pl.BlockSpec((bm, np_), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, np_), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), data.dtype),
        interpret=interpret,
    )(c2, c2)
    return out[:m, :m]
