"""Tiled matmul Pallas TPU kernel: C[M,N] = A[M,K] @ B[K,N]  (BLAS-3, §5.1).

TPU adaptation of the paper's Snitch dgemm: MXU-aligned 128×128×128 tiles,
K-innermost grid with a float32 VMEM accumulator (the MXU accumulates in
f32 regardless of input dtype), revolving A/B blocks HBM→VMEM via BlockSpec
pipelining.  Block shapes are the hillclimbing knob (§Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, min_tile, pad_to, round_up


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = interpret_default()
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    sub, lane = min_tile(a.dtype)
    bm = min(block_m, round_up(m, sub))
    bn = min(block_n, round_up(n, lane))
    bk = min(block_k, round_up(k, lane))
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    a2 = pad_to(a, (mp, kp))
    b2 = pad_to(b, (kp, np_))
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a2, b2)
    return out[:m, :n]
