"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax.numpy as jnp


def axpy(x: jnp.ndarray, y: jnp.ndarray, alpha) -> jnp.ndarray:
    return jnp.asarray(alpha, x.dtype) * x + y


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(a.dtype)


def atax(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    a32 = a.astype(jnp.float32)
    return (a32.T @ (a32 @ x.astype(jnp.float32))).astype(a.dtype)


def covariance(data: jnp.ndarray) -> jnp.ndarray:
    d32 = data.astype(jnp.float32)
    centred = d32 - jnp.mean(d32, axis=1, keepdims=True)
    return (centred @ centred.T / (data.shape[1] - 1)).astype(data.dtype)


def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True
) -> jnp.ndarray:
    """Naive O(S²) attention, f32 accumulation — the flash oracle."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Sequential oracle for the SSM scan kernel: h_t = a_t·h_{t-1} + b_t,
    y_t = Σ_n h_t[:, n]·c_t[n].  a, b: (B,S,D,N); c: (B,S,N) -> (B,S,D)."""
    import jax

    def step(h, abc):
        a_t, b_t, c_t = abc
        h = a_t * h + b_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    h0 = jnp.zeros(a.shape[:1] + a.shape[2:], jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (a.astype(jnp.float32).swapaxes(0, 1),
         b.astype(jnp.float32).swapaxes(0, 1),
         c.astype(jnp.float32).swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(a.dtype)
