"""AXPY Pallas TPU kernel: z = alpha * x + y  (BLAS-1, paper §5.1).

TPU adaptation: the 1-D vector is viewed as (rows, 1024) lane-aligned tiles
living in VMEM; each grid step streams one (block_rows, 1024) tile through
the VPU.  alpha arrives in SMEM as a scalar-prefetch operand — the analogue
of the paper's job-argument word (it is *job information*, not an operand,
exactly the distinction §3.2 draws).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, min_tile, pad_to, round_up

LANES = 1024          # 8 * 128: one f32 VREG row of 8 sublanes
DEFAULT_BLOCK_ROWS = 8


def _axpy_kernel(alpha_ref, x_ref, y_ref, z_ref):
    alpha = alpha_ref[0].astype(jnp.float32)
    z_ref[...] = (
        alpha * x_ref[...].astype(jnp.float32) + y_ref[...].astype(jnp.float32)
    ).astype(z_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def axpy(
    x: jnp.ndarray,
    y: jnp.ndarray,
    alpha: jnp.ndarray | float,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = interpret_default()
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"axpy wants equal 1-D shapes, got {x.shape}, {y.shape}")
    n = x.shape[0]
    sub, _ = min_tile(x.dtype)
    rows_grain = max(block_rows, sub)
    padded = round_up(max(n, 1), LANES * rows_grain)
    rows = padded // LANES
    x2 = pad_to(x, (padded,)).reshape(rows, LANES)
    y2 = pad_to(y, (padded,)).reshape(rows, LANES)
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1)

    grid = (rows // rows_grain,)
    z2 = pl.pallas_call(
        _axpy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((rows_grain, LANES), lambda i, *_: (i, 0)),
                pl.BlockSpec((rows_grain, LANES), lambda i, *_: (i, 0)),
            ],
            out_specs=pl.BlockSpec((rows_grain, LANES), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), x.dtype),
        interpret=interpret,
    )(alpha_arr, x2, y2)
    return z2.reshape(padded)[:n]
