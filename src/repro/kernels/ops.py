"""Unified jit'd entry points for the Pallas kernels.

Every op takes ``impl`` ∈ {"pallas", "xla", "auto"}:
  * "pallas" — the TPU kernel (interpret mode automatically off-TPU);
  * "xla"    — the pure-jnp oracle (the dry-run path: TPU Pallas kernels do
               not lower on the CPU backend);
  * "auto"   — pallas on TPU, xla elsewhere (the framework default).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.atax import atax as _atax_pallas
from repro.kernels.axpy import axpy as _axpy_pallas
from repro.kernels.covariance import covariance as _cov_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.matmul import matmul as _matmul_pallas

IMPLS = ("pallas", "xla", "auto")


def _resolve(impl: str) -> str:
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def axpy(x, y, alpha, *, impl: str = "auto", **kw) -> jnp.ndarray:
    if _resolve(impl) == "pallas":
        return _axpy_pallas(x, y, alpha, **kw)
    return ref.axpy(x, y, alpha)


def matmul(a, b, *, impl: str = "auto", **kw) -> jnp.ndarray:
    if _resolve(impl) == "pallas":
        return _matmul_pallas(a, b, **kw)
    return ref.matmul(a, b)


def atax(a, x, *, impl: str = "auto", **kw) -> jnp.ndarray:
    if _resolve(impl) == "pallas":
        return _atax_pallas(a, x, **kw)
    return ref.atax(a, x)


def covariance(data, *, impl: str = "auto", **kw) -> jnp.ndarray:
    if _resolve(impl) == "pallas":
        return _cov_pallas(data, **kw)
    return ref.covariance(data)


def attention(
    q, k, v, *, causal: bool = True, impl: str = "auto", **kw
) -> jnp.ndarray:
    """Multi-head attention with GQA support: k/v may have fewer heads than q
    (q heads must be a multiple); KV heads are repeated before the kernel."""
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        if hq % hkv:
            raise ValueError(f"GQA heads {hq} not a multiple of {hkv}")
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if _resolve(impl) == "pallas":
        return _flash_pallas(q, k, v, causal=causal, **kw)
    return ref.attention(q, k, v, causal=causal)
