"""Shared helpers for the Pallas TPU kernels.

Hardware adaptation note (DESIGN.md §2): the paper's kernels are double
precision on Snitch FPUs.  TPU MXU/VPU have no fp64 datapath, so the TPU
adaptation targets float32 (and bfloat16 where numerically safe); the fp64
offload jobs keep the XLA path.  Block shapes honour the TPU tiling grain —
(8, 128) for f32, (16, 128) for bf16 — and MXU-friendly 128×128 tiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def interpret_default() -> bool:
    """Pallas TPU kernels run in interpret mode off-TPU (CPU CI validation)."""
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def min_tile(dtype) -> tuple:
    """Minimum TPU tile (sublane, lane) for a dtype."""
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.bfloat16):
        return (16, 128)
    if d in (jnp.dtype(jnp.int8), jnp.dtype(jnp.float8_e4m3fn)):
        return (32, 128)
    return (8, 128)


def pad_to(x: jnp.ndarray, shape: tuple) -> jnp.ndarray:
    """Zero-pad trailing dims of ``x`` up to ``shape``."""
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)
