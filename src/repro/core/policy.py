"""Typed offload policies — the session API's vocabulary.

The runtime grew four stringly-typed mode knobs across PRs 1–3: the
``"resident"`` operand sentinel, ``OffloadConfig.info_dist`` /
``.completion`` raw strings, and the ``staging`` / ``via=`` strategy
strings threaded through ``DispatchPlan.stage``, ``OffloadStream`` and
``ServeConfig``.  A typo in any of them (``info_dist="mulicast"``) used to
silently misconfigure the run.  This module replaces them with enums —
string-valued, so they compare and hash like their legacy spellings and
flow through every existing code path — and bundles them, together with
the fusion/pipelining knobs that used to be separate *methods*
(``offload_fused``, ``OffloadStream``), into one immutable
:class:`OffloadPolicy`.

``AUTO`` is the headline policy: every decidable field is left ``None``
and the session planner (:mod:`repro.core.session`) fills it in from the
simulator's dispatch and staging cost models — mode selection driven by
the paper's quantitative runtime model (§6; Colagrande & Benini,
arXiv:2404.01908) instead of per-call hardcoding.

Legacy raw strings are still accepted everywhere (coerced, validated)
but raise :class:`DeprecationWarning` — the validated deprecation shims
of the migration path documented in the README's "Session API" section.
"""

from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import Any, Dict, Optional, Tuple, Type, TypeVar, Union


class Staging(str, enum.Enum):
    """Phase-E placement strategy for replicated operands.

    Mirrors ``repro.core.broadcast.STAGING_MODES`` (the legacy string
    surface) member for member; see ``DispatchPlan.stage`` for the data
    paths.
    """

    DIRECT = "direct"            # one replicated device_put (O(n) host link)
    HOST_FANOUT = "host_fanout"  # explicit sequential O(n) baseline
    TREE = "tree"                # hierarchical broadcast: O(1) host link
    TREE_RESHARD = "tree_reshard"  # tree root upload + resharding fast path


class Residency(str, enum.Enum):
    """Whether a submit stages fresh operands or reuses resident buffers."""

    FRESH = "fresh"              # phase-E stage the passed operands
    RESIDENT = "resident"        # reuse the plan's resident device buffers


class InfoDist(str, enum.Enum):
    """Job-information distribution (paper §4.2)."""

    MULTICAST = "multicast"      # replicated job info, O(log n) broadcast
    P2P_CHAIN = "p2p_chain"      # the baseline's O(n) collective-permute chain


class Completion(str, enum.Enum):
    """Job-completion synchronization (paper §4.3)."""

    UNIT = "unit"                # the job completion unit (fused psum)
    CENTRAL_COUNTER = "central_counter"  # software central-counter chain


class TenantKind(str, enum.Enum):
    """What a fabric tenant is, to the scheduler's admission model.

    A ``SERVE`` tenant is resident — it holds a floor lease indefinitely
    and bursts above it between decode batches; an ``OFFLOAD`` tenant is
    bursty — it leases for a bounded job stream and releases.  The
    :class:`repro.core.fabric.FabricScheduler` favors leaving head-room
    for the resident class when slicing the fabric.
    """

    OFFLOAD = "offload"
    SERVE = "serve"


_E = TypeVar("_E", bound=enum.Enum)


def coerce_enum(enum_cls: Type[_E], value: Union[str, _E], field: str,
                *, warn_legacy: bool = False) -> _E:
    """Validate ``value`` as a member of ``enum_cls`` (coercing strings).

    With ``warn_legacy=True`` a raw string (the pre-session spelling)
    additionally raises a :class:`DeprecationWarning` pointing at the
    typed replacement — enum members always pass silently.  An unknown
    value raises :class:`ValueError` naming the valid set, so a typo like
    ``info_dist="mulicast"`` fails loudly instead of misconfiguring the
    run.
    """
    if isinstance(value, enum_cls):
        return value
    try:
        member = enum_cls(value)
    except ValueError:
        from repro.analysis.diagnostics import invalid_mode
        valid = tuple(m.value for m in enum_cls)
        raise invalid_mode(field, value, valid).as_error(ValueError) from None
    if warn_legacy:
        warnings.warn(
            f"passing {field} as a raw string ({value!r}) is deprecated; "
            f"use {enum_cls.__name__}.{member.name} (repro.api)",
            DeprecationWarning, stacklevel=3)
    return member


def warn_legacy(old: str, new: str) -> None:
    """One legacy-surface deprecation warning, uniformly worded."""
    warnings.warn(f"{old} is deprecated; use {new} (repro.api)",
                  DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Model-driven deadlines and the recovery escalation ladder.

    The deadline of attempt ``a`` is the §6 ``Session.estimate`` job
    total × ``deadline_factor`` × ``backoff^a`` — *virtual cycles*, not
    wallclock, so recovery is deterministic (this replaces
    ``StepWatchdog``'s latency-history cold-start heuristic for the
    offload path).  On a trip the session escalates:

      1. resubmit in the lease (transient faults — lost arrivals,
         stalls — succeed here),
      2. a disjoint backup window inside the lease, address-mask
         encoded (``backup=True``; also the speculative race partner
         for stragglers that complete but blow the deadline),
      3. full lease failover through ``FabricScheduler.fail_clusters``
         (``failover=True``), shrinking gracefully when no equal-size
         healthy window exists.

    ``max_attempts`` bounds the trips before :class:`~repro.core.
    faults.FaultError`.
    """

    max_attempts: int = 3
    deadline_factor: float = 3.0
    backoff: float = 2.0
    backup: bool = True
    failover: bool = True

    def __post_init__(self) -> None:
        from repro.analysis.diagnostics import invalid_field
        if self.max_attempts < 1:
            raise invalid_field(
                "max_attempts",
                f"max_attempts must be >= 1, got {self.max_attempts}"
            ).as_error(ValueError)
        if self.deadline_factor <= 1.0:
            raise invalid_field(
                "deadline_factor",
                f"deadline_factor must be > 1 (a deadline at or below the "
                f"prediction trips every job), got {self.deadline_factor}"
            ).as_error(ValueError)
        if self.backoff < 1.0:
            raise invalid_field(
                "backoff", f"backoff must be >= 1, got {self.backoff}"
            ).as_error(ValueError)


@dataclasses.dataclass(frozen=True)
class OffloadPolicy:
    """How a session submit is dispatched — every mode knob in one place.

    ``None`` in a decidable field (``staging``, ``fuse``, ``window``)
    means *let the planner decide from the cost models*; the module-level
    :data:`AUTO` policy leaves all three open.  Explicit values pin the
    decision (the typed spelling of every legacy hand-picked mode):

    * ``staging`` — phase-E strategy for replicated operands.
    * ``residency`` — ``FRESH`` stages the passed operands; ``RESIDENT``
      redispatches the plan's resident buffers (zero ``device_put``).
    * ``info_dist`` / ``completion`` — the paper's two implementations
      (§4.2/§4.3); defaults are the extended (multicast + unit) system.
    * ``fuse`` — dispatch batching factor B: B job instances stacked into
      one XLA launch (1 = no fusion).  Replaces ``offload_fused``.
    * ``window`` — in-flight pipeline window (1 = synchronous).  Replaces
      the ``OffloadStream`` constructor knob; capped by the runtime's
      completion-unit copies at submit time.
    * ``depth`` — staging buffer slots for the pipelined upload overlap.
    * ``donate_operands`` — XLA buffer donation, as in ``OffloadConfig``.
    * ``retry`` — a :class:`RetryPolicy` routes submits through the
      fault-tolerant path (model-driven deadlines + the escalation
      ladder); ``None`` (default) keeps the fast path with no deadline
      checks.
    """

    staging: Optional[Staging] = None
    residency: Residency = Residency.FRESH
    info_dist: InfoDist = InfoDist.MULTICAST
    completion: Completion = Completion.UNIT
    fuse: Optional[int] = None
    window: Optional[int] = None
    depth: int = 2
    donate_operands: bool = False
    retry: Optional[RetryPolicy] = None

    def __post_init__(self):
        coerce = object.__setattr__
        if self.staging is not None:
            coerce(self, "staging",
                   coerce_enum(Staging, self.staging, "staging"))
        coerce(self, "residency",
               coerce_enum(Residency, self.residency, "residency"))
        coerce(self, "info_dist",
               coerce_enum(InfoDist, self.info_dist, "info_dist"))
        coerce(self, "completion",
               coerce_enum(Completion, self.completion, "completion"))
        if self.retry is not None and not isinstance(self.retry, RetryPolicy):
            from repro.analysis.diagnostics import invalid_field
            raise invalid_field(
                "retry", f"retry must be a RetryPolicy, got "
                         f"{type(self.retry).__name__}"
            ).as_error(TypeError)
        for field, lo in (("fuse", 1), ("window", 1), ("depth", 1)):
            v = getattr(self, field)
            if v is not None and (not isinstance(v, int) or v < lo):
                from repro.analysis.diagnostics import invalid_field
                raise invalid_field(
                    field, f"{field} must be an int >= {lo}, got {v!r}"
                ).as_error(ValueError)
        # cross-field contradictions fail at construction, not mid-dispatch:
        # a RESIDENT submit stages nothing, so a pinned non-DIRECT staging
        # strategy could never run — silently ignoring it would misreport
        # every estimate/explain derived from the policy
        if (self.residency is Residency.RESIDENT
                and self.staging is not None
                and self.staging is not Staging.DIRECT):
            from repro.analysis.diagnostics import contradiction
            raise contradiction(
                f"residency=RESIDENT stages no operands; pinning "
                f"staging={self.staging.value!r} is contradictory (leave "
                "staging unset or DIRECT)", name="staging"
            ).as_error(ValueError)

    @property
    def decided(self) -> bool:
        """True when no field is left for the planner."""
        return None not in (self.staging, self.fuse, self.window)

    def pinned(self, **fields) -> "OffloadPolicy":
        """A copy with ``fields`` replaced (typed ``dataclasses.replace``)."""
        return dataclasses.replace(self, **fields)

    def diff(self, other: "OffloadPolicy") -> Dict[str, Tuple[Any, Any]]:
        """Field-by-field delta to ``other``: ``{field: (mine, theirs)}``.

        The perf linter renders its ``suggested_policy`` through this
        (only the changed knobs, not the full record), and it is handy
        for explaining what a planner decision actually pinned.
        """
        out: Dict[str, Tuple[Any, Any]] = {}
        for f in dataclasses.fields(self):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if a != b:
                out[f.name] = (a, b)
        return out


#: The model-driven policy: the planner picks staging mode, fusion factor
#: B, and in-flight window from the simulator's cost models, per
#: job-shape and cluster count.
AUTO = OffloadPolicy()
