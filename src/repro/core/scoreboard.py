"""Out-of-order dependent dispatch: the host-side issue scoreboard.

The paper's dispatch path (and PRs 1-7 on top of it) treats every job as
independent: a chain of K dependent jobs pays K host round trips — fetch
the producer's result to the host (d2h), restage it for the consumer
(h2d) — and serializes on the host even when sub-DAGs are independent.
This module is the host dispatcher's answer, structured like an
out-of-order core's issue logic (R10K-style Active List + Integer
Queue):

* the **Active List** holds every node of a submitted graph in program
  order with its lifecycle state (``waiting -> issued -> retired``) —
  retirement bookkeeping stays in order per completion unit while issue
  does not;
* the **Integer Queue** is the ready station: a node becomes *issuable*
  the moment every producer it depends on has been **issued** (not
  completed — JAX dispatch is async, so a consumer launch can consume a
  producer's not-yet-materialized device array and the substrate chains
  them device-side);
* **buffer renaming** breaks WAR/WAW hazards: graph staging never
  overwrites a plan's resident buffers (every node stages into fresh
  renamed buffers), and a forwarded producer result that a donating
  consumer would consume is copied to a fresh buffer first —
  ``pending_readers`` tells the dispatcher when a rename copy is
  required instead of stalling.

The scoreboard itself is pure host-side bookkeeping (no jax imports) —
:meth:`Session.submit_graph <repro.core.session.Session.submit_graph>`
drives it, and the property tests drive it with synthetic random DAGs.

:class:`InflightWindow` is the bounded in-flight companion structure:
at most ``limit`` issued-but-not-retired jobs per runtime (one
completion-unit copy each, fig. 6).  It generalizes the window-stall
logic :class:`~repro.core.stream.OffloadStream` had inline — stream and
graph dispatch now share it.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import (
    Any, Callable, Deque, Dict, Iterable, List, Mapping, Optional,
    Sequence, Tuple, Union,
)

from repro.analysis import sanitizer as _san

__all__ = [
    "GraphError", "GraphNode", "InflightWindow", "Ref", "Scoreboard",
    "resolve_graph",
]


class GraphError(ValueError):
    """A malformed job graph: unknown reference, duplicate name, cycle,
    or an issue/retire call that violates the scoreboard protocol."""


@dataclasses.dataclass(frozen=True)
class Ref:
    """A dataflow edge: *this operand is node* ``node``'s *result*.

    ``node`` names a producer by index (position in the node list) or by
    its ``GraphNode.name``.  The consumer's operand is the producer's
    output forwarded device-to-device to the consumer's sharding — never
    fetched to the host.
    """

    node: Union[int, str]


@dataclasses.dataclass
class GraphNode:
    """One job of a dependency graph (the ``submit_graph`` vocabulary).

    ``operands`` maps operand names to host arrays or :class:`Ref`s to
    producer nodes (or is ``Residency.RESIDENT`` to reuse the plan's
    resident buffers).  ``after`` adds pure ordering edges on top of the
    dataflow.  ``fetch`` controls whether ``GraphHandle.wait`` returns
    this node's result (default: only *sink* nodes — results no other
    node consumes — are fetched; intermediates stay on-fabric).
    ``session`` dispatches the node through another session's lease (a
    graph spanning multiple leases issues concurrently across them).
    """

    job: Any                                 # PaperJob
    operands: Any                            # Mapping[str, ndarray|Ref] | Residency
    name: Optional[str] = None
    job_args: Optional[Any] = None
    after: Sequence[Union[int, str, Ref]] = ()
    n: Optional[int] = None
    request: Optional[Any] = None
    clusters: Optional[Sequence[int]] = None
    fetch: Optional[bool] = None
    session: Optional[Any] = None


def _dep_id(ref: Union[int, str, Ref], names: Dict[str, int],
            n_nodes: int, where: str) -> int:
    node = ref.node if isinstance(ref, Ref) else ref
    if isinstance(node, str):
        if node not in names:
            raise GraphError(f"{where}: unknown node name {node!r} "
                             f"(known: {sorted(names)})")
        return names[node]
    idx = int(node)
    if not 0 <= idx < n_nodes:
        raise GraphError(f"{where}: node index {idx} outside "
                         f"[0, {n_nodes})")
    return idx


def resolve_graph(nodes: Sequence[GraphNode]
                  ) -> Tuple[List[List[int]], List[List[Tuple[int, str]]]]:
    """Resolve names/refs of ``nodes`` -> (deps, data_edges) per node.

    ``deps[i]`` are all predecessor indices of node i (dataflow and
    ``after`` ordering edges merged); ``data_edges[i]`` the dataflow
    subset as ``(producer, operand_name)``.  Raises :class:`GraphError`
    on duplicate names, unresolvable references, or self-dependencies
    (cycles are caught by :class:`Scoreboard`).
    """
    if not nodes:
        raise GraphError("empty graph")
    names: Dict[str, int] = {}
    for i, nd in enumerate(nodes):
        if nd.name is not None:
            if nd.name in names:
                raise GraphError(f"duplicate node name {nd.name!r} "
                                 f"(nodes {names[nd.name]} and {i})")
            names[nd.name] = i
    deps: List[List[int]] = []
    data_edges: List[List[Tuple[int, str]]] = []
    for i, nd in enumerate(nodes):
        where = f"node {i}" + (f" ({nd.name})" if nd.name else "")
        d: List[int] = []
        edges: List[Tuple[int, str]] = []
        if isinstance(nd.operands, Mapping):
            for op_name, value in nd.operands.items():
                if isinstance(value, Ref):
                    src = _dep_id(value, names, len(nodes),
                                  f"{where} operand {op_name!r}")
                    edges.append((src, op_name))
                    d.append(src)
        for ref in nd.after:
            d.append(_dep_id(ref, names, len(nodes), f"{where} after"))
        if i in d:
            raise GraphError(f"{where} depends on itself")
        deps.append(sorted(set(d)))
        data_edges.append(edges)
    return deps, data_edges


#: Active-List lifecycle states
WAITING, ISSUED, RETIRED = "waiting", "issued", "retired"


class Scoreboard:
    """Active-List/Integer-Queue issue engine over a dependency DAG.

    Constructed from per-node predecessor lists (see
    :func:`resolve_graph`); raises :class:`GraphError` on a cycle.  The
    driver loop is::

        sb = Scoreboard(deps)
        while not sb.all_retired:
            for i in sb.ready():      # Integer Queue, age order
                dispatch(i); sb.issue(i)
            sb.retire(oldest_inflight)   # when a unit must be freed

    ``issue`` requires readiness (every predecessor issued) and
    ``retire`` requires ``issued`` — protocol violations raise rather
    than corrupt state, so the property tests can drive random
    interleavings hard.
    """

    def __init__(self, deps: Sequence[Iterable[int]]):
        self.deps: List[Tuple[int, ...]] = [
            tuple(sorted(set(int(x) for x in d))) for d in deps]
        n = len(self.deps)
        for i, d in enumerate(self.deps):
            for p in d:
                if not 0 <= p < n:
                    raise GraphError(
                        f"node {i} depends on out-of-range node {p}")
            if i in d:
                raise GraphError(f"node {i} depends on itself")
        self.succs: List[List[int]] = [[] for _ in range(n)]
        for i, d in enumerate(self.deps):
            for p in d:
                self.succs[p].append(i)
        self._check_acyclic()
        #: the Active List: program-order lifecycle states
        self.state: List[str] = [WAITING] * n
        self._unissued_preds = [len(d) for d in self.deps]
        #: unissued *dataflow-or-ordering* consumers per producer — while
        #: > 0 a producer's result buffer must survive (a donating
        #: consumer renames instead of consuming it)
        self._pending_readers = [len(s) for s in self.succs]
        self.issue_order: List[int] = []
        self.retire_order: List[int] = []
        self._inflight = 0
        self.max_inflight = 0

    def _check_acyclic(self) -> None:
        indeg = [len(d) for d in self.deps]
        q = collections.deque(i for i, d in enumerate(indeg) if d == 0)
        seen = 0
        while q:
            i = q.popleft()
            seen += 1
            for s in self.succs[i]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    q.append(s)
        if seen != len(self.deps):
            stuck = [i for i, d in enumerate(indeg) if d > 0]
            raise GraphError(f"dependency cycle through nodes {stuck}")

    def __len__(self) -> int:
        return len(self.deps)

    # -- Integer Queue ------------------------------------------------------

    def ready(self) -> List[int]:
        """Issuable nodes in age (program) order: waiting, all
        predecessors issued.  Issue readiness is *dispatch*-based, not
        completion-based — async dispatch lets a consumer launch chain on
        a producer's in-flight device array."""
        return [i for i in range(len(self.deps))
                if self.state[i] == WAITING
                and self._unissued_preds[i] == 0]

    def issue(self, i: int) -> None:
        if self.state[i] != WAITING:
            raise GraphError(f"node {i} already {self.state[i]}")
        if self._unissued_preds[i]:
            raise GraphError(
                f"node {i} is not ready: {self._unissued_preds[i]} "
                "unissued predecessors")
        s = _san.active()
        if s is not None:
            s.sb_issue(self, i, self.deps[i])
        self.state[i] = ISSUED
        self.issue_order.append(i)
        self._inflight += 1
        self.max_inflight = max(self.max_inflight, self._inflight)
        for s in self.succs[i]:
            self._unissued_preds[s] -= 1
        for p in self.deps[i]:
            self._pending_readers[p] -= 1

    def retire(self, i: int) -> None:
        """Completion-side retirement (the job's completion cause was
        collected and its unit copy freed) — any order relative to
        issue order of *other* nodes."""
        if self.state[i] != ISSUED:
            raise GraphError(f"cannot retire node {i}: {self.state[i]}")
        s = _san.active()
        if s is not None:
            s.sb_retire(self, i)
        self.state[i] = RETIRED
        self.retire_order.append(i)
        self._inflight -= 1

    # -- rename/readiness queries ------------------------------------------

    def pending_readers(self, i: int) -> int:
        """Consumers of node ``i`` not yet issued.  A donating consumer
        must *rename* (copy) the forwarded buffer while this is > 0 —
        consuming it in place would be a WAR hazard on the remaining
        readers."""
        return self._pending_readers[i]

    @property
    def inflight(self) -> int:
        return self._inflight

    @property
    def all_issued(self) -> bool:
        return all(s != WAITING for s in self.state)

    @property
    def all_retired(self) -> bool:
        return all(s == RETIRED for s in self.state)

    def sinks(self) -> List[int]:
        """Nodes with no consumers — the graph's results by default."""
        return [i for i, s in enumerate(self.succs) if not s]


class InflightWindow:
    """Bounded issued-but-not-retired window (completion-unit copies).

    Job k and job k + ``limit`` share a completion-unit copy, so k must
    have retired before k + ``limit`` issues (fig. 6).  ``make_room``
    drains oldest-first through the caller's ``drain`` callback (wait or
    retire — the stream waits for data, the graph dispatcher retires
    completion-only), counting each forced drain as a stall.
    """

    def __init__(self, limit: int):
        if limit < 1:
            raise ValueError(f"window limit must be >= 1, got {limit}")
        self.limit = limit
        self._q: Deque[Any] = collections.deque()
        self.stalls = 0

    def __len__(self) -> int:
        return len(self._q)

    def make_room(self, drain: Callable[[Any], Any]) -> None:
        while len(self._q) >= self.limit:
            drain(self._q.popleft())
            self.stalls += 1

    def push(self, handle: Any) -> None:
        self._q.append(handle)

    def popleft(self) -> Any:
        """Remove and return the oldest in-flight handle (caller drains)."""
        return self._q.popleft()

    def drain_all(self, drain: Callable[[Any], Any]) -> List[Any]:
        out = []
        while self._q:
            out.append(drain(self._q.popleft()))
        return out
