"""The paper's six benchmark kernels (§5.1) as offloadable jobs.

Each kernel carries two coupled descriptions:

* a :class:`~repro.core.simulator.JobSpec` — the phase-level profile consumed
  by the cycle-accurate simulator and the analytical model (transfer sizes,
  compute cycles, level structure).  The AXPY/ATAX profiles are anchored to
  the paper's measured coefficients (1.47 cycles/element for AXPY; the
  eq.-6 terms for ATAX).
* a real JAX computation — used by :mod:`repro.core.offload` to actually run
  the job on a device mesh through the offload runtime (baseline vs
  multicast), and cross-checked against a pure reference.

Kernel/job mapping onto clusters (consistent between both views):

  AXPY        x, y row-chunks per cluster; embarrassingly parallel (Amdahl
              class, §5.3).
  MonteCarlo  no operands, per-cluster RNG streams, scalar writeback (Amdahl).
  Matmul      A row-chunk + full B per cluster (B is re-read by every cluster
              through the single SPM port).  The benchmarked sizes are small —
              the paper's fine-grained regime — so E stays short (Amdahl).
  ATAX        full A and x per cluster (the paper's eq. 6 broadcast term
              N(1+M)/8 · n), duplicated A·x pass, y chunk per cluster
              (broadcast class).
  Covariance  full data matrix per cluster, cov row-chunk per cluster
              (broadcast class).
  BFS         full graph per cluster, frontier chunk per cluster, level-
              synchronous with a global software barrier per level
              (broadcast class).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import JobSpec

DTYPE = jnp.float64  # the paper's workloads are double precision

# Measured per-element execution coefficients (cycles per element per 8-core
# cluster-group, §5.5 F and reconstructions for the remaining kernels).
AXPY_CYC_PER_ELEM = 1.47          # paper §5.5 F (measured)
MC_CYC_PER_SAMPLE = 25.0          # software LCG + FP compare + accumulate
MM_CYC_PER_MAC = 1.1              # FREP FMA pipeline, near 1 MAC/cycle/core
ATAX_DUP_COEFF = 3.98             # eq. 6: duplicated A·x term (per N·M)
ATAX_PAR_COEFF = 1.9              # eq. 6: 2.9·N/(8n) minus the G term N/(8n)
COV_CYC_PER_MAC = 1.2
BFS_CYC_PER_EDGE = 8.0


def _chunks(total: int, n: int, i: int) -> int:
    """Row-balanced chunk size of cluster i when splitting `total` over n."""
    base, rem = divmod(total, n)
    return base + (1 if i < rem else 0)


@dataclasses.dataclass
class PaperJob:
    """A benchmark kernel: simulator spec + real JAX computation."""

    spec: JobSpec
    #: builds (operands, expected) given a seed — host-side, pure numpy
    make_instance: Callable[[int], Tuple[Dict[str, np.ndarray], np.ndarray]]
    #: global JAX computation (applied to the full operands; the offload
    #: runtime shards it over clusters per `shard_axes`)
    compute: Callable[..., jnp.ndarray]
    #: operand name -> axis to shard over clusters (None = replicate/broadcast)
    shard_axes: Dict[str, int | None]
    #: output axis sharded over clusters (None = reduced or replicated)
    out_axis: int | None
    #: cross-cluster combination when out_axis is None:
    #:   "sum"  — psum of per-cluster partials (ATAX)
    #:   "mean" — psum / n (Monte Carlo per-shard estimates)
    #:   None   — computed redundantly on every cluster (broadcast class)
    reduce: str | None = None


# ----------------------------------------------------------------------------
# AXPY — BLAS-1: z = alpha * x + y
# ----------------------------------------------------------------------------


def axpy_spec(N: int) -> JobSpec:
    return JobSpec(
        name=f"axpy[N={N}]",
        arg_words=5,  # N, alpha, x_ptr, y_ptr, z_ptr
        operand_transfers=lambda n, i: [8 * _chunks(N, n, i)] * 2,  # x, y chunks
        compute_cycles=lambda n, i: AXPY_CYC_PER_ELEM * _chunks(N, n, i) / 8.0,
        writeback_transfers=lambda n, i: [8 * _chunks(N, n, i)],
    )


def make_axpy(N: int = 1024) -> PaperJob:
    def make_instance(seed: int):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(N)
        y = rng.standard_normal(N)
        alpha = 2.5
        return {"x": x, "y": y}, alpha * x + y

    def compute(x, y):
        return 2.5 * x + y

    return PaperJob(
        spec=axpy_spec(N),
        make_instance=make_instance,
        compute=compute,
        shard_axes={"x": 0, "y": 0},
        out_axis=0,
    )


# ----------------------------------------------------------------------------
# Monte Carlo — pi estimation by rejection sampling
# ----------------------------------------------------------------------------


def montecarlo_spec(N: int) -> JobSpec:
    return JobSpec(
        name=f"montecarlo[N={N}]",
        arg_words=3,  # N, seed, result_ptr
        operand_transfers=lambda n, i: [],
        compute_cycles=lambda n, i: MC_CYC_PER_SAMPLE * _chunks(N, n, i) / 8.0,
        writeback_transfers=lambda n, i: [8],
    )


def make_montecarlo(N: int = 16384) -> PaperJob:
    def make_instance(seed: int):
        # The operand is just the per-sample uniform draws (precomputed so the
        # reference is exact); the device job counts hits in the unit circle.
        rng = np.random.default_rng(seed)
        pts = rng.random((N, 2))
        hits = float(((pts**2).sum(axis=1) <= 1.0).sum())
        return {"pts": pts}, np.asarray(4.0 * hits / N)

    def compute(pts):
        hits = jnp.sum((pts**2).sum(axis=1) <= 1.0)
        return 4.0 * hits.astype(DTYPE) / pts.shape[0] * 1.0

    return PaperJob(
        spec=montecarlo_spec(N),
        make_instance=make_instance,
        compute=compute,
        shard_axes={"pts": 0},
        out_axis=None,
        reduce="mean",
    )


# ----------------------------------------------------------------------------
# Matmul — BLAS-3: C[M,N] = A[M,K] @ B[K,N], A row-split, B broadcast
# ----------------------------------------------------------------------------


def matmul_spec(M: int, K: int, N: int) -> JobSpec:
    return JobSpec(
        name=f"matmul[{M}x{K}x{N}]",
        arg_words=6,  # M, K, N, a_ptr, b_ptr, c_ptr
        operand_transfers=lambda n, i: [8 * _chunks(M, n, i) * K, 8 * K * N],
        compute_cycles=lambda n, i: MM_CYC_PER_MAC * _chunks(M, n, i) * K * N / 8.0,
        writeback_transfers=lambda n, i: [8 * _chunks(M, n, i) * N],
    )


def make_matmul(M: int = 16, K: int = 16, N: int = 16) -> PaperJob:
    def make_instance(seed: int):
        rng = np.random.default_rng(seed)
        A = rng.standard_normal((M, K))
        B = rng.standard_normal((K, N))
        return {"A": A, "B": B}, A @ B

    def compute(A, B):
        return A @ B

    return PaperJob(
        spec=matmul_spec(M, K, N),
        make_instance=make_instance,
        compute=compute,
        shard_axes={"A": 0, "B": None},
        out_axis=0,
    )


# ----------------------------------------------------------------------------
# ATAX — PolyBench: y = A^T (A x)
# ----------------------------------------------------------------------------


def atax_spec(M: int, N: int) -> JobSpec:
    # Paper mapping (eq. 6): every cluster retrieves the full A (M×N) and x
    # (the broadcast term N(1+M)/8 · n: the single SPM port serializes n full
    # copies), duplicates the A·x pass (the n-independent 3.98·N·M term), and
    # computes an N/n chunk of y (the 1.9·N/(8n) part of the 2.9·N/(8n) term;
    # the remaining N/(8n) is the phase-G writeback of the y chunk).
    return JobSpec(
        name=f"atax[{M}x{N}]",
        arg_words=6,  # M, N, A_ptr, x_ptr, y_ptr, tmp_ptr
        operand_transfers=lambda n, i: [8 * M * N, 8 * N],
        compute_cycles=lambda n, i: (
            ATAX_DUP_COEFF * N * M + ATAX_PAR_COEFF * _chunks(N, n, i) / 8.0
        ),
        writeback_transfers=lambda n, i: [8 * _chunks(N, n, i)],
    )


def make_atax(M: int = 64, N: int = 64) -> PaperJob:
    def make_instance(seed: int):
        rng = np.random.default_rng(seed)
        A = rng.standard_normal((M, N))
        x = rng.standard_normal(N)
        return {"A": A, "x": x}, A.T @ (A @ x)

    def compute(A, x):
        return A.T @ (A @ x)

    return PaperJob(
        spec=atax_spec(M, N),
        make_instance=make_instance,
        compute=compute,
        # Runtime mapping: shard A rows, psum the partial A_i^T (A_i x).
        shard_axes={"A": 0, "x": None},
        out_axis=None,
        reduce="sum",
    )


# ----------------------------------------------------------------------------
# Covariance — PolyBench: cov(M×M) of an M×N data matrix
# ----------------------------------------------------------------------------


def covariance_spec(M: int, N: int) -> JobSpec:
    return JobSpec(
        name=f"covariance[{M}x{N}]",
        arg_words=5,
        operand_transfers=lambda n, i: [8 * M * N],
        compute_cycles=lambda n, i: (
            COV_CYC_PER_MAC * (_chunks(M, n, i) * M * N + M * N) / 8.0
        ),
        writeback_transfers=lambda n, i: [8 * _chunks(M, n, i) * M],
    )


def make_covariance(M: int = 32, N: int = 64) -> PaperJob:
    def make_instance(seed: int):
        rng = np.random.default_rng(seed)
        data = rng.standard_normal((M, N))
        centred = data - data.mean(axis=1, keepdims=True)
        return {"data": data}, centred @ centred.T / (N - 1)

    def compute(data):
        centred = data - data.mean(axis=1, keepdims=True)
        return centred @ centred.T / (data.shape[1] - 1)

    return PaperJob(
        spec=covariance_spec(M, N),
        make_instance=make_instance,
        compute=compute,
        shard_axes={"data": None},  # broadcast class: full data everywhere
        out_axis=None,  # computed redundantly on every cluster
    )


# ----------------------------------------------------------------------------
# BFS — Graph500-style level-synchronous traversal (dense adjacency)
# ----------------------------------------------------------------------------


def bfs_spec(V: int, avg_degree: int = 4, levels: int = 6) -> JobSpec:
    E_g = V * avg_degree
    return JobSpec(
        name=f"bfs[V={V}]",
        arg_words=5,
        operand_transfers=lambda n, i: [8 * (V + E_g)],  # CSR broadcast
        compute_cycles=lambda n, i: BFS_CYC_PER_EDGE * (E_g / n) / 8.0,
        writeback_transfers=lambda n, i: [8 * _chunks(V, n, i)],
        levels=levels,
    )


def make_bfs(V: int = 256, seed_graph: int = 0) -> PaperJob:
    rng = np.random.default_rng(seed_graph)
    adj = np.zeros((V, V), dtype=bool)
    # Random sparse graph, symmetric, guaranteed-connected via a ring.
    for v in range(V):
        adj[v, (v + 1) % V] = True
    extra = rng.integers(0, V, size=(3 * V, 2))
    adj[extra[:, 0], extra[:, 1]] = True
    adj |= adj.T
    np.fill_diagonal(adj, False)

    def reference_distances() -> np.ndarray:
        dist = np.full(V, -1, dtype=np.int64)
        dist[0] = 0
        frontier = {0}
        d = 0
        while frontier:
            d += 1
            nxt = set()
            for u in frontier:
                for v in np.nonzero(adj[u])[0]:
                    if dist[v] < 0:
                        dist[v] = d
                        nxt.add(v)
            frontier = nxt
        return dist

    def make_instance(seed: int):
        return {"adj": adj.astype(np.float64)}, reference_distances().astype(np.float64)

    def compute(adj_f):
        V_ = adj_f.shape[0]
        dist0 = jnp.full((V_,), -1.0, dtype=DTYPE).at[0].set(0.0)
        frontier0 = jnp.zeros((V_,), dtype=DTYPE).at[0].set(1.0)

        def body(state):
            dist, frontier, d = state
            reach = (adj_f.T @ frontier) > 0
            newly = reach & (dist < 0)
            dist = jnp.where(newly, d + 1.0, dist)
            return dist, newly.astype(DTYPE), d + 1.0

        def cond(state):
            _, frontier, _ = state
            return jnp.sum(frontier) > 0

        dist, _, _ = jax.lax.while_loop(cond, body, (dist0, frontier0, 0.0))
        return dist

    return PaperJob(
        spec=bfs_spec(V),
        make_instance=make_instance,
        compute=compute,
        shard_axes={"adj": None},
        out_axis=None,  # computed redundantly; runtime keeps one copy
    )


# ----------------------------------------------------------------------------
# Fused-batch helpers (offload_fused / OffloadStream)
# ----------------------------------------------------------------------------


def make_instances(job: PaperJob, batch: int, seed0: int = 0
                   ) -> Tuple[List[Dict[str, np.ndarray]], List[np.ndarray]]:
    """B independent instances of ``job`` -> (operand dicts, expected)."""
    pairs = [job.make_instance(seed0 + i) for i in range(batch)]
    return [ops for ops, _ in pairs], [exp for _, exp in pairs]


def stack_instances(instances: Sequence[Dict[str, np.ndarray]]
                    ) -> Dict[str, np.ndarray]:
    """Stack B operand dicts along a new leading batch axis.

    All instances must share operand names/shapes/dtypes — they are B
    draws of the *same* job, which is what makes one fused launch valid.
    """
    if not instances:
        raise ValueError("stack_instances needs at least one instance")
    names = sorted(instances[0])
    for i, inst in enumerate(instances):
        if sorted(inst) != names:
            raise ValueError(
                f"instance {i} operand names {sorted(inst)} != {names}")
    return {name: np.stack([np.asarray(inst[name]) for inst in instances])
            for name in names}


#: Registry used by benchmarks and tests.
PAPER_JOBS: Dict[str, Callable[..., PaperJob]] = {
    "axpy": make_axpy,
    "montecarlo": make_montecarlo,
    "matmul": make_matmul,
    "atax": make_atax,
    "covariance": make_covariance,
    "bfs": make_bfs,
}
