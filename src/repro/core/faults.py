"""Deterministic fault injection for the offload fabric.

The paper's §6 model predicts an offloaded job's runtime with < 15 %
error — so a job that overshoots its prediction is *detectably*
anomalous, and the completion unit's ``outstanding()`` register state
(fig. 6: offload register minus arrivals counter) says exactly how many
clusters never reported.  This module turns those two signals into a
testable fault-tolerance substrate:

* :class:`FaultPlan` / :class:`FaultSpec` — a seeded, explicit schedule
  of faults keyed by *dispatch index*, never by wallclock.  Every
  recovery path the plan provokes is bit-reproducible in CI.
* :class:`FaultInjector` — the runtime hook.  ``OffloadRuntime`` calls
  :meth:`FaultInjector.on_dispatch` from its dispatch tail;
  ``JobHandle.wait`` then consults the injector's per-job effect:
  missing arrivals surface as a typed :class:`CompletionTimeout`
  (after feeding the partial arrivals to the completion unit and
  cancelling the stuck register), straggle/stall delays surface as
  *virtual cycles* in the §6 model domain.
* :class:`SessionHealth` — the recovery counters a
  :class:`~repro.core.session.Session` accumulates while walking the
  escalation ladder (resubmit → disjoint backup window → lease
  failover), plus the virtual-cycle timeline the ``faults`` bench
  suite checks against :func:`predict_recovery`.

Fault taxonomy (``FaultKind``):

``CLUSTER_DEATH``
    The named clusters stop arriving from ``at_dispatch`` onward —
    permanent until :meth:`FaultInjector.revive`.  Every dispatch whose
    selection intersects the dead set loses those clusters' arrivals.
``STRAGGLE``
    A multiplicative delay: the affected dispatch completes, but
    ``factor`` × the §6 predicted job cycles late.  With ``clusters``
    given the slowness is persistent (a straggler cluster); without,
    it is a one-shot delay at ``at_dispatch``.
``HOST_LINK_STALL``
    An additive delay of ``factor`` cycles on the host link (phase A/E
    leg) of the dispatch at ``at_dispatch`` — one-shot.
``LOST_ARRIVAL``
    ``count`` completion writes of the dispatch at ``at_dispatch``
    are dropped in flight — transient (the clusters are healthy; a
    resubmit succeeds).

All delays are *virtual*: they live in the model's cycle domain
(1 cycle = 1 ns at the paper's 1 GHz), not in host wallclock, so
deadline arithmetic (``deadline = estimate × factor × backoff^attempt``)
is exact and CI never sleeps.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.core import model as amodel
from repro.core.params import DEFAULT_PARAMS, OccamyParams


class FaultKind(str, enum.Enum):
    """The fault taxonomy (module docstring)."""

    CLUSTER_DEATH = "cluster_death"
    STRAGGLE = "straggle"
    HOST_LINK_STALL = "host_link_stall"
    LOST_ARRIVAL = "lost_arrival"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``at_dispatch`` indexes the injector's global dispatch counter
    (every ``_launch`` through a hooked runtime increments it — probes
    and retries count too, which keeps the schedule deterministic under
    recovery).  ``clusters`` are *global* fabric ids.  ``factor`` is the
    straggle multiplier (× predicted job cycles) or the stall's absolute
    cycles; ``count`` the number of arrivals a ``LOST_ARRIVAL`` drops.
    """

    kind: FaultKind
    at_dispatch: int = 0
    clusters: Tuple[int, ...] = ()
    factor: float = 0.0
    count: int = 1

    def __post_init__(self) -> None:
        object.__setattr__(self, "kind", FaultKind(self.kind))
        object.__setattr__(
            self, "clusters", tuple(int(c) for c in self.clusters))
        if self.at_dispatch < 0:
            raise ValueError(f"at_dispatch must be >= 0, got {self.at_dispatch}")
        if self.kind is FaultKind.CLUSTER_DEATH and not self.clusters:
            raise ValueError("CLUSTER_DEATH needs a non-empty cluster set")
        if self.kind is FaultKind.STRAGGLE and self.factor <= 0:
            raise ValueError("STRAGGLE needs factor > 0")
        if self.kind is FaultKind.HOST_LINK_STALL and self.factor <= 0:
            raise ValueError("HOST_LINK_STALL needs factor (cycles) > 0")
        if self.kind is FaultKind.LOST_ARRIVAL and self.count < 1:
            raise ValueError("LOST_ARRIVAL needs count >= 1")


class FaultPlan:
    """An ordered, immutable schedule of :class:`FaultSpec`\\ s."""

    def __init__(self, faults: Sequence[FaultSpec] = ()):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)
        for f in self.faults:
            if not isinstance(f, FaultSpec):
                raise TypeError(f"FaultPlan takes FaultSpecs, got {f!r}")

    def __iter__(self) -> Iterator[FaultSpec]:
        return iter(self.faults)

    def __len__(self) -> int:
        return len(self.faults)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.faults)!r})"

    def compose(self, *others: "FaultPlan") -> "FaultPlan":
        """Chaos composition: merge fault schedules into one plan,
        ordered by dispatch index (ties keep the operand order).  The
        ``preempt`` churn bench composes a random plan onto its arrival
        trace this way — overload handling and fault recovery share one
        injector."""
        merged = list(self.faults)
        for other in others:
            merged.extend(other.faults)
        merged.sort(key=lambda f: f.at_dispatch)
        return FaultPlan(merged)

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        if not isinstance(other, FaultPlan):
            return NotImplemented
        return self.compose(other)

    @staticmethod
    def random(seed: int, *, n_faults: int = 2, num_clusters: int = 8,
               max_dispatch: int = 4,
               kinds: Sequence[FaultKind] = tuple(FaultKind),
               max_factor: float = 8.0) -> "FaultPlan":
        """A seeded random plan — same seed, same plan, bit-for-bit."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = FaultKind(kinds[int(rng.integers(len(kinds)))])
            at = int(rng.integers(max_dispatch))
            if kind is FaultKind.CLUSTER_DEATH:
                k = int(rng.integers(1, max(2, num_clusters // 4 + 1)))
                clusters = tuple(sorted(
                    int(c) for c in rng.choice(num_clusters, size=k,
                                               replace=False)))
                faults.append(FaultSpec(kind, at, clusters=clusters))
            elif kind is FaultKind.STRAGGLE:
                faults.append(FaultSpec(
                    kind, at, factor=float(1.0 + rng.random() * max_factor)))
            elif kind is FaultKind.HOST_LINK_STALL:
                faults.append(FaultSpec(
                    kind, at, factor=float(rng.integers(1_000, 100_000))))
            else:
                faults.append(FaultSpec(
                    kind, at, count=int(rng.integers(1, 3))))
        return FaultPlan(faults)


class CompletionTimeout(RuntimeError):
    """A dispatch's completion never fully arrived (deadline trip).

    Carries the actionable signal the escalation ladder needs: which
    job, how many arrivals are missing (the ``outstanding()`` register
    delta), and the global cluster ids of the failed selection.
    """

    def __init__(self, job_id: int, missing: int,
                 clusters: Tuple[int, ...]):
        self.job_id = job_id
        self.missing = missing
        self.clusters = tuple(clusters)
        super().__init__(
            f"job {job_id}: {missing}/{len(self.clusters)} arrivals missing "
            f"on clusters {list(self.clusters)}")


class FaultError(RuntimeError):
    """Recovery exhausted: retries, backup windows, and failover all
    failed (or were disabled by the :class:`~repro.core.policy.
    RetryPolicy`)."""


@dataclasses.dataclass
class _JobEffect:
    """The injector's resolved effect on one dispatched job."""

    lost: int = 0                 # arrivals dropped
    delay_cycles: float = 0.0     # virtual lateness (model domain)


class FaultInjector:
    """Applies a :class:`FaultPlan` to a hooked runtime, deterministically.

    One injector may be shared by several runtimes (a session keys one
    runtime per config): effects are keyed by (runtime, job id) and the
    dispatch counter is global, so the schedule is a pure function of
    dispatch order — which the recovery machinery itself keeps
    deterministic (virtual-cycle deadlines, no wallclock).
    """

    def __init__(self, plan: FaultPlan,
                 params: OccamyParams = DEFAULT_PARAMS):
        if not isinstance(plan, FaultPlan):
            plan = FaultPlan(plan)
        self.plan = plan
        self.params = params
        self._dispatch = 0
        self._dead: set = set()
        self._effects: Dict[Tuple[int, int], _JobEffect] = {}
        self.injected: Dict[str, int] = {k.value: 0 for k in FaultKind}

    # -- introspection ------------------------------------------------------

    @property
    def dispatch_index(self) -> int:
        return self._dispatch

    @property
    def dead_clusters(self) -> frozenset:
        """Global ids of clusters currently dead (armed CLUSTER_DEATHs)."""
        return frozenset(self._dead)

    def revive(self, clusters: Sequence[int]) -> None:
        """Bring clusters back (the test hook for repair scenarios)."""
        self._dead -= set(int(c) for c in clusters)

    # -- the runtime hooks --------------------------------------------------

    def on_dispatch(self, runtime: Any, job_id: int,
                    cluster_ids: Sequence[int], spec: Any) -> None:
        """Called from the dispatch tail; resolves this job's effect."""
        d = self._dispatch
        self._dispatch += 1
        ids = tuple(int(c) for c in cluster_ids)
        eff = _JobEffect()
        for f in self.plan:
            if f.kind is FaultKind.CLUSTER_DEATH and f.at_dispatch == d:
                newly = set(f.clusters) - self._dead
                self._dead |= newly
                self.injected[FaultKind.CLUSTER_DEATH.value] += len(newly)
        dead_hit = [c for c in ids if c in self._dead]
        if dead_hit:
            eff.lost += len(dead_hit)
        for f in self.plan:
            if f.kind is FaultKind.STRAGGLE:
                hit = ((f.at_dispatch <= d and set(f.clusters) & set(ids))
                       if f.clusters else f.at_dispatch == d)
                if hit:
                    eff.delay_cycles += f.factor * amodel.predict_total_v2(
                        spec, len(ids), self.params)
                    self.injected[FaultKind.STRAGGLE.value] += 1
            elif (f.kind is FaultKind.HOST_LINK_STALL
                  and f.at_dispatch == d):
                eff.delay_cycles += f.factor
                self.injected[FaultKind.HOST_LINK_STALL.value] += 1
            elif f.kind is FaultKind.LOST_ARRIVAL and f.at_dispatch == d:
                eff.lost += f.count
                self.injected[FaultKind.LOST_ARRIVAL.value] += 1
        eff.lost = min(eff.lost, len(ids))
        if eff.lost or eff.delay_cycles:
            self._effects[(id(runtime), job_id)] = eff

    def lost_arrivals(self, runtime: Any, job_id: int) -> int:
        eff = self._effects.get((id(runtime), job_id))
        return eff.lost if eff is not None else 0

    def delay_cycles(self, runtime: Any, job_id: int) -> float:
        eff = self._effects.get((id(runtime), job_id))
        return eff.delay_cycles if eff is not None else 0.0


# ---------------------------------------------------------------------------
# Model-driven deadlines and the recovery-overhead closed form.
# ---------------------------------------------------------------------------


def deadline_cycles(base_cycles: float, retry: Any, attempt: int = 0
                    ) -> float:
    """The model-driven deadline of attempt ``attempt``:
    §6 predicted job cycles × ``deadline_factor`` × ``backoff^attempt``.
    This replaces ``StepWatchdog``'s cold-start heuristic — a fresh
    session knows its deadline before the first job ever runs."""
    return retry.deadline_factor * base_cycles * (retry.backoff ** attempt)


@dataclasses.dataclass
class SessionHealth:
    """Recovery counters + the virtual-cycle timeline of a session.

    ``virtual_cycles`` accumulates the modeled completion time of every
    reliable job (including trips, probes, backups) — the deterministic
    "measured" side the ``faults`` bench compares against
    :func:`predict_recovery`.
    """

    deadline_trips: int = 0
    retries: int = 0
    probes: int = 0
    backups: int = 0
    failovers: int = 0
    restages: int = 0
    degraded: int = 0
    jobs_ok: int = 0
    jobs_failed: int = 0
    virtual_cycles: float = 0.0

    def snapshot(self) -> "SessionHealth":
        return dataclasses.replace(self)


def probe_bound(n_sel: int, n_dead: int) -> int:
    """Upper bound on bisection probes to localize ``n_dead`` dead
    clusters inside a selection of ``n_sel`` (the closed form's
    approximation of the session's actual probe walk): one whole-set
    probe plus two probes per bisection level per dead cluster."""
    if n_dead <= 0:
        return 1                         # one clean probe confirms transient
    levels = max(1, math.ceil(math.log2(max(2, n_sel))))
    return 1 + 2 * levels * n_dead


def predict_recovery(job: Any, n: int, plan: FaultPlan, retry: Any,
                     params: OccamyParams = DEFAULT_PARAMS,
                     probe_n: Optional[int] = None) -> float:
    """Closed-form predicted recovery overhead (extra virtual cycles over
    the fault-free run) of ONE job on ``n`` clusters under ``plan``.

    Deliberately coarser than the session's walk — probe counts use the
    :func:`probe_bound` bisection bound and every probe is costed at the
    mean of its success/timeout cost — so the ``faults`` bench's
    model-error rows measure a real prediction, not an identity.  The
    bench gates the error < 15 %, the same bar as the paper's §6 model.
    """
    est = amodel.predict_total_v2(job.spec, n, params)
    # the probe job is tiny; its predicted cycles on the probed subsets
    # are approximated by the full-selection estimate of the probe job
    from repro.core import jobs as _jobs
    probe_est = amodel.predict_total_v2(
        _jobs.make_axpy(PROBE_N).spec, max(1, (probe_n or n) // 2), params)
    overhead = 0.0
    for f in plan:
        d0 = deadline_cycles(est, retry, attempt=0)
        if f.kind is FaultKind.STRAGGLE:
            finish = est * (1.0 + f.factor)
            if finish <= d0:
                overhead += finish - est
            elif retry.backup:
                overhead += min(d0 + est, finish) - est
            else:
                overhead += finish - est
        elif f.kind is FaultKind.HOST_LINK_STALL:
            finish = est + f.factor
            if finish <= d0:
                overhead += f.factor
            elif retry.backup:
                overhead += min(d0 + est, finish) - est
            else:
                overhead += f.factor
        elif f.kind is FaultKind.LOST_ARRIVAL:
            # transient: trip (wait out the deadline), one clean probe of
            # the whole selection at its success cost (bisection never
            # starts), resubmit on the same selection
            clean_probe = amodel.predict_total_v2(
                _jobs.make_axpy(PROBE_N).spec, max(1, probe_n or n), params)
            overhead += d0 + clean_probe
        elif f.kind is FaultKind.CLUSTER_DEATH:
            n_dead = len(f.clusters)
            probes = probe_bound(n, n_dead)
            probe_cost = probes * probe_est * (1 + retry.deadline_factor) / 2
            overhead += d0 + probe_cost
    return overhead


#: probe payload size — divisible by every cluster count up to 8, so the
#: bisection probes can shard it on any subset of the test substrate
PROBE_N = 840
