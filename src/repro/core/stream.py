"""Pipelined multi-job offload stream — overlap staging with execution.

The paper's companion work ("Optimizing Offload Performance in Heterogeneous
MPSoCs", arXiv:2404.01908) shows that once the per-job offload overhead has
been shrunk (multicast, resident operands), the remaining floor is hidden by
*overlapping* offload phases of job k+1 with the execution of job k.
:class:`OffloadStream` is that overlap for this framework's own host
critical path:

* **double-buffered phase-E staging** — each ``submit()`` uploads its
  operands into the next of ``depth`` staging slots of the shared
  :class:`~repro.core.offload.DispatchPlan` (``plan.stage(ops, slot=k)``).
  JAX transfers and launches are async, so job k+1's ``device_put`` runs
  while job k's compute occupies the clusters — the E(k+1) || F(k) overlap
  of the paper's phase diagram (fig. 3), with ``depth`` bounding how many
  upload buffers exist at once.
* **bounded in-flight window** — at most ``window`` jobs are outstanding,
  defaulting to the runtime's ``n_units`` completion-unit copies (fig. 6:
  one unit instance per outstanding job).  A ``submit()`` into a full
  window first drains the oldest handle (a *window stall*, counted in
  ``stats``).
* **out-of-order completion drain** — handles may be waited in any order;
  :meth:`~repro.core.completion.CompletionUnit.collect` parks other jobs'
  causes, exactly as for plain async ``offload()``.

Typical use::

    rt = OffloadRuntime(n_units=4)
    stream = OffloadStream(rt, job, n=8)
    handles = [stream.submit(ops) for ops in instances]   # pipelined
    results = [h.wait() for h in handles]                 # any order

or, submit-and-drain in one call::

    results = stream.map(instances)
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.jobs import PaperJob
from repro.core.offload import (
    DispatchPlan, JobHandle, OffloadRuntime, _is_resident,
)
from repro.core.policy import Staging, coerce_enum, warn_legacy
from repro.core.scoreboard import InflightWindow
from repro.core import multicast as mc


class OffloadStream:
    """An async job queue over :class:`OffloadRuntime` with pipelined
    staging.  One stream drives one (job, cluster selection) pair — the
    regime where a dispatch plan is warm and the only per-job costs left
    are staging and launch.

    Direct construction is deprecated: the session API
    (``repro.api.Session``) pipelines every submit through this machinery
    with the window/depth/staging knobs carried by the typed
    ``OffloadPolicy`` (and picked by the planner under ``AUTO``).
    """

    def __init__(self, runtime: OffloadRuntime, job: PaperJob, *,
                 n: Optional[int] = None,
                 request: Optional[mc.MulticastRequest] = None,
                 clusters: Optional[Sequence[int]] = None,
                 depth: int = 2,
                 window: Optional[int] = None,
                 staging: Optional[Staging] = None,
                 _warn: bool = True):
        if _warn:
            warn_legacy("direct OffloadStream construction",
                        "Session.submit(job, operands, policy=...)")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if window is not None and window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if staging is not None:
            # enum members pass silently; raw strings warn (legacy surface)
            staging = coerce_enum(Staging, staging, "staging",
                                  warn_legacy=True)
        self.runtime = runtime
        self.job = job
        self._sel = dict(n=n, request=request, clusters=clusters)
        self.depth = depth
        #: staging strategy for slot uploads (None = the runtime default);
        #: "tree" keeps the double-buffered E(k+1) || F(k) overlap *and*
        #: O(1) host-link bytes per job — the upload-overlap property only
        #: concerns when staging happens, the tree only concerns how
        self.staging = staging
        # the window is capped by the completion-unit copies: job k and job
        # k + n_units share a unit, so k must have completed first — the
        # same InflightWindow bound the graph dispatcher uses (fig. 6)
        self.window = min(window or runtime.unit.n_units,
                          runtime.unit.n_units)
        self.plan: Optional[DispatchPlan] = None
        self._inflight = InflightWindow(self.window)
        self._seq = 0
        self._stats: Dict[str, int] = {"submitted": 0, "drained": 0}

    @property
    def stats(self) -> Dict[str, int]:
        return dict(self._stats, window_stalls=self._inflight.stalls)

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def submit(self, operands, job_args: Optional[np.ndarray] = None
               ) -> JobHandle:
        """Stage into the next buffer slot and launch; returns the handle.

        ``operands`` is a host operand dict (phase-E staged into the next
        of ``depth`` slots — the upload overlaps with the in-flight jobs'
        compute) or ``"resident"`` to redispatch the plan's resident
        buffers with zero staging (the pipeline then pays only launch +
        fetch per job, and the window hides those behind compute).  The
        launch itself is async, so a caller looping ``submit()`` keeps up
        to ``window`` jobs in flight with zero blocking until the window
        fills.
        """
        if job_args is None:
            job_args = np.ones((8,), dtype=np.float64)
        job_args = np.asarray(job_args, dtype=np.float64)
        resident = _is_resident(operands, "submit")
        if self.plan is None:
            self.plan = self.runtime.plan(
                self.job, None if resident else operands,
                args_shape=job_args.shape, **self._sel)
        if resident:
            staged = self.plan.resident_operands()
        else:
            staged = self.plan.stage(operands, slot=self._seq % self.depth,
                                     via=self.staging)
        # all completion-unit copies busy: block on the oldest job
        self._inflight.make_room(lambda h: h.wait())
        args_dev = self.plan.stage_args(job_args, via=self.staging)
        handle = self.runtime._launch(self.plan, args_dev, staged,
                                      consumed_resident=resident)
        self._inflight.push(handle)
        self._seq += 1
        self._stats["submitted"] += 1
        return handle

    def drain(self) -> List[Any]:
        """Wait for every in-flight job, in submit order; returns results."""
        out = self._inflight.drain_all(lambda h: h.wait())
        self._stats["drained"] += len(out)
        return out

    def map(self, instances: Sequence[Dict[str, np.ndarray]],
            job_args: Optional[Sequence[np.ndarray]] = None) -> List[Any]:
        """Submit every instance through the pipelined window, then wait.

        Results come back in submit order regardless of completion order
        (``JobHandle.wait()`` is idempotent, so handles already drained by
        window stalls just return their cached data).
        """
        if job_args is None:
            handles = [self.submit(ops) for ops in instances]
        else:
            handles = [self.submit(ops, a)
                       for ops, a in zip(instances, job_args)]
        return [h.wait() for h in handles]
