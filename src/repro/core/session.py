"""Unified predictive offload session — one submit path, model-driven modes.

The paper's final contribution is a quantitative model of offloaded
runtime (§6, error < 15%); its companion work (Colagrande & Benini,
"Optimizing Offload Performance in Heterogeneous MPSoCs",
arXiv:2404.01908) argues the *mode* of an offload — multicast vs. p2p,
fused vs. streamed, how wide a pipeline — should be chosen by that model,
not hardcoded per call.  After PRs 1–3 this framework had the pieces but
not the wiring: validated dispatch/staging cost models sat in
:mod:`repro.core.simulator` and :mod:`repro.core.model` while the user
surface fragmented into four stringly-typed entry points (``offload(job,
"resident")``, ``via=`` kwargs, ``OffloadStream``, ``offload_fused``,
plus the serve engine).  This module is the wiring:

* :class:`Session` — the single front door.  ``submit(job, operands)``
  covers one-shot, resident, fused, and streamed dispatch: a dict is one
  job, a list of dicts is many (fused into B-launches and/or pipelined
  through an in-flight window), ``Residency.RESIDENT`` redispatches
  warm buffers.  Successive single submits of the same (job, selection)
  pair share a pipelined stream, so the session *is* the stream.
* :class:`Planner` — fills the open fields of an
  :class:`~repro.core.policy.OffloadPolicy` (``policy=AUTO``) from the
  simulator's cost models: staging mode per replicated-operand footprint
  (discrete-event ``simulate_staging``), fusion factor B and pipeline
  window from the eq.-4 phase terms (dispatch constant amortized over B,
  staging overlapped when the window is open).
* :func:`estimate` / :meth:`SessionHandle.explain` — the <15 %-error
  model as an API contract: the predicted phase-by-phase breakdown
  (paper fig. 11 / §6) and the host-link staging-leg predictions are
  returned next to the measured :class:`~repro.core.offload.PlanStats`,
  so every dispatch can say what it *should* have cost.

The per-job amortization model (README "Pipelined offload"):

    t_job(B, W) = t_const/B + t_E + t_F + t_G            (W = 1)
    t_job(B, W) = max(t_const/B + t_E, t_F + t_G)        (W > 1)

with ``t_const`` the dispatch-constant phases (A–D, H, I) paid once per
launch and the E/F/G terms scaling with the fused batch; an open window
overlaps the next launch's host-side work (constant + staging) with the
current launch's device phases.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Deque, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.diagnostics import DiagnosticsLog
from repro.core import model as amodel
from repro.core import multicast as mc
from repro.core import simulator
from repro.core.fabric import ClusterLease, Overloaded
from repro.core.faults import (
    PROBE_N, CompletionTimeout, FaultError, FaultInjector, SessionHealth,
    deadline_cycles,
)
from repro.core.jobs import PaperJob, make_axpy, stack_instances
from repro.core.offload import (
    FusedHandle, JobHandle, OffloadConfig, OffloadRuntime, PlanStats,
)
from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.phases import Phase
from repro.core.policy import (
    AUTO, InfoDist, OffloadPolicy, Residency, RetryPolicy, Staging,
)
from repro.core.scoreboard import (
    ISSUED, GraphError, GraphNode, InflightWindow, Ref, Scoreboard,
    resolve_graph,
)
from repro.core.stream import OffloadStream

#: dispatch-constant phases — paid once per launch, amortized by fusion
CONST_PHASES = (Phase.A, Phase.B, Phase.C, Phase.D, Phase.H, Phase.I)


def amortized_per_job(phases: Mapping[Phase, float], fuse: int,
                      window: int) -> float:
    """The per-job amortization model over a set of eq.-4 phase terms
    (module docstring): t_const/B + t_E + t_F + t_G serially, with the
    host-side work (constant + staging) hidden behind the previous
    launch's device phases once the window is open.  Shared by
    :meth:`Planner.per_job_cycles` and :func:`estimate` so the model has
    one definition."""
    const = sum(phases.get(p, 0.0) for p in CONST_PHASES)
    e = phases.get(Phase.E, 0.0)
    fg = phases.get(Phase.F, 0.0) + phases.get(Phase.G, 0.0)
    if window > 1:
        return max(const / fuse + e, fg)
    return const / fuse + e + fg


def predict_staging(nbytes: float, clusters: Union[int, Sequence[int]],
                    staging: Union[str, Staging],
                    params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Closed-form host-link staging prediction for one replicated operand.

    The §6-style contract surface for phase-E staging: ``DIRECT`` and
    ``HOST_FANOUT`` both move O(n) logical host-link bytes and share the
    O(n) closed form; ``TREE`` / ``TREE_RESHARD`` share the O(1)-upload
    tree form.  Validated (< 15 % vs. the discrete-event
    ``simulate_staging``) by the ``staging`` bench suite and
    ``tests/test_session.py`` against the recorded ``BENCH_offload.json``
    points.
    """
    staging = Staging(staging)
    mode = ("tree" if staging in (Staging.TREE, Staging.TREE_RESHARD)
            else "host_fanout")
    return simulator.staging_model(nbytes, clusters, mode, params)


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """The planner's resolution of an :class:`OffloadPolicy`'s open fields."""

    n: int
    staging: Staging
    fuse: int                 # B instances per launch (1 = unfused)
    window: int               # in-flight launches (1 = synchronous)
    residency: Residency
    reason: str = ""          # one-line planner note (why these modes)


@dataclasses.dataclass(frozen=True)
class Estimate:
    """Predicted cost of an offload under a decision (paper §6 surface).

    ``phases`` are the eq.-4 per-phase terms of ONE job on ``n`` clusters
    (multicast implementation; the baseline is simulated instead —
    §5.6).  ``job_cycles`` is the modeled end-to-end runtime of one job
    (with the beyond-paper port-saturation bound); ``per_job_cycles``
    applies the decision's fusion/pipelining amortization.
    ``staging_cycles`` predicts the host-link staging leg of the
    replicated operands for every staging strategy (the comparison the
    planner ran), keyed by ``Staging`` value.
    """

    job: str
    n: int
    batch: int
    decision: PlanDecision
    phases: Mapping[Phase, float]
    job_cycles: float
    per_job_cycles: float
    staging_cycles: Mapping[str, float]
    replicated_bytes: int

    @property
    def per_launch_phases(self) -> Dict[Phase, float]:
        """Phase terms of ONE fused launch under the decision: the
        dispatch-constant phases are paid once, the batch-scaling phases
        (E operand staging, F compute, G writeback) carry all B stacked
        instances.  Equal to ``phases`` when the launch is unfused."""
        B = self.decision.fuse
        return {ph: (v if ph in CONST_PHASES else v * B)
                for ph, v in self.phases.items()}

    @property
    def per_instance_phases(self) -> Dict[Phase, float]:
        """Phase terms attributable to one instance of a fused launch:
        the dispatch constant amortized over B, the batch-scaling phases
        at their single-instance size.  Equal to ``phases`` when
        unfused."""
        B = self.decision.fuse
        return {ph: (v / B if ph in CONST_PHASES else v)
                for ph, v in self.phases.items()}

    def table(self) -> str:
        """Phase-by-phase breakdown, render-ready (fig. 11 shape).

        For a fused decision (B > 1) each phase reports the
        *per-instance* and *per-launch* terms side by side — a stacked
        batch is otherwise ambiguous about which of the two a number
        means."""
        lines = [f"estimate {self.job} n={self.n} batch={self.batch} "
                 f"[staging={self.decision.staging.value} "
                 f"fuse={self.decision.fuse} window={self.decision.window}]"]
        B = self.decision.fuse
        per_inst = self.per_instance_phases
        per_launch = self.per_launch_phases
        for ph in Phase:
            if ph in self.phases:
                if B > 1:
                    lines.append(
                        f"  phase {ph.name}: per-instance "
                        f"{per_inst[ph]:12.1f} cyc | per-launch (B={B}) "
                        f"{per_launch[ph]:12.1f} cyc")
                else:
                    lines.append(f"  phase {ph.name}: "
                                 f"{self.phases[ph]:12.1f} cyc")
        lines.append(f"  job total:  {self.job_cycles:12.1f} cyc "
                     f"(per-job amortized: {self.per_job_cycles:.1f})")
        if self.replicated_bytes:
            stag = ", ".join(f"{k}={v:.0f}"
                             for k, v in self.staging_cycles.items())
            lines.append(f"  staging leg ({self.replicated_bytes} replicated "
                         f"bytes): {stag} cyc")
        if self.decision.reason:
            lines.append(f"  planner: {self.decision.reason}")
        return "\n".join(lines)

    __str__ = table


class Planner:
    """Model-driven mode selection: fills an ``OffloadPolicy``'s open
    fields from the simulator's dispatch and staging cost models."""

    #: candidate fusion factors (powers of two keep the compiled-program
    #: count per plan small; 8 matches the bench sweep's upper end)
    FUSE_CANDIDATES = (1, 2, 4, 8)

    #: substrate-validity guard for tree staging in :meth:`decide`: the
    #: cycle model (a serial host link, §4.1) says the fan-out tree wins
    #: from n >= 4 at any size, but this framework's test substrate has a
    #: parallel, cache-dominated host link where a sub-MiB replicated
    #: ``device_put`` is near-free and d2d tree copies are not — the
    #: recorded ``staging_wall`` suite shows the tree winning wallclock
    #: only in the bandwidth-bound regime (1.34x at 32 MiB, n=8).  Below
    #: this footprint ``decide`` stays on the substrate's native DIRECT
    #: path; set it to 0 for a model-faithful (Occamy-like, serial-link)
    #: substrate.  ``pick_staging`` itself is the pure cycle-domain
    #: ordering either way — it is what ``estimate`` reports and what the
    #: staging-suite acceptance validates.
    TREE_MIN_BYTES = 8 << 20

    def __init__(self, params: OccamyParams = DEFAULT_PARAMS,
                 max_fuse: int = 8,
                 tree_min_bytes: Optional[int] = None):
        self.params = params
        self.max_fuse = max_fuse
        self.tree_min_bytes = (self.TREE_MIN_BYTES if tree_min_bytes is None
                               else tree_min_bytes)

    # -- model pieces -------------------------------------------------------

    def replicated_bytes(self, job: PaperJob,
                         operands: Optional[Mapping[str, Any]] = None) -> int:
        """Host-link-replicated operand footprint (shard_axes None)."""
        if operands is None:
            operands, _ = job.make_instance(0)
        return sum(int(np.asarray(v).nbytes)
                   for k, v in operands.items()
                   if job.shard_axes.get(k) is None)

    def staging_cost(self, nbytes: int,
                     clusters: Union[int, Sequence[int]],
                     staging: Staging) -> float:
        """Discrete-event staging cycles of the replicated footprint —
        the simulator's view, used for *decisions* (the closed form of
        :func:`predict_staging` is the prediction contract)."""
        if nbytes <= 0:
            return 0.0
        mode = ("tree" if staging in (Staging.TREE, Staging.TREE_RESHARD)
                else "host_fanout")
        return simulator.simulate_staging(nbytes, clusters, mode, self.params)

    def per_job_cycles(self, spec: simulator.JobSpec, n: int,
                       fuse: int = 1, window: int = 1) -> float:
        """The amortization model (module docstring): eq.-4 terms with
        the dispatch constant paid per launch and host work overlapped
        when the window is open."""
        return amortized_per_job(amodel.predict(spec, n, self.params).terms,
                                 fuse, window)

    # -- decisions ----------------------------------------------------------

    def pick_staging(self, nbytes: int,
                     clusters: Union[int, Sequence[int]]) -> Staging:
        n = clusters if isinstance(clusters, int) else len(list(clusters))
        if nbytes <= 0 or n < 2:
            return Staging.DIRECT   # nothing to fan out
        tree = self.staging_cost(nbytes, clusters, Staging.TREE)
        fanout = self.staging_cost(nbytes, clusters, Staging.HOST_FANOUT)
        # DIRECT delegates to the substrate but moves the same O(n)
        # logical host-link bytes as the explicit fan-out
        return Staging.TREE if tree <= fanout else Staging.DIRECT

    def pick_fuse(self, spec: simulator.JobSpec, n: int, batch: int) -> int:
        """Fuse when (and only when) the job is dispatch/staging-bound.

        The eq.-4 terms split a launch into host-side work (the dispatch
        constant + phase-E staging) and device work (F + G).  In the
        fine-grained regime — host work >= device work, the paper's
        motivating case — fusing amortizes the host critical path across
        the largest batch.  Compute-bound jobs pipeline instead: the
        open window already hides the host work behind the previous
        launch's compute, while fusing would defer job 0's launch behind
        B-1 extra stacked stagings for no modeled gain (per-job device
        work is B-independent).
        """
        cands = [b for b in self.FUSE_CANDIDATES
                 if b <= min(batch, self.max_fuse)]
        if len(cands) <= 1:
            return 1
        terms = amodel.predict(spec, n, self.params).terms
        host = (sum(terms.get(p, 0.0) for p in CONST_PHASES)
                + terms.get(Phase.E, 0.0))
        device = terms.get(Phase.F, 0.0) + terms.get(Phase.G, 0.0)
        return max(cands) if host >= device else 1

    def pick_window(self, batch: int, fuse: int, n_units: int) -> int:
        """In-flight launches: the eq.-4 overlap model says pipelining
        never hurts (host constant + staging hide behind device phases),
        so open the window to the completion-unit bound.  A multi-job
        submit needs no more than its launch count; a single-job submit
        keeps the window open for the submits that follow it (the
        session is the stream)."""
        if batch > 1:
            launches = math.ceil(batch / fuse)
            return max(1, min(n_units, launches))
        return max(1, n_units)

    def decide(self, job: PaperJob, clusters: Union[int, Sequence[int]],
               batch: int, policy: OffloadPolicy, n_units: int,
               operands: Optional[Mapping[str, Any]] = None) -> PlanDecision:
        n = clusters if isinstance(clusters, int) else len(list(clusters))
        resident = policy.residency is Residency.RESIDENT
        if policy.fuse is not None:
            # a pinned fuse factor is clamped to the submitted batch —
            # the launches that actually run (mirrors pick_fuse's cap),
            # so explain()/estimate never report a mode that never ran
            fuse = min(policy.fuse, max(batch, 1))
        elif resident and batch <= 1:
            # resident single-job redispatch reuses unfused buffers;
            # fusing would need a staged (B, ...) batch
            fuse = 1
        else:
            fuse = self.pick_fuse(job.spec, n, batch)
        if policy.staging is not None:
            staging = policy.staging
        elif resident:
            staging = Staging.DIRECT  # resident redispatch stages nothing
        else:
            # a fused launch stages the stacked batch as ONE B-times
            # larger replicated transfer (the B instances ride one
            # tree), so the bandwidth-regime guard sees B * rep bytes
            rep = self.replicated_bytes(job, operands) * fuse
            # the TREE_MIN_BYTES guard: only ride the tree where the
            # serial-link model's premise holds on this substrate
            staging = (self.pick_staging(rep, clusters)
                       if rep >= self.tree_min_bytes else Staging.DIRECT)
        window = (policy.window if policy.window is not None
                  else self.pick_window(batch, fuse, n_units))
        reason = (f"staging={staging.value} "
                  f"({'pinned' if policy.staging is not None else 'model'}), "
                  f"fuse={fuse} "
                  f"({'pinned' if policy.fuse is not None else 'model'}), "
                  f"window={window} "
                  f"({'pinned' if policy.window is not None else 'model'})")
        return PlanDecision(n=n, staging=staging, fuse=fuse, window=window,
                            residency=policy.residency, reason=reason)


def estimate(job: PaperJob, *,
             n: Optional[int] = None,
             clusters: Optional[Sequence[int]] = None,
             batch: int = 1,
             policy: Optional[OffloadPolicy] = None,
             n_units: int = 4,
             params: OccamyParams = DEFAULT_PARAMS,
             operands: Optional[Mapping[str, Any]] = None,
             planner: Optional[Planner] = None) -> Estimate:
    """Predict an offload's phase-by-phase cost under ``policy`` (model
    only — needs no devices, works at any ``n`` up to the Occamy
    topology).  The session's ``<15 %``-error contract surface: for the
    multicast implementation ``job_cycles`` is the paper's §6 analytical
    model (with the port-saturation refinement); the baseline
    implementation is simulated instead (§5.6: the paper models the
    extended system only).
    """
    policy = AUTO if policy is None else policy
    if (n is None) == (clusters is None):
        raise ValueError("give exactly one of n / clusters")
    sel: Union[int, List[int]] = (int(n) if n is not None
                                  else sorted(int(c) for c in clusters))
    n_eff = sel if isinstance(sel, int) else len(sel)
    if not (1 <= n_eff <= params.num_clusters):
        raise ValueError(f"n={n_eff} outside [1, {params.num_clusters}]")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    planner = planner or Planner(params)
    decision = planner.decide(job, sel, batch, policy, n_units,
                              operands=operands)

    if policy.info_dist is InfoDist.MULTICAST:
        phases = dict(amodel.predict(job.spec, n_eff, params).terms)
        job_cycles = amodel.predict_total_v2(job.spec, n_eff, params)
    else:
        sim = simulator.simulate(job.spec, n_eff, "baseline", params)
        phases = {ph: st.max for ph, st in sim.phase_stats().items()}
        job_cycles = sim.total

    per_job = amortized_per_job(phases, decision.fuse, decision.window)

    rep_bytes = planner.replicated_bytes(job, operands)
    staging_cycles = {}
    if rep_bytes > 0:
        for s in (Staging.DIRECT, Staging.HOST_FANOUT, Staging.TREE):
            staging_cycles[s.value] = predict_staging(rep_bytes, sel, s,
                                                      params)
    return Estimate(job=job.spec.name, n=n_eff, batch=batch,
                    decision=decision, phases=phases, job_cycles=job_cycles,
                    per_job_cycles=per_job, staging_cycles=staging_cycles,
                    replicated_bytes=rep_bytes)


@dataclasses.dataclass
class Explain:
    """Predicted breakdown next to the measured dispatch counters."""

    estimate: Estimate
    stats: PlanStats            # measured counters of the plans involved
    jobs: int
    wall_s: Optional[float] = None   # end-to-end, once waited
    findings: List[Any] = dataclasses.field(default_factory=list)

    def table(self) -> str:
        lines = [self.estimate.table(), f"measured ({self.jobs} jobs):"]
        for f in dataclasses.fields(PlanStats):
            lines.append(f"  {f.name}: {getattr(self.stats, f.name)}")
        if self.wall_s is not None:
            lines.append(f"  wall_s: {self.wall_s:.6f} "
                         f"({self.wall_s / max(self.jobs, 1) * 1e6:.1f} "
                         "us/job)")
        if self.findings:
            lines.append(f"perf findings ({len(self.findings)}):")
            for pf in self.findings:
                lines.append(f"  {pf}")
        return "\n".join(lines)

    __str__ = table


class SessionHandle:
    """In-flight submit: one job or a fused/pipelined batch of them.

    ``wait()`` returns the result (single submit) or the per-job results
    in submit order (list submit).  ``explain()`` returns the
    :class:`Explain` pairing the predicted breakdown with measured
    :class:`PlanStats`.
    """

    def __init__(self, session: "Session", job: PaperJob,
                 est: Estimate, parts: List[Tuple[str, Any]],
                 multi: bool, plans: List[Any], submitted_at: float,
                 findings: Sequence[Any] = ()):
        self.session = session
        self.job = job
        self._estimate = est
        self._parts = parts        # [("single", JobHandle) | ("fused", FusedHandle)]
        self._multi = multi
        self._plans = plans
        self._submitted_at = submitted_at
        self._wall: Optional[float] = None
        self._result: Any = None
        self._done = False
        #: advisory OFLP1## perf findings (submit ran with lint=True)
        self.findings: List[Any] = list(findings)

    @property
    def jobs(self) -> int:
        return sum(h.batch if kind == "fused" else 1
                   for kind, h in self._parts)

    @property
    def decision(self) -> PlanDecision:
        return self._estimate.decision

    def wait(self) -> Any:
        if self._done:
            return self._result
        out: List[Any] = []
        for kind, h in self._parts:
            if kind == "fused":
                out.extend(h.wait_each())
            else:
                out.append(h.wait())
        self._wall = time.monotonic() - self._submitted_at
        self._result = out if self._multi else out[0]
        self._done = True
        return self._result

    def explain(self) -> Explain:
        """Predicted phase breakdown (paper §6) next to measured stats.

        The measured counters are the cumulative :class:`PlanStats` of
        every dispatch plan this submit ran through (plans are shared
        across submits of the same (job, selection) pair — the counters
        are the plan's running totals, the same hooks the fast-path
        tests assert against).
        """
        agg = PlanStats()
        for plan in self._plans:
            if plan is not None:
                agg.accumulate(plan.stats)
        return Explain(estimate=self._estimate, stats=agg, jobs=self.jobs,
                       wall_s=self._wall, findings=list(self.findings))


class ReliableHandle:
    """In-flight *reliable* submit — the fault-tolerant path's handle.

    A policy with ``retry=RetryPolicy(...)`` routes ``Session.submit``
    here: every job instance runs under a model-driven deadline
    (:func:`repro.core.faults.deadline_cycles` over the §6 estimate) and,
    on a trip, the session walks the escalation ladder — resubmit in
    place, disjoint backup window, full lease failover.  ``wait()``
    executes the ladder synchronously per instance and returns results in
    submit order; recoverable faults leave the results bit-identical to a
    fault-free run.
    """

    def __init__(self, session: "Session", job: PaperJob, est: Estimate,
                 instances: List[Mapping[str, np.ndarray]],
                 args_list: Optional[List[np.ndarray]],
                 pol: OffloadPolicy, retry: RetryPolicy,
                 multi: bool, sel: Sequence[int]):
        self.session = session
        self.job = job
        self._estimate = est
        self._instances = instances
        self._args: List[Optional[np.ndarray]] = (
            list(args_list) if args_list is not None
            else [None] * len(instances))
        self._pol = pol
        self._retry = retry
        self._multi = multi
        self._sel = list(sel)
        self._result: Any = None
        self._done = False

    @property
    def jobs(self) -> int:
        return len(self._instances)

    @property
    def decision(self) -> PlanDecision:
        return self._estimate.decision

    def wait(self) -> Any:
        if self._done:
            return self._result
        out: List[Any] = []
        for inst, args in zip(self._instances, self._args):
            data, sel = self.session._run_reliable(
                self.job, inst, args, self._pol, self._retry,
                list(self._sel))
            # job k+1 starts from the post-recovery selection: a failover
            # or degradation carries forward instead of re-tripping
            self._sel = list(sel)
            out.append(data)
        self._result = out if self._multi else out[0]
        self._done = True
        return self._result

    def explain(self) -> Explain:
        return Explain(estimate=self._estimate, stats=self.session.stats,
                       jobs=self.jobs, wall_s=None)


class GraphHandle:
    """An in-flight dependency graph (:meth:`Session.submit_graph`).

    One :class:`~repro.core.offload.JobHandle` per node, issued by the
    scoreboard in dependency order with producer results forwarded
    device-to-device.  ``wait()`` retires every node (completion
    doorbells only) and fetches just the *fetch* nodes' results — the
    sinks by default — keyed by node name (or index when unnamed);
    intermediate results never cross the host link, which the owning
    plans' ``stats.d2h_bytes`` counters prove exactly.  ``result(node)``
    fetches any single node on demand.  Both are idempotent.

    ``forwarded`` maps each dataflow edge ``(producer, consumer,
    operand)`` to its logical d2d byte count (0 for a same-sharding
    alias or rename copy — no fabric edge crossed).
    """

    def __init__(self, nodes: Sequence[GraphNode], sb: Scoreboard,
                 handles: List[JobHandle], fetch: List[int],
                 forwarded: Dict[Tuple[int, int, str], int],
                 window_stalls: int):
        self.nodes = list(nodes)
        self._sb = sb
        self._handles = handles
        self._fetch = fetch
        self.forwarded = forwarded
        self.window_stalls = window_stalls
        self._keys: List[Union[int, str]] = [
            nd.name if nd.name is not None else i
            for i, nd in enumerate(self.nodes)]
        self._results: Optional[Dict[Union[int, str], Any]] = None
        #: advisory OFLP1## perf findings (graph submitted with lint=True)
        self.findings: List[Any] = []

    @property
    def issue_order(self) -> List[int]:
        """The order the scoreboard actually issued nodes in."""
        return list(self._sb.issue_order)

    @property
    def max_inflight(self) -> int:
        return self._sb.max_inflight

    def _node_index(self, node: Union[int, str]) -> int:
        if isinstance(node, str):
            for i, nd in enumerate(self.nodes):
                if nd.name == node:
                    return i
            raise GraphError(f"unknown node name {node!r}")
        idx = int(node)
        if not 0 <= idx < len(self.nodes):
            raise GraphError(
                f"node index {idx} outside [0, {len(self.nodes)})")
        return idx

    def _retire_all(self) -> None:
        """Retire every node (completion only, no result fetch).

        Tolerant in shape: a :class:`CompletionTimeout` on one node
        still retires the rest (abandoning them would leak their
        completion-unit copies), then the first fault re-raises.
        """
        fault: Optional[CompletionTimeout] = None
        for i, h in enumerate(self._handles):
            try:
                h.retire()
            except CompletionTimeout as exc:
                if fault is None:
                    fault = exc
            if self._sb.state[i] == ISSUED:
                self._sb.retire(i)
        if fault is not None:
            raise fault

    def wait(self) -> Dict[Union[int, str], Any]:
        """Retire the whole graph; fetch and return the fetch nodes'
        results, keyed by node name (or index when unnamed)."""
        if self._results is not None:
            return dict(self._results)
        self._retire_all()
        self._results = {self._keys[i]: self._handles[i].wait()
                         for i in self._fetch}
        return dict(self._results)

    def result(self, node: Union[int, str]) -> Any:
        """Fetch one node's result by name or index (idempotent; counts
        its payload into the owning plan's ``d2h_bytes`` on first
        fetch)."""
        return self._handles[self._node_index(node)].wait()


class Session:
    """The unified offload front door: typed policies, one submit path.

    A session owns one :class:`OffloadRuntime` per distinct
    :class:`OffloadConfig` a policy implies (multicast and baseline
    submits may share a session), a planner, and the pipelined stream
    state that makes successive single submits overlap.  ``policy``
    (default :data:`~repro.core.policy.AUTO`) is the session default;
    every ``submit``/``estimate`` accepts a per-call override.
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None, *,
                 lease: Optional[ClusterLease] = None,
                 policy: OffloadPolicy = AUTO,
                 n_units: int = 4,
                 params: OccamyParams = DEFAULT_PARAMS,
                 planner: Optional[Planner] = None,
                 runtime: Optional[OffloadRuntime] = None,
                 faults: Optional[FaultInjector] = None,
                 verify: bool = True,
                 lint: bool = False,
                 diag_limit: int = 256):
        if runtime is not None and devices is not None:
            raise ValueError("give devices or a runtime, not both")
        if lease is not None and (devices is not None or runtime is not None):
            raise ValueError("give a lease or devices/runtime, not both")
        if not isinstance(policy, OffloadPolicy):
            raise TypeError(f"policy must be an OffloadPolicy, got "
                            f"{type(policy).__name__}")
        self.policy = policy
        self.n_units = n_units
        self.verify = bool(verify)
        self.lint = bool(lint)
        self.params = params
        self.planner = planner or Planner(params)
        self._faults = faults
        self._health = SessionHealth()
        self._runtimes: Dict[OffloadConfig, OffloadRuntime] = {}
        self._closed = False
        self._suspended = False       # lease preempted, awaiting re-place
        self._preempt_snaps: List[Tuple] = []
        self._drain_deadline = 0.0    # model drain budget of the last suspend
        if lease is not None:
            # the session binds the lease's fabric window, not the global
            # mesh: submits select within it, plans/trees key on its
            # global cluster ids, close() returns it to the scheduler
            self._devices = list(lease.devices)
            self._cluster_ids: Tuple[int, ...] = tuple(lease.clusters)
            self._lease: Optional[ClusterLease] = lease
            if lease.scheduler is not None:
                # register for failover callbacks: fail_clusters() rebinds
                # this session onto the replacement window in place
                lease.scheduler._bind_session(lease, self)
        elif runtime is not None:
            self._devices = list(runtime.all_devices)
            self._cluster_ids = tuple(runtime.cluster_ids)
            self._lease = None
            if faults is not None:
                runtime.fault_injector = faults
            self._runtimes[self._cfg_key(runtime.config)] = runtime
        else:
            if devices is None:
                import jax
                devices = jax.devices()
            self._devices = list(devices)
            self._cluster_ids = tuple(range(len(self._devices)))
            self._lease = None
        self._streams: Dict[Tuple, OffloadStream] = {}
        self._fused_inflight: Deque[FusedHandle] = collections.deque()
        self._graphs: List["GraphHandle"] = []
        # estimates are deterministic per (job, selection, batch, policy):
        # cache them so warm submits pay no model arithmetic
        self._est_cache: Dict[Tuple, Estimate] = {}
        # perf-lint findings are deterministic over the same key
        self._lint_cache: Dict[Tuple, List[Any]] = {}
        # verify warnings + lint findings land here, ring-buffered so a
        # long-lived serve loop holds memory flat (diag_limit caps it)
        self._diags = DiagnosticsLog(diag_limit)
        # stage() residency ledger for the OFLP106 pass: (job, selection)
        # -> staging cycles paid and how many resident submits reused it
        self._staged_residency: Dict[Tuple, Dict[str, Any]] = {}

    @property
    def devices(self) -> List[Any]:
        return list(self._devices)

    @property
    def diagnostics(self) -> "DiagnosticsLog":
        """The session's bounded diagnostics table: the most recent
        ``diag_limit`` verify warnings and perf-lint findings
        (:class:`~repro.analysis.diagnostics.DiagnosticsLog`), with
        ``total``/``dropped`` counters that never lose count."""
        return self._diags

    @property
    def lease(self) -> ClusterLease:
        """The fabric window this session owns.  A session constructed
        the pre-scheduler way (devices / runtime / default) reports its
        whole window as a synthesized one-tenant lease — the legacy
        whole-mesh path *is* the single-tenant special case."""
        if self._lease is not None:
            return self._lease
        # the descriptor names the cluster *set*; an adopted runtime may
        # order its window arbitrarily (device i <-> cluster_ids[i])
        return ClusterLease(lease_id=0, tenant="default",
                            clusters=tuple(sorted(self._cluster_ids)))

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Drain in-flight work and release the lease (idempotent).

        After ``close()`` every submit/stage/estimate raises
        :class:`RuntimeError` — a scheduler may have re-leased the
        window to another tenant."""
        if self._closed:
            return
        self.drain()
        self._closed = True
        if self._lease is not None and self._lease.scheduler is not None:
            self._lease.scheduler._unbind_session(self._lease)
            if self._lease.active:
                # already-released (or externally resized) leases are left
                # alone — close() is cleanup, not a second release
                self._lease.release()

    def _check_open(self, op: str) -> None:
        if self._closed:
            raise RuntimeError(
                f"{op} on a closed session (its lease over clusters "
                f"{self._cluster_ids} was released)")
        if self._suspended:
            raise RuntimeError(
                f"{op} on a suspended session: its lease was preempted "
                "and is queued for re-placement (resident operands are "
                "snapshotted and restage on resume)")

    # -- plumbing -----------------------------------------------------------

    @staticmethod
    def _cfg_key(cfg: OffloadConfig) -> OffloadConfig:
        """Runtime-map key: the session passes the staging mode on every
        stage call, so a runtime's staging *default* must not split the
        map (an adopted runtime with staging=TREE still backs DIRECT
        submits and vice versa)."""
        return dataclasses.replace(cfg, staging=Staging.DIRECT)

    def _runtime_for(self, policy: OffloadPolicy) -> OffloadRuntime:
        cfg = OffloadConfig(info_dist=policy.info_dist,
                            completion=policy.completion,
                            donate_operands=policy.donate_operands)
        return self._runtime_from_cfg(cfg)

    def _runtime_from_cfg(self, cfg: OffloadConfig) -> OffloadRuntime:
        key = self._cfg_key(cfg)
        rt = self._runtimes.get(key)
        if rt is None:
            rt = OffloadRuntime(self._devices, config=cfg,
                                n_units=self.n_units,
                                cluster_ids=self._cluster_ids,
                                fault_injector=self._faults)
            self._runtimes[key] = rt
        return rt

    @staticmethod
    def _sel_key(n, request, clusters) -> Tuple:
        if request is not None:
            return ("request", request.addr, request.mask)
        if clusters is not None:
            return ("clusters", tuple(sorted(clusters)))
        return ("n", n)

    def _selection_ids(self, policy: OffloadPolicy, n, request, clusters
                       ) -> Tuple[List[int], Optional[int]]:
        rt = self._runtime_for(policy)
        if n is None and request is None and clusters is None:
            n = len(self._devices)
        _, ids = rt.select_clusters(
            n=n if (request is None and clusters is None) else None,
            request=request, clusters=clusters)
        return list(ids), n

    def _stream_for(self, job: PaperJob, policy: OffloadPolicy,
                    decision: PlanDecision, n, request, clusters
                    ) -> OffloadStream:
        rt = self._runtime_for(policy)
        key = (job.spec.name, self._sel_key(n, request, clusters),
               rt.config, decision.staging, decision.window, policy.depth)
        stream = self._streams.get(key)
        if stream is None:
            stream = OffloadStream(rt, job, n=n, request=request,
                                   clusters=clusters, depth=policy.depth,
                                   window=decision.window,
                                   staging=decision.staging, _warn=False)
            self._streams[key] = stream
        return stream

    # -- the submit path ----------------------------------------------------

    def submit(self, job: PaperJob,
               operands: Union[Mapping[str, np.ndarray],
                               Sequence[Mapping[str, np.ndarray]],
                               Residency],
               *,
               policy: Optional[OffloadPolicy] = None,
               job_args: Optional[Union[np.ndarray,
                                        Sequence[np.ndarray]]] = None,
               n: Optional[int] = None,
               request: Optional[mc.MulticastRequest] = None,
               clusters: Optional[Sequence[int]] = None,
               after: Sequence[Any] = (),
               lint: Optional[bool] = None) -> SessionHandle:
        """Dispatch ``job`` under a typed policy — the one submit path.

        ``after`` adds ordering edges on in-flight handles
        (:class:`SessionHandle`, :class:`GraphHandle`, or raw job
        handles): a predecessor sharing clusters with this selection is
        ordered for free (per-device launch order serializes on the
        shared lease), a disjoint one gets a conservative completion
        barrier — its doorbell is collected (``retire()``), never its
        result payload.  For dataflow (consuming a predecessor's
        *result*), use :meth:`submit_graph`.

        ``operands`` selects the shape of the submit:

        * a dict — one job instance (phase-E staged per the decision's
          staging mode, pipelined against other in-flight submits of the
          same (job, selection) pair when the window is open);
        * a sequence of dicts — B(atch) instances; the planner (or the
          pinned policy) fuses them into ⌈batch/fuse⌉ launches and
          pipelines those through the window;
        * ``Residency.RESIDENT`` — redispatch the plan's resident
          buffers with zero staging (``policy.fuse`` > 1 selects the
          resident *fused* batch).

        Returns a :class:`SessionHandle`; ``wait()`` yields the result
        (dict submit) or per-job results in submit order (list submit),
        ``explain()`` the predicted-vs-measured breakdown.

        ``lint=True`` (or ``Session(lint=True)``) additionally runs the
        performance linter (:mod:`repro.analysis.perflint`) over the
        submit: advisory ``OFLP1##`` findings — never a gate — land in
        :attr:`Session.diagnostics`, on ``handle.findings``, and in
        ``handle.explain()``.
        """
        self._check_open("submit")
        pol = self.policy if policy is None else policy
        if pol.retry is not None:
            # reliable dispatch is synchronous: barrier every predecessor
            for h in after:
                for jh in self._job_handles_of(h):
                    jh.retire()
            return self._submit_reliable(job, operands, pol, job_args,
                                         n, request, clusters)
        resident = isinstance(operands, Residency)
        if resident:
            if operands is not Residency.RESIDENT:
                raise ValueError(
                    "pass an operand dict, a sequence of them, or "
                    "Residency.RESIDENT")
            # a resident submit stages nothing: drop any pinned staging
            # along with the residency pin, so a policy whose staging
            # primed the buffers (e.g. TREE via sess.stage) is reusable
            # here instead of tripping the RESIDENT+staging contradiction
            pol = pol.pinned(residency=Residency.RESIDENT, staging=None)
        elif isinstance(operands, str):
            raise TypeError(
                "the session API takes typed operands: an operand dict, a "
                "sequence of them, or Residency.RESIDENT (the legacy "
                "'resident' string lives on offload() only)")
        multi = (not resident
                 and isinstance(operands, (list, tuple)))
        if multi and not operands:
            raise ValueError("empty instance list")
        if not multi and not resident and not isinstance(operands, Mapping):
            raise TypeError(f"unsupported operands {type(operands)!r}")
        if self.verify and not resident:
            self._verify_submit(job, operands, n, request, clusters)

        ids, n = self._selection_ids(pol, n, request, clusters)
        if after:
            mine = set(ids)
            for h in after:
                for jh in self._job_handles_of(h):
                    if not (set(jh.cluster_ids) & mine):
                        jh.retire()   # disjoint: completion barrier
        batch = (len(operands) if multi
                 else (pol.fuse or 1) if resident else 1)
        first_ops = (operands[0] if multi
                     else None if resident else operands)
        if resident:
            entry = self._staged_residency.get((job.spec.name, tuple(ids)))
            if entry is not None:
                entry["uses"] += 1
        cache_key = (job.spec.name, tuple(ids), batch, pol)
        est = self._est_cache.get(cache_key)
        if est is None:
            est = estimate(job, clusters=ids, batch=batch, policy=pol,
                           n_units=self.n_units, params=self.params,
                           operands=first_ops, planner=self.planner)
            self._est_cache[cache_key] = est
        findings = self._lint_submit(
            job, first_ops, pol, batch, ids,
            self.lint if lint is None else lint, cache_key)
        self._slo_gate(est, batch)
        decision = est.decision
        rt = self._runtime_for(pol)
        t0 = time.monotonic()
        parts: List[Tuple[str, Any]] = []
        plans: List[Any] = []

        if resident and decision.fuse > 1:
            h = rt._offload_fused(job, Residency.RESIDENT,
                                  job_args=_one_args(job_args),
                                  n=n, request=request, clusters=clusters,
                                  batch=decision.fuse,
                                  staging=decision.staging)
            parts.append(("fused", h))
            plans.append(self._last_fused_plan(rt, job, decision.fuse, ids))
        elif not multi:
            stream = self._stream_for(job, pol, decision, n, request,
                                      clusters)
            h = stream.submit(
                Residency.RESIDENT if resident else operands,
                _one_args(job_args))
            parts.append(("single", h))
            plans.append(stream.plan)
        else:
            B = decision.fuse
            args_list = _args_list(job_args, batch)
            i = 0
            if B > 1:
                # like OffloadStream, the in-flight window is capped by
                # the runtime's completion-unit copies: launch k and
                # launch k + n_units share a unit, so k must have
                # completed first
                window = min(decision.window, rt.unit.n_units)
                while batch - i >= B:
                    group = list(operands[i:i + B])
                    gargs = _stack_args(args_list, i, B)
                    while (len(self._fused_inflight) >= window
                           and self._fused_inflight):
                        self._fused_inflight.popleft().wait()
                    h = rt._offload_fused(job, group, job_args=gargs,
                                          n=n, request=request,
                                          clusters=clusters,
                                          staging=decision.staging)
                    self._fused_inflight.append(h)
                    parts.append(("fused", h))
                    i += B
                if parts:
                    plans.append(self._last_fused_plan(rt, job,
                                                       decision.fuse, ids))
            if i < batch:
                # remainder (or the unfused path): pipelined singles
                stream = self._stream_for(job, pol, decision, n, request,
                                          clusters)
                for k in range(i, batch):
                    h = stream.submit(
                        operands[k],
                        args_list[k] if args_list is not None else None)
                    parts.append(("single", h))
                plans.append(stream.plan)

        return SessionHandle(self, job, est, parts, multi or
                             (resident and decision.fuse > 1), plans, t0,
                             findings=findings)

    def _lint_submit(self, job: PaperJob, first_ops: Any,
                     pol: OffloadPolicy, batch: int, ids: Sequence[int],
                     lint: bool, cache_key: Tuple) -> List[Any]:
        """Run (and cache) the perf linter for one submit; findings are
        recorded in the session diagnostics log the first time only."""
        if not lint:
            return []
        findings = self._lint_cache.get(cache_key)
        if findings is None:
            from repro.analysis import perflint
            findings = perflint.lint(
                job, first_ops, policy=pol, batch=batch,
                clusters=list(ids), allowed=self._cluster_ids,
                n_units=self.n_units, params=self.params,
                planner=self.planner)
            self._lint_cache[cache_key] = findings
            self._diags.record(f.diagnostic for f in findings)
        return findings

    def _verify_submit(self, job: PaperJob, operands: Any, n, request,
                       clusters) -> None:
        """The static pre-dispatch gate (``Session(verify=False)`` skips).

        Use-after-donate (OFL003) raises the historical
        :class:`~repro.core.offload.DonatedOperandError` — now *before*
        any staging instead of at wait time; other error diagnostics
        (sharding mismatch OFL006, inactive lease OFL011) raise
        :class:`~repro.analysis.verifier.VerificationError`.
        """
        from repro.analysis import verifier as _verifier
        from repro.analysis.diagnostics import Severity
        if n is None and request is None and clusters is None:
            n = len(self._devices)
        diags = _verifier.verify(job, lease=self._lease, operands=operands,
                                 n=None if request is not None else n,
                                 clusters=clusters, n_units=self.n_units)
        # every diagnostic — warnings included — lands in the session's
        # ring-buffered log (they used to be computed then discarded)
        self._diags.record(diags)
        errors = [d for d in diags if d.severity is Severity.ERROR]
        if not errors:
            return
        donated = [d for d in errors if d.code == "OFL003"]
        if donated:
            from repro.core.offload import DonatedOperandError
            # the diagnostic message is "<what> was deleted by ...": hand
            # the <what> back to the historical exception type
            what = donated[0].message.split(" was deleted by ")[0]
            raise DonatedOperandError(what)
        raise _verifier.VerificationError(errors)

    @staticmethod
    def _job_handles_of(h: Any) -> List[JobHandle]:
        """Flatten an ``after=`` predecessor to its raw job handles."""
        if isinstance(h, SessionHandle):
            return [p for _, p in h._parts]
        if isinstance(h, GraphHandle):
            return list(h._handles)
        if isinstance(h, JobHandle):
            return [h]
        raise TypeError(
            f"after= takes session/graph/job handles, got "
            f"{type(h).__name__}")

    # -- dependent job graphs -----------------------------------------------

    def submit_graph(self, nodes: Sequence[GraphNode], *,
                     policy: Optional[OffloadPolicy] = None,
                     lint: Optional[bool] = None) -> GraphHandle:
        """Dispatch a DAG of dependent jobs like an out-of-order core.

        ``nodes`` are :class:`~repro.core.scoreboard.GraphNode`\\ s whose
        operands may be host arrays, ``Residency.RESIDENT``, or
        :class:`~repro.core.scoreboard.Ref`\\ s to earlier nodes'
        results; ``after=`` entries add pure ordering edges.  The
        scoreboard (Active List + Integer Queue) issues every node whose
        producers have *issued* — async dispatch chains the data
        device-side, so independent sub-DAGs issue concurrently across
        the in-flight window (and across leases, for nodes carrying
        ``session=`` of another session).  Producer results are
        forwarded device-to-device to each consumer's sharding
        (:meth:`DispatchPlan.forward <repro.core.offload.DispatchPlan.forward>`
        — alias, rename copy, reshard, or fan-out tree); they are never
        fetched to the host unless the node is a *fetch* node (a sink,
        or ``fetch=True``).  WAR/WAW hazards against resident buffers
        and donating consumers are broken by renaming: graph staging
        always lands in fresh buffers.

        Returns a :class:`GraphHandle`; its ``wait()`` yields the fetch
        nodes' results keyed by name (or index).
        """
        self._check_open("submit_graph")
        pol = self.policy if policy is None else policy
        if pol.retry is not None:
            raise GraphError(
                "graph submits do not ride the retry/deadline ladder; "
                "drop policy.retry (wrap individual submits for "
                "fault-tolerant dispatch)")
        import jax
        nodes = list(nodes)
        for nd in nodes:
            if not isinstance(nd, GraphNode):
                raise GraphError(
                    f"submit_graph takes GraphNode entries, got "
                    f"{type(nd).__name__}")
        if self.verify:
            from repro.analysis import verifier as _verifier
            diags = _verifier.verify_graph(
                nodes, policy=pol, n_units=self.n_units,
                default_width=len(self._devices), session=self)
            self._diags.record(diags)
            _verifier.raise_errors(diags)
        findings: List[Any] = []
        if self.lint if lint is None else lint:
            from repro.analysis import perflint
            findings = perflint.lint_graph(
                nodes, policy=pol, n_units=self.n_units,
                default_width=len(self._devices),
                allowed=self._cluster_ids, params=self.params,
                planner=self.planner)
            self._diags.record(f.diagnostic for f in findings)
        deps, data_edges = resolve_graph(nodes)
        sb = Scoreboard(deps)
        targets: List["Session"] = []
        rts: List[OffloadRuntime] = []
        sel_kwargs: List[Dict[str, Any]] = []
        for i, nd in enumerate(nodes):
            t = nd.session if nd.session is not None else self
            if not isinstance(t, Session):
                raise GraphError(
                    f"node {i}: session= must be a Session, got "
                    f"{type(t).__name__}")
            t._check_open(f"submit_graph node {i}")
            _, n_eff = t._selection_ids(pol, nd.n, nd.request, nd.clusters)
            targets.append(t)
            rts.append(t._runtime_for(pol))
            sel_kwargs.append(dict(n=n_eff, request=nd.request,
                                   clusters=nd.clusters))
        via = pol.staging          # None -> the runtime's substrate default
        windows: Dict[int, InflightWindow] = {}
        handles: List[Optional[JobHandle]] = [None] * len(nodes)
        forwarded: Dict[Tuple[int, int, str], int] = {}

        def _drain(entry: Tuple[int, JobHandle]) -> None:
            j, h = entry
            h.retire()
            if sb.state[j] == ISSUED:
                sb.retire(j)

        while not sb.all_issued:
            i = sb.ready()[0]              # Integer Queue, age order
            nd, rt = nodes[i], rts[i]
            win = windows.get(id(rt))
            if win is None:
                limit = (pol.window if pol.window is not None
                         else rt.unit.n_units)
                win = InflightWindow(max(1, min(limit, rt.unit.n_units)))
                windows[id(rt)] = win
            job_args = np.asarray(
                nd.job_args if nd.job_args is not None
                else np.ones((8,), dtype=np.float64), dtype=np.float64)
            if isinstance(nd.operands, Residency):
                if nd.operands is not Residency.RESIDENT:
                    raise GraphError(
                        f"node {i}: pass an operand dict or "
                        "Residency.RESIDENT")
                plan = rt.plan(nd.job, operands=None,
                               args_shape=job_args.shape, **sel_kwargs[i])
                win.make_room(_drain)
                args_dev = plan.stage_args(job_args, via=via)
                staged = plan.resident_operands()
                handle = rt._launch(plan, args_dev, staged)
            else:
                ops = dict(nd.operands)
                for src, op_name in data_edges[i]:
                    # the producer's (possibly still in-flight) output —
                    # async dispatch chains it device-side
                    ops[op_name] = handles[src].result
                meta = {
                    k: (np.broadcast_to(np.zeros((), v.dtype), v.shape)
                        if isinstance(v, jax.Array) else np.asarray(v))
                    for k, v in ops.items()}
                plan = rt.plan(nd.job, operands=meta,
                               args_shape=job_args.shape, **sel_kwargs[i])
                win.make_room(_drain)
                args_dev = plan.stage_args(job_args, via=via)
                staged, fwd = plan.stage_renamed(ops, via=via)
                for src, op_name in data_edges[i]:
                    forwarded[(src, i, op_name)] = fwd.get(op_name, 0)
                handle = rt._launch(plan, args_dev, staged,
                                    consumed_resident=False)
            handles[i] = handle
            sb.issue(i)
            win.push((i, handle))

        sinks = set(sb.sinks())
        fetch = [i for i, nd in enumerate(nodes)
                 if (nd.fetch if nd.fetch is not None else i in sinks)]
        gh = GraphHandle(nodes, sb, handles, fetch, forwarded,
                         sum(w.stalls for w in windows.values()))
        gh.findings = findings
        for t in {id(t): t for t in [self] + targets}.values():
            t._graphs.append(gh)
        return gh

    # -- the fault-tolerant path --------------------------------------------

    def _submit_reliable(self, job: PaperJob, operands, pol: OffloadPolicy,
                         job_args, n, request, clusters) -> "ReliableHandle":
        """Route a retrying submit: deadline-checked synchronous singles.

        The reliable path snapshots host operands so any attempt can be
        replayed bit-identically — ``Residency.RESIDENT`` (device-only
        buffers) therefore cannot ride it."""
        retry = pol.retry
        assert retry is not None
        if isinstance(operands, (Residency, str)):
            raise ValueError(
                "retry needs host operand snapshots to replay an attempt; "
                "submit operand dicts, not Residency.RESIDENT")
        multi = isinstance(operands, (list, tuple))
        if multi and not operands:
            raise ValueError("empty instance list")
        instances = (
            [dict(o) for o in operands] if multi else [dict(operands)])
        args_list = _args_list(job_args, len(instances))
        # reliable dispatch is synchronous singles: a deadline race needs
        # one completion per attempt, not a fused/pipelined batch
        rpol = pol.pinned(
            fuse=1, window=1,
            staging=pol.staging if pol.staging is not None
            else Staging.DIRECT)
        ids, _ = self._selection_ids(rpol, n, request, clusters)
        est = self._reliable_est(job, ids, rpol)
        self._slo_gate(est, len(instances))
        return ReliableHandle(self, job, est, instances, args_list,
                              rpol, retry, multi, ids)

    def _reliable_est(self, job: PaperJob, sel_glob: Sequence[int],
                      rpol: OffloadPolicy) -> Estimate:
        key = ("reliable", job.spec.name, tuple(sel_glob), rpol)
        est = self._est_cache.get(key)
        if est is None:
            est = estimate(job, clusters=list(sel_glob), batch=1,
                           policy=rpol, n_units=self.n_units,
                           params=self.params, planner=self.planner)
            self._est_cache[key] = est
        return est

    def _rel_ids(self, globs: Sequence[int]) -> List[int]:
        """Global fabric ids -> window-relative indices (the selection
        vocabulary ``OffloadRuntime.select_clusters`` takes)."""
        idx = {c: i for i, c in enumerate(self._cluster_ids)}
        return [idx[c] for c in globs]

    def _run_reliable(self, job: PaperJob, inst: Mapping[str, np.ndarray],
                      args: Optional[np.ndarray], rpol: OffloadPolicy,
                      retry: RetryPolicy, sel_glob: List[int]
                      ) -> Tuple[Any, List[int]]:
        """One job instance through the deadline/escalation machinery.

        Returns ``(result, selection)`` — the selection the job finally
        ran on, so the caller can carry a failover forward.  All deadline
        arithmetic is in the §6 model's virtual-cycle domain: recovery is
        deterministic, never wallclock-dependent."""
        known_dead: set = set()
        attempt = 0
        while True:
            # re-fetched every attempt: a failover swaps the runtimes out
            rt = self._runtime_for(rpol)
            base = self._reliable_est(job, sel_glob, rpol).job_cycles
            deadline = deadline_cycles(base, retry, attempt)
            try:
                handle = rt.offload(job, dict(inst), job_args=args,
                                    clusters=self._rel_ids(sel_glob))
                data = handle.wait()
            except CompletionTimeout as exc:
                self._health.deadline_trips += 1
                self._health.virtual_cycles += deadline
                attempt += 1
                if attempt >= retry.max_attempts:
                    self._health.jobs_failed += 1
                    raise FaultError(
                        f"job {job.spec.name!r} failed after {attempt} "
                        f"attempts on clusters {tuple(sel_glob)} "
                        f"({exc.missing} arrivals missing)") from exc
                known_dead |= self._probe_dead(rt, retry, exc)
                sel_glob = self._next_selection(job, rpol, retry, sel_glob,
                                                known_dead)
                self._health.retries += 1
                continue
            # completed — race the deadline in the virtual-cycle domain: a
            # straggling primary that finishes past its deadline loses to
            # a backup launched *at* the deadline on a disjoint window
            inj = self._faults
            delay = (inj.delay_cycles(rt, handle.job_id)
                     if inj is not None else 0.0)
            finish = base + delay
            if finish > deadline and retry.backup:
                self._health.deadline_trips += 1
                avoid = set(known_dead)
                if inj is not None:
                    avoid |= set(inj.dead_clusters)
                backup_sel = self._disjoint_window(sel_glob, avoid)
                if backup_sel is not None:
                    try:
                        bh = rt.offload(job, dict(inst), job_args=args,
                                        clusters=self._rel_ids(backup_sel))
                        bdata = bh.wait()
                        bdelay = (inj.delay_cycles(rt, bh.job_id)
                                  if inj is not None else 0.0)
                        # the backup launches when the primary's deadline
                        # expires; first completion wins
                        b_finish = deadline + base + bdelay
                        self._health.backups += 1
                        if b_finish < finish:
                            data, finish = bdata, b_finish
                    except CompletionTimeout:
                        pass   # primary already has the result in hand
            self._health.virtual_cycles += finish
            self._health.jobs_ok += 1
            return data, sel_glob

    def _probe_dead(self, rt: OffloadRuntime, retry: RetryPolicy,
                    exc: CompletionTimeout) -> set:
        """Localize dead clusters after a trip.

        The completion unit already says *how many* arrivals are missing
        (``exc.missing`` — the §4.3 machinery as a failure detector);
        bisection probes with a small AXPY narrow down *which* clusters.
        A probe group whose miss count equals its size is entirely dead —
        the shortcut that makes localization O(log n) per dead cluster.
        Without an injector there is nothing to probe against: the whole
        selection is conservatively suspect."""
        inj = self._faults
        if inj is None:
            return set(exc.clusters)
        probe_job = make_axpy(PROBE_N)
        dead: set = set()
        stack: List[List[int]] = [sorted(exc.clusters)]
        while stack:
            grp = stack.pop()
            if not grp:
                continue
            self._health.probes += 1
            p_est = amodel.predict_total_v2(probe_job.spec, len(grp),
                                            self.params)
            ops, _ = probe_job.make_instance(0)
            try:
                rt.offload(probe_job, ops,
                           clusters=self._rel_ids(grp)).wait()
                self._health.virtual_cycles += p_est
            except CompletionTimeout as pe:
                # a failed probe costs its own deadline, not its estimate
                self._health.virtual_cycles += retry.deadline_factor * p_est
                if pe.missing >= len(grp) or len(grp) == 1:
                    dead.update(grp)
                else:
                    mid = len(grp) // 2
                    stack.append(grp[:mid])
                    stack.append(grp[mid:])
        return dead

    def _disjoint_window(self, sel_glob: Sequence[int],
                         avoid: set) -> Optional[List[int]]:
        """An equal-size healthy window in the lease, disjoint from the
        current selection (rung 2 of the ladder; the selection is later
        greedily covered by address-mask subcube requests)."""
        want = len(sel_glob)
        used = set(sel_glob) | set(avoid)
        pool = [c for c in self._cluster_ids if c not in used]
        return pool[:want] if len(pool) >= want else None

    def _next_selection(self, job: PaperJob, rpol: OffloadPolicy,
                        retry: RetryPolicy, sel_glob: List[int],
                        known_dead: set) -> List[int]:
        """The escalation ladder: where does the next attempt run?

        1. no dead cluster in the selection → transient fault (lost
           arrival, stall): resubmit in place;
        2. a disjoint equal-size healthy window inside the lease → the
           backup window;
        3. ``FabricScheduler.fail_clusters`` → full lease failover (the
           scheduler rebinds this session onto a healthy window, restaging
           resident operands); without a scheduler, degrade to the largest
           power-of-two healthy prefix of the window.
        """
        if not (set(sel_glob) & known_dead):
            return sel_glob                      # rung 1: resubmit in place
        if retry.backup:
            backup = self._disjoint_window(sel_glob, known_dead)
            if backup is not None:
                self._health.backups += 1        # rung 2: backup window
                return backup
        sched = self._lease.scheduler if self._lease is not None else None
        if retry.failover and sched is not None:  # rung 3: lease failover
            dead_here = sorted(known_dead & set(self._cluster_ids))
            if dead_here:
                sched.fail_clusters(dead_here)   # -> self._rebind(...)
            if self._closed or self._lease is None:
                self._health.jobs_failed += 1
                raise FaultError(
                    f"lease lost: no healthy window to fail over to "
                    f"(dead clusters {sorted(known_dead)})")
            healthy = [c for c in self._cluster_ids if c not in known_dead]
        else:
            # no scheduler (or failover disabled): degrade in the window
            healthy = [c for c in self._cluster_ids if c not in known_dead]
        n_ok = min(len(sel_glob), len(healthy))
        if n_ok == 0:
            self._health.jobs_failed += 1
            raise FaultError(
                f"no healthy clusters left in window {self._cluster_ids} "
                f"(dead: {sorted(known_dead)})")
        # power-of-two selections keep every job's shard split valid
        n_sel = 1 << (n_ok.bit_length() - 1)
        if n_sel < len(sel_glob):
            self._health.degraded += 1
        return healthy[:n_sel]

    def _snapshot_resident(self) -> List[Tuple]:
        """Host-side snapshots of every fully-resident plan — the
        failover/preemption snapshot path.  Each entry carries what a
        restage needs: the job, the host operand dict, the
        window-relative placement, the staging strategy the operands
        originally rode, and the runtime config."""
        old_ids = list(self._cluster_ids)
        snapshots = []
        for rt in self._runtimes.values():
            for plan in rt._plans.values():
                src = dict(plan._resident_src)
                if len(src) != len(plan.op_meta):
                    continue    # nothing (or only partial) residency
                rel = [old_ids.index(c) for c in plan.cluster_ids]
                snapshots.append((plan.job, src, rel, plan._staged_via,
                                  plan.fuse, plan.args_shape, rt.config))
        return snapshots

    def _drop_runtimes(self) -> None:
        self._runtimes = {}
        self._streams = {}
        self._fused_inflight = collections.deque()
        self._est_cache = {}
        self._lint_cache = {}
        # the failover window invalidates the ledger's selections
        self._staged_residency = {}

    def _restage(self, snapshots: List[Tuple]) -> int:
        """Replay resident snapshots onto the current window through the
        same staging strategy they originally rode (a tree-staged weight
        re-crosses the host link once, to the new root).  Returns the
        number of operands restaged."""
        restaged = 0
        for job, src, rel, via, fuse, args_shape, cfg in snapshots:
            if max(rel) >= len(self._cluster_ids):
                continue        # shrunken window: this placement is gone
            rt = self._runtime_from_cfg(cfg)
            plan = rt.plan(job, operands=src, clusters=rel,
                           args_shape=args_shape, fuse=fuse)
            plan.stage(src, _caller_owned=False, via=via)
            restaged += len(src)
        return restaged

    def _rebind(self, new_lease: Optional[ClusterLease]) -> int:
        """Failover callback from ``FabricScheduler.fail_clusters``: move
        this session onto ``new_lease``'s window (``None`` = no healthy
        window existed; the session closes).  Returns the number of
        operands restaged."""
        self._drain_tolerant()
        if new_lease is None:
            self._closed = True
            self._lease = None
            return 0
        snapshots = self._snapshot_resident()
        self._lease = new_lease
        self._devices = list(new_lease.devices)
        self._cluster_ids = tuple(new_lease.clusters)
        self._drop_runtimes()
        restaged = self._restage(snapshots)
        self._health.failovers += 1
        self._health.restages += restaged
        return restaged

    def _suspend(self, drain_deadline: float = 0.0) -> int:
        """Preemption callback from ``FabricScheduler.preempt``: drain
        the in-flight window (the victim's drain budget is the §6-model
        ``drain_deadline`` the scheduler computed; jobs that blow it trip
        the fault ladder's ``CompletionTimeout`` and are absorbed like
        any drain), snapshot resident state on the host, drop the
        old-window runtimes, and suspend — every submit until
        :meth:`_resume` raises.  Returns the snapshot count."""
        self._drain_deadline = float(drain_deadline)
        self._drain_tolerant()
        self._preempt_snaps = self._snapshot_resident()
        self._drop_runtimes()
        self._suspended = True
        return len(self._preempt_snaps)

    def _resume(self, new_lease: ClusterLease) -> int:
        """Re-placement callback: adopt the re-granted window, restage
        the preemption snapshots through the broadcast tree they
        originally rode, and reopen for submits.  Returns the number of
        operands restaged — results after resume are bit-identical to an
        unpreempted run (the ``preempt`` bench asserts it)."""
        self._lease = new_lease
        self._devices = list(new_lease.devices)
        self._cluster_ids = tuple(new_lease.clusters)
        self._suspended = False
        restaged = self._restage(self._preempt_snaps)
        self._preempt_snaps = []
        self._health.restages += restaged
        return restaged

    def _close_revoked(self) -> None:
        """Permanent revocation (``FabricScheduler.revoke``): the lease
        is gone and will not be re-placed."""
        self._preempt_snaps = []
        self._suspended = False
        self._closed = True
        self._lease = None

    def _inflight_launches(self) -> int:
        """Launches currently in flight across the fused deque and every
        open stream — the backlog term of the SLO backpressure model."""
        return (len(self._fused_inflight)
                + sum(len(s._inflight) for s in self._streams.values()))

    def _slo_gate(self, est: Estimate, batch: int) -> None:
        """Submit-side backpressure: when this session's lease belongs
        to a tenant with a declared SLO, predict the submit's completion
        — the in-flight backlog at the per-job pipeline period, plus the
        batch itself on top of the first-launch latency — and shed with
        a typed :class:`Overloaded` when it cannot fit, instead of
        silently deepening the pipeline."""
        lease = self._lease
        if lease is None or lease.scheduler is None:
            return
        ten = lease.scheduler.tenant(lease.tenant)
        if ten is None or ten.slo is None:
            return
        backlog = self._inflight_launches() * est.per_job_cycles
        total = (backlog + est.job_cycles
                 + est.staging_cycles.get("direct", 0.0)
                 + max(0, batch - 1) * est.per_job_cycles)
        if total > ten.slo:
            raise Overloaded(
                f"tenant {ten.name!r} slo={ten.slo:.0f} cycles < predicted "
                f"completion {total:.0f} (backlog {backlog:.0f}); submit "
                "shed — drain() and retry",
                retry_after_cycles=backlog)

    def _drain_tolerant(self) -> None:
        """Drain in-flight work, absorbing completion trips (a failover
        must not abandon the other streams' handles mid-deque)."""
        while self._fused_inflight:
            try:
                self._fused_inflight.popleft().wait()
            except CompletionTimeout:
                self._health.jobs_failed += 1
        for stream in self._streams.values():
            while stream._inflight:
                try:
                    stream._inflight.popleft().wait()
                    stream._stats["drained"] += 1
                except CompletionTimeout:
                    self._health.jobs_failed += 1
        for gh in self._graphs:
            try:
                gh._retire_all()
            except CompletionTimeout:
                self._health.jobs_failed += 1
        self._graphs.clear()

    def health(self) -> SessionHealth:
        """Fault/recovery counters of this session (a snapshot)."""
        return self._health.snapshot()

    def stage(self, job: PaperJob,
              operands: Union[Mapping[str, np.ndarray],
                              Sequence[Mapping[str, np.ndarray]]],
              *,
              policy: Optional[OffloadPolicy] = None,
              n: Optional[int] = None,
              request: Optional[mc.MulticastRequest] = None,
              clusters: Optional[Sequence[int]] = None) -> PlanDecision:
        """Phase-E stage ``operands`` as the plan's *resident* buffers.

        Primes the zero-``device_put`` warm path: subsequent
        ``submit(job, Residency.RESIDENT, ...)`` calls redispatch these
        buffers.  A sequence of B dicts stages the fused (B, ...) batch
        (for resident fused redispatch under ``policy.fuse=B``).  Staging
        strategy follows the policy/planner decision; returns it.
        """
        self._check_open("stage")
        pol = self.policy if policy is None else policy
        multi = isinstance(operands, (list, tuple))
        batch = len(operands) if multi else 1
        ids, n = self._selection_ids(pol, n, request, clusters)
        first_ops = operands[0] if multi else operands
        decision = self.planner.decide(
            job, ids, batch, pol.pinned(fuse=pol.fuse or (batch if multi
                                                          else 1)),
            self.n_units, operands=first_ops)
        rt = self._runtime_for(pol)
        stacked = stack_instances(operands) if multi else dict(operands)
        plan = rt.plan(job, operands=stacked, n=n, request=request,
                       clusters=clusters,
                       args_shape=(batch, 8) if multi else (8,),
                       fuse=batch if multi else None)
        plan.stage(stacked, _caller_owned=not multi,
                   via=decision.staging)
        # OFLP106 ledger: remember what this stage cost; resident submits
        # of the same (job, selection) bump the use counter, and
        # perflint.lint_session flags entries nothing ever redispatched
        rep = self.planner.replicated_bytes(job, first_ops) * batch
        total = sum(int(np.asarray(v).nbytes)
                    for v in first_ops.values()) * batch
        cycles = (self.planner.staging_cost(rep, ids, decision.staging)
                  if rep > 0 else 0.0)
        if total > rep:   # sharded operands ride the host link once
            cycles += (self.params.dma_setup_one
                       + (total - rep) / self.params.wide_bw_bytes_per_cycle
                       + self.params.dma_latency)
        self._staged_residency[(job.spec.name, tuple(ids))] = {
            "cycles": cycles, "uses": 0, "batch": batch,
        }
        return decision

    @staticmethod
    def _last_fused_plan(rt: OffloadRuntime, job: PaperJob, fuse: int,
                         ids: Sequence[int]):
        fused = [p for k, p in rt._plans.items()
                 if k[0] == job.spec.name and k[1] == tuple(ids)
                 and k[3] == fuse]
        return fused[-1] if fused else None

    def runtime(self, policy: Optional[OffloadPolicy] = None
                ) -> OffloadRuntime:
        """The :class:`OffloadRuntime` backing ``policy`` (the session
        default when omitted) — the escape hatch to plan/HLO
        introspection (``lowered_text``, ``plan``, per-plan stats)."""
        return self._runtime_for(self.policy if policy is None else policy)

    # -- prediction ---------------------------------------------------------

    def estimate(self, job: PaperJob, *,
                 batch: int = 1,
                 policy: Optional[OffloadPolicy] = None,
                 n: Optional[int] = None,
                 clusters: Optional[Sequence[int]] = None,
                 operands: Optional[Mapping[str, Any]] = None) -> Estimate:
        """Predict a submit without dispatching (see module
        :func:`estimate`); defaults to every device of the session.
        ``n`` beyond the session's device count is allowed — the model
        covers the full Occamy topology even when the substrate is
        smaller."""
        self._check_open("estimate")
        pol = self.policy if policy is None else policy
        if n is None and clusters is None:
            # default to the session's own fabric window, so a lease's
            # placement (quadrant structure) shapes the prediction
            clusters = list(self._cluster_ids)
        return estimate(job, n=n, clusters=clusters, batch=batch, policy=pol,
                        n_units=self.n_units, params=self.params,
                        operands=operands, planner=self.planner)

    # -- bookkeeping --------------------------------------------------------

    def drain(self) -> None:
        """Block until every in-flight submit has completed.

        Completion trips (injected faults) are absorbed into
        ``health().jobs_failed`` rather than raised: drain is cleanup,
        and a raise mid-deque would abandon the remaining handles."""
        self._drain_tolerant()

    @property
    def stats(self) -> PlanStats:
        """Aggregated dispatch counters across the session's runtimes."""
        agg = PlanStats()
        for rt in self._runtimes.values():
            agg.accumulate(rt.stats)
        return agg


def _one_args(job_args) -> Optional[np.ndarray]:
    if job_args is None:
        return None
    if isinstance(job_args, (list, tuple)):
        raise ValueError("per-job args need a list submit")
    return np.asarray(job_args)


def _args_list(job_args, batch: int) -> Optional[List[np.ndarray]]:
    if job_args is None:
        return None
    if isinstance(job_args, (list, tuple)):
        if len(job_args) != batch:
            raise ValueError(
                f"{len(job_args)} job_args for {batch} instances")
        return [np.asarray(a) for a in job_args]
    return [np.asarray(job_args)] * batch


def _stack_args(args_list: Optional[List[np.ndarray]], i: int, B: int
                ) -> Optional[np.ndarray]:
    if args_list is None:
        return None
    return np.stack(args_list[i:i + B])
