"""Hierarchical broadcast staging — lowering the multicast selection to a
data path (the last O(n) segment of the dispatch critical path).

The paper's NoC multicast (§4.2) turns O(n) point-to-point *job-information*
writes into one logical broadcast, and :mod:`repro.core.multicast` reproduces
its address-mask selection algebra.  But that algebra only ever *selected*
clusters here; replicated **operands** (phase E) still crossed the host link
once per destination — ``jax.device_put(arr, replicated_sharding)`` is n
host->device transfers in a trench coat.  Colagrande & Benini
(arXiv:2404.01908) show operand communication dominates offload overhead for
data-heavy jobs, and Zuckerman et al. (arXiv:2407.04182) argue the fan-out
topology should be *derived from the platform hierarchy* rather than
flattened.  This module does exactly that:

* :func:`build_tree` derives a **quadrant-aware fan-out tree** from a cluster
  selection: a binomial (recursive-doubling) broadcast first across the
  selected quadrants' representatives, then — all quadrants in parallel —
  across each quadrant's selected clusters.  Depth is bounded by
  ``ceil(log2 #quadrants) + ceil(log2 max clusters/quadrant)``, mirroring the
  two-level address split of fig. 5 (quadrant bits above cluster bits).
* :func:`tree_from_request` derives the tree straight from a
  :class:`~repro.core.multicast.MulticastRequest` — the (addr, mask) pair *is*
  the fan-out specification; the tree reaches exactly the clusters the
  request decodes to.
* :class:`TreeStager` executes the tree as a staging data path: the operand
  crosses the host link **once** (a single-device ``device_put`` to the tree
  root), then fans out device-to-device along the tree levels (each level one
  batched ``device_put``), and the per-device buffers are assembled into the
  replicated jax array the compiled program expects.  Host-link bytes drop
  from O(n)·size to O(1)·size; the d2d copies ride the accelerator
  interconnect instead.  A *replicated-resharding fast path*
  (``reshard=True``) hands the fan-out to the runtime in one call — upload
  to the root, then ``device_put`` the committed buffer straight to the
  replicated sharding (XLA lowers it to its own broadcast, typically an
  all-gather-style tree) — for sub-meshes where that is supported.

Byte accounting: every entry point takes an optional ``stats`` object with
``h2d_bytes`` / ``d2d_bytes`` counters (duck-typed —
:class:`repro.core.offload.PlanStats` qualifies) so the O(n) -> O(1)
host-link claim is *asserted*, not just timed.  The counters are the
**logical link bytes of the staging strategy** — what the strategy moves
over each link class — independent of substrate-level copy elision.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import multicast as mc

Edge = Tuple[int, int]          # (src cluster id, dst cluster id)

#: every replicated-placement strategy the runtime understands (the single
#: source of truth — ``repro.core.offload`` re-exports it, the serve engine
#: accepts the non-baseline subset)
STAGING_MODES = ("direct", "host_fanout", "tree", "tree_reshard")
#: the strategies that route through the fan-out tree
TREE_MODES = ("tree", "tree_reshard")
#: the two explicit data-path strategies the staging cost model covers
DATA_PATH_MODES = ("host_fanout", "tree")


@dataclasses.dataclass(frozen=True)
class BroadcastTree:
    """A levelled fan-out tree over a cluster selection.

    ``levels[k]`` holds the (src, dst) copies of step k; all edges of a
    level are independent (no node appears twice in one level, and every
    source already holds the data), so a level is one parallel round of
    transfers.  Every selected cluster is reached exactly once: the tree
    has ``len(clusters) - 1`` edges and each non-root node one parent.
    """

    clusters: Tuple[int, ...]                      # sorted selection
    root: int
    levels: Tuple[Tuple[Edge, ...], ...]

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def edges(self) -> Tuple[Edge, ...]:
        return tuple(e for level in self.levels for e in level)

    @property
    def n_edges(self) -> int:
        return len(self.clusters) - 1

    def parents(self) -> Dict[int, int]:
        """dst -> src over every edge (each dst appears exactly once)."""
        return {d: s for s, d in self.edges}

    def reached(self) -> Tuple[int, ...]:
        """Every cluster the broadcast covers (root + all edge dsts)."""
        return tuple(sorted({self.root} | {d for _, d in self.edges}))

    def cross_quadrant_edges(
        self, clusters_per_quadrant: int = mc.CLUSTERS_PER_QUADRANT
    ) -> int:
        """How many tree edges cross a quadrant boundary.

        Cross-quadrant hops pay the long narrow-network latency (§5.5 C),
        so this is the placement-sensitive part of the tree staging cost.
        The fabric scheduler's placement objective is the full
        discrete-event staging cost (``simulate_staging``, which resolves
        these edges among everything else); this count is the cheap,
        testable proxy for it — a window inside one quadrant has zero, a
        straddling window at least one — used to assert placement
        quality.
        """
        return sum(
            1 for s, d in self.edges
            if s // clusters_per_quadrant != d // clusters_per_quadrant
        )


def depth_bound(cluster_ids: Iterable[int],
                clusters_per_quadrant: int = mc.CLUSTERS_PER_QUADRANT) -> int:
    """``ceil(log2 Q) + ceil(log2 C_max)`` for a selection: the fig.-5 bound
    (Q = selected quadrants, C_max = most clusters selected in one quadrant).
    """
    by_q: Dict[int, int] = {}
    for c in set(cluster_ids):
        by_q[c // clusters_per_quadrant] = by_q.get(c // clusters_per_quadrant, 0) + 1
    if not by_q:
        return 0
    return (math.ceil(math.log2(len(by_q)))
            + math.ceil(math.log2(max(by_q.values()))))


def _binomial_rounds(have: List[int], todo: List[int]) -> List[List[Edge]]:
    """Recursive-doubling rounds: every holder forwards to one receiver."""
    rounds: List[List[Edge]] = []
    while todo:
        edges: List[Edge] = []
        for src in list(have):
            if not todo:
                break
            dst = todo.pop(0)
            edges.append((src, dst))
            have.append(dst)
        rounds.append(edges)
    return rounds


def build_tree(cluster_ids: Iterable[int],
               clusters_per_quadrant: int = mc.CLUSTERS_PER_QUADRANT
               ) -> BroadcastTree:
    """Derive the quadrant-aware fan-out tree for a cluster selection.

    Phase 1 broadcasts across quadrant representatives (the lowest selected
    cluster of each quadrant), phase 2 broadcasts within every quadrant in
    parallel.  Works for any non-empty selection — degenerate n=1 (no
    edges) and non-power-of-two selections included.
    """
    ids = sorted(set(int(c) for c in cluster_ids))
    if not ids:
        raise ValueError("empty cluster selection")
    if ids[0] < 0:
        raise ValueError(f"negative cluster id {ids[0]}")
    by_q: Dict[int, List[int]] = {}
    for c in ids:
        by_q.setdefault(c // clusters_per_quadrant, []).append(c)
    reps = [members[0] for _, members in sorted(by_q.items())]
    root = ids[0]                     # lowest id == its quadrant's rep
    assert root in reps
    inter = _binomial_rounds([root], [r for r in reps if r != root])
    # Phase 2: all quadrants fan out in parallel — one binomial broadcast
    # per quadrant, merged round-wise into shared levels.
    per_q = [_binomial_rounds([members[0]], members[1:])
             for _, members in sorted(by_q.items())]
    intra = [sum(rounds, []) for rounds in
             itertools.zip_longest(*per_q, fillvalue=[])]
    levels = tuple(tuple(lv) for lv in inter + intra if lv)
    return BroadcastTree(tuple(ids), root, levels)


def tree_from_request(req: mc.MulticastRequest,
                      num_clusters: int = mc.NUM_CLUSTERS,
                      clusters_per_quadrant: int = mc.CLUSTERS_PER_QUADRANT
                      ) -> BroadcastTree:
    """The fan-out tree of an address-mask multicast request (fig. 5)."""
    ids = mc.decode_cluster_selection(req, num_clusters)
    if not ids:
        raise ValueError(f"request {req} selects no clusters")
    return build_tree(ids, clusters_per_quadrant)


# ---------------------------------------------------------------------------
# The staging data path.
# ---------------------------------------------------------------------------


class TreeStager:
    """Executes a :class:`BroadcastTree` as a replicated-operand data path.

    ``devices[i]`` realizes ``cluster_ids[i]``; the stager uploads once to
    the root's device and fans out level by level.  One stager per
    (selection, device set) — plans and engines cache it.
    """

    def __init__(self, devices: Sequence[jax.Device],
                 cluster_ids: Optional[Sequence[int]] = None,
                 clusters_per_quadrant: int = mc.CLUSTERS_PER_QUADRANT):
        ids = (list(range(len(devices))) if cluster_ids is None
               else [int(c) for c in cluster_ids])
        if len(ids) != len(devices):
            raise ValueError(
                f"{len(ids)} cluster ids for {len(devices)} devices")
        self.tree = build_tree(ids, clusters_per_quadrant)
        self._dev: Dict[int, jax.Device] = dict(zip(ids, devices))
        self._order = list(ids)       # device order of the sub-mesh

    def put_replicated(self, arr: np.ndarray, sharding,
                       *, reshard: bool = False,
                       stats: Optional[Any] = None):
        """Stage ``arr`` replicated onto the sub-mesh with ONE host upload.

        ``sharding`` must be a fully-replicated sharding over exactly the
        stager's devices.  ``reshard=True`` takes the replicated-resharding
        fast path (root upload + one resharding ``device_put``); the
        default walks the explicit tree.  ``stats.h2d_bytes`` grows by
        ``arr.nbytes`` and ``stats.d2d_bytes`` by ``(n-1) * arr.nbytes``
        either way — the logical link bytes of the strategy.
        """
        arr = np.asarray(arr)
        n = len(self._order)
        root_dev = self._dev[self.tree.root]
        buf = jax.device_put(arr, root_dev)
        if stats is not None:
            stats.h2d_bytes += arr.nbytes
            stats.d2d_bytes += arr.nbytes * (n - 1)
        if n == 1:
            return jax.make_array_from_single_device_arrays(
                arr.shape, sharding, [buf])
        if reshard:
            return jax.device_put(buf, sharding)
        bufs = {self.tree.root: buf}
        for level in self.tree.levels:
            srcs = [bufs[s] for s, _ in level]
            dsts = [self._dev[d] for _, d in level]
            out = jax.device_put(srcs, dsts)     # one parallel round
            for (_, d), b in zip(level, out):
                bufs[d] = b
        return jax.make_array_from_single_device_arrays(
            arr.shape, sharding, [bufs[c] for c in self._order])


    def forward_replicated(self, value, sharding,
                           *, stats: Optional[Any] = None):
        """Fan a *device-resident* producer result out replicated — the
        forwarding counterpart of :meth:`put_replicated`.

        ``value`` is a jax array living on the fabric (a dependent job's
        producer output, possibly still in flight — async dispatch chains
        the copies behind it).  It hops device-to-device to the tree root
        and then rides the same levelled fan-out; the host link is never
        touched, so ``stats.h2d_bytes`` stays put and the whole
        ``n * nbytes`` logical movement lands in ``stats.forward_bytes``
        (and ``d2d_bytes`` — forwarding is fan-out traffic too).
        """
        n = len(self._order)
        nbytes = int(value.nbytes)
        root_dev = self._dev[self.tree.root]
        buf = jax.device_put(value, root_dev)
        if stats is not None:
            stats.forward_bytes += nbytes * n
            stats.d2d_bytes += nbytes * n
        if n == 1:
            return jax.make_array_from_single_device_arrays(
                tuple(value.shape), sharding, [buf])
        bufs = {self.tree.root: buf}
        for level in self.tree.levels:
            srcs = [bufs[s] for s, _ in level]
            dsts = [self._dev[d] for _, d in level]
            out = jax.device_put(srcs, dsts)
            for (_, d), b in zip(level, out):
                bufs[d] = b
        return jax.make_array_from_single_device_arrays(
            tuple(value.shape), sharding, [bufs[c] for c in self._order])


def is_replicated(sharding) -> bool:
    """True iff ``sharding`` places the full array on every device."""
    spec = getattr(sharding, "spec", None)
    if spec is None:
        return False
    return all(p is None for p in spec)


def placement_bytes(arr: np.ndarray, sharding) -> int:
    """Logical host-link bytes of a *direct* ``device_put``: per-device
    shard bytes × device count.  A fully replicated array costs n·size; a
    model-sharded-but-data-replicated parameter costs size × (data
    replicas); a fully sharded operand costs exactly size."""
    arr = np.asarray(arr)
    shard = sharding.shard_shape(tuple(arr.shape))
    per = int(np.prod(shard, dtype=np.int64)) * arr.dtype.itemsize
    return per * len(sharding.device_set)


def place_pytree(tree: Any, shardings: Any, stager: TreeStager,
                 *, reshard: bool = False, stats: Optional[Any] = None) -> Any:
    """``device_put`` a pytree, routing replicated leaves through the tree.

    Sharded leaves cross the host link once regardless of n (each device
    receives only its shard), so they take the direct path; replicated
    leaves — the O(n) host-link offenders — go through
    :meth:`TreeStager.put_replicated`.  ``stats`` counts both classes.
    """
    def place(leaf, sharding):
        arr = np.asarray(leaf)
        if is_replicated(sharding):
            return stager.put_replicated(arr, sharding, reshard=reshard,
                                         stats=stats)
        # partially-replicated leaves (e.g. model-sharded, data-replicated
        # parameters) still take the direct path; only the fully replicated
        # class is tree-staged today
        if stats is not None:
            stats.h2d_bytes += placement_bytes(arr, sharding)
        return jax.device_put(leaf, sharding)

    return jax.tree_util.tree_map(place, tree, shardings)
