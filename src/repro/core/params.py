"""Occamy machine parameters for the offload phase simulator.

Every constant is either stated verbatim in the paper or reconstructed so
that the paper's published aggregates emerge mechanistically from the
simulation.  The paper's anchors (1 GHz ⇒ cycles == ns):

  §5.5 B:  multicast wakeup costs 47 cycles: one host store (8) + 39 cycles
           of network propagation ("39 arise in the hardware as the write
           request exits CVA6's memory subsystem...").
  §5.5 E:  t_setup = 53 cycles (programming the x and y transfers),
           t_latency = 55 cycles round trip, bw = 64 B/cycle (512-bit NoC).
  §5.5 F:  t_init = 55 cycles; AXPY computes at 1.47 cycles/element over the
           8 compute cores of each cluster.
  §5.5 G:  t_setup = 21 cycles (single transfer), t_latency = 55 cycles.
  eq. 5:   t̂_axpy(n) = 400 + N/4 + 2.47·N/(8n).  Decomposition used here:
             [E+F+G constants] = (53+55) + 55 + (21+55) = 239
             [A+B+C+D+H+I]_mc = 24 + 47 + 10 + 0 + 60 + 20 = 161
             sum = 400  ✓ (verified in tests/test_model.py)
           A = host_info_base(12) + 2·(1 ptr + 5 AXPY arg words) = 24.
           H_unit = phase_sync(4) + arrival code(2) + CLINT travel(13) +
                    fire(2) + IPI propagation(39) = 60.
  §5.2:    average baseline offload overhead at 1 cluster ≈ 242 cycles:
             A(24) + B(47) + C(10) + D(0) + H_sw(141) + I(20) = 242  ✓
           H_sw(1) = phase_sync(4) + barrier code(73) + local travel(10) +
                     AMO(7) + IPI store(8) + propagation(39) = 141.
  fig. 7:  overhead grows with n, ≈1146 cycles max on a 32-cluster Matmul:
           CVA6's limited outstanding-write budget serializes baseline IPIs
           at host_store_next = 25 cycles apiece (§4.2: "CVA6's memory
           subsystem supports only a low number of outstanding write
           transactions"), giving B(32) = 8 + 31·25 + 39 = 822 and a total
           offload overhead within a few % of the paper's 1146
           (benchmarks/fig07_overhead.py).
  §5.4:    extension runtimes track ideal offset by ~185 cycles with σ=18;
           our reconstruction yields the model-consistent 161 (the paper's
           own closed-form constant also decomposes to 161 = 400 - 239; the
           24-cycle gap between their model and their measurement is within
           the <15 % error band they report, and we document the same gap).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class OccamyParams:
    # --- topology -------------------------------------------------------------
    clusters_per_quadrant: int = 4
    num_quadrants: int = 8
    cores_per_cluster: int = 8          # compute cores (the DMA core is extra)

    # --- interconnect ---------------------------------------------------------
    wide_bw_bytes_per_cycle: int = 64   # 512-bit wide NoC / SPM port
    narrow_local: float = 10.0          # load from own-cluster TCDM
    narrow_same_quadrant: float = 25.0  # load from a cluster in same quadrant
    narrow_cross_quadrant: float = 40.0 # load from a cluster in another quadrant
    noc_propagation: float = 39.0       # CVA6 store -> core wakeup propagation

    # --- host (CVA6) ----------------------------------------------------------
    host_store_first: float = 8.0       # first posted write issues immediately
    host_store_next: float = 25.0       # subsequent writes: outstanding-txn limit
    host_info_base: float = 12.0        # phase A: prologue of the offload call
    host_info_per_word: float = 2.0     # phase A: per job-information word
    host_resume: float = 20.0           # phase I: take interrupt, clear, return

    # --- DMA ------------------------------------------------------------------
    dma_latency: float = 55.0           # AR->R->AW/W->B round trip (§5.5 E)
    dma_setup_two: float = 53.0         # programming two transfers (§5.5 E)
    dma_setup_one: float = 21.0         # programming one transfer (§5.5 G)
    dma_args_setup: float = 20.0        # phase D argument-transfer setup
    cluster0_port_occupancy: float = 30.0  # phase D serialization at cluster 0

    # --- synchronization ------------------------------------------------------
    phase_sync: float = 4.0             # DMA-core <-> compute-core handshake
    amo_service: float = 7.0            # one AMO increment at the TCDM counter
    sw_barrier_code: float = 73.0       # central-counter arrival routine (SW)
    unit_arrival_code: float = 2.0      # completion-unit arrival (posted store)
    unit_fire: float = 2.0              # completion unit compare + IPI fire
    clint_travel: float = 13.0          # cluster -> CLINT peripheral write

    # --- job execution --------------------------------------------------------
    f_init: float = 55.0                # phase F per-job init (§5.5 F)

    @property
    def num_clusters(self) -> int:
        return self.clusters_per_quadrant * self.num_quadrants

    @property
    def num_cores(self) -> int:
        # 8 compute + 1 DMA core per cluster, plus the CVA6 host.
        return self.num_clusters * (self.cores_per_cluster + 1) + 1

    def narrow_latency(self, src_cluster: int, dst_cluster: int) -> float:
        """Narrow-network access latency between two clusters (§5.5 C)."""
        if src_cluster == dst_cluster:
            return self.narrow_local
        if src_cluster // self.clusters_per_quadrant == dst_cluster // self.clusters_per_quadrant:
            return self.narrow_same_quadrant
        return self.narrow_cross_quadrant

    def dma_setup(self, num_transfers: int) -> float:
        """Cycles to program ``num_transfers`` DMA descriptors back-to-back.

        Anchored at the paper's two measured points: 53 cycles for two
        transfers (phase E of AXPY) and 21 for one (phase G); extrapolated
        linearly with the measured increment (53 - 21 = 32) beyond two.
        """
        if num_transfers <= 0:
            return 0.0
        if num_transfers == 1:
            return self.dma_setup_one
        return self.dma_setup_two + (num_transfers - 2) * (
            self.dma_setup_two - self.dma_setup_one
        )


DEFAULT_PARAMS = OccamyParams()
