"""Cycle-accurate discrete-event simulator of the Occamy offload process.

This is the reproduction's stand-in for the paper's QuestaSim RTL measurements
(§5.1): a discrete-event model of the nine offload phases (fig. 3) over the
Occamy topology, parameterized by the paper's measured constants
(:mod:`repro.core.params`).  It reproduces, mechanistically rather than by
curve-fitting:

* the O(n) baseline wakeup (sequential IPIs limited by CVA6's outstanding
  write budget) vs O(1) multicast wakeup (§5.5 B);
* the quadrant-step behaviour of job-pointer retrieval (§5.5 C);
* the single-read-port wide-SPM contention: DMA transfers are granted
  sequentially in arrival order and perfectly interleave, so the port is
  work-conserving (§5.5 E) — implemented as a FIFO server at 64 B/cycle;
* the second-order effect of dispatch skew: offload phases offset the
  clusters' phase-E start times, which *hides* SPM contention, so part of the
  offload overhead is recovered (§5.2) — this falls out of the FIFO model;
* phase E/G coupling: a cluster's writeback can stall behind another
  cluster's operand fetch (§5.5 G) — both phases share the wide port;
* the software central-counter barrier vs the job completion unit (§4.3).

Three execution modes:

* ``baseline``  — the unmodified system (sequential IPIs, phases C/D, software
  central-counter barrier);
* ``multicast`` — the paper's extensions (multicast job-info distribution and
  wakeup, phases C/D collapsed, job completion unit);
* ``ideal``     — the job as if it materialized on the accelerator at t=0 with
  no offload phases (the paper's "executed directly on the device"); used to
  compute the offload overhead t_base - t_ideal (§5.2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Union

from repro.core import broadcast as bcast
from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.phases import Phase, PhaseSpan, PhaseStats

Mode = str
MODES = ("baseline", "multicast", "ideal")


@dataclasses.dataclass
class JobSpec:
    """Phase-level description of an offloadable job (simulator view).

    ``operand_transfers(n, i)`` / ``writeback_transfers(n, i)`` return the DMA
    transfer sizes in bytes issued by cluster ``i`` when the job runs on ``n``
    clusters.  ``compute_cycles(n, i)`` is phase-F work excluding the
    ``f_init`` constant.  ``levels`` > 1 inserts software global barriers
    inside phase F (BFS's level-synchronous traversal).
    """

    name: str
    arg_words: int
    operand_transfers: "callable"
    compute_cycles: "callable"
    writeback_transfers: "callable"
    levels: int = 1


@dataclasses.dataclass
class SimResult:
    job: str
    mode: Mode
    n: int
    total: float                      # host-to-host cycles (device-only for ideal)
    spans: List[PhaseSpan]
    cluster_done: List[float]         # per-cluster end of phase G

    def phase_stats(self) -> Dict[Phase, PhaseStats]:
        per_phase: Dict[Phase, List[float]] = {}
        for s in self.spans:
            per_phase.setdefault(s.phase, []).append(s.duration)
        return {p: PhaseStats.of(p, d) for p, d in per_phase.items()}


# ---------------------------------------------------------------------------
# The wide interconnect / SPM port: a single work-conserving FIFO server.
# ---------------------------------------------------------------------------


class WidePort:
    """Single-ported wide SPM interface, 64 B/cycle, grant in arrival order.

    The paper (§5.5 E): "the wide SPM has a single read port, all clusters
    have to contend access to this resource, so the DMA transfers from every
    cluster will be granted sequentially [...] multiple short DMA transfers
    perfectly interleave, thus taking the same amount of time as a single DMA
    transfer of combined length at the SPM interface".
    """

    def __init__(self, bw: float):
        self.bw = bw
        self.free_at = 0.0

    def serve(self, eligible: float, nbytes: float) -> float:
        start = max(self.free_at, eligible)
        end = start + max(1.0, nbytes / self.bw)
        self.free_at = end
        return end


@dataclasses.dataclass
class _Chain:
    """A cluster's pending port requests: E transfers then G transfers."""

    cluster: int
    e_sizes: List[float]
    g_sizes: List[float]
    next_idx: int = 0
    stage: int = 0                    # 0 = E, 1 = G, 2 = done
    eligible: float = 0.0
    e_end: float = 0.0
    g_end: float = 0.0
    g_gap: Optional["callable"] = None  # e_end -> eligibility of first G transfer

    def done(self) -> bool:
        return self.stage == 2


def _run_port(port: WidePort, chains: List[_Chain], latency: float) -> None:
    """Serve every chain to completion in FIFO (arrival-order) fashion."""
    # Clusters with no E transfers resolve their stage boundary immediately.
    for c in chains:
        _advance_empty_stages(c, latency)
    while True:
        live = [c for c in chains if not c.done()]
        if not live:
            return
        # FIFO: earliest-eligible request first; ties broken by cluster index
        # (round-robin-ish fairness, deterministic).
        c = min(live, key=lambda ch: (ch.eligible, ch.cluster))
        sizes = c.e_sizes if c.stage == 0 else c.g_sizes
        end = port.serve(c.eligible, sizes[c.next_idx])
        c.next_idx += 1
        if c.next_idx < len(sizes):
            c.eligible = end          # descriptors are pre-programmed
            continue
        # Stage complete: the cluster observes completion after the round trip.
        if c.stage == 0:
            c.e_end = end + latency
            c.stage, c.next_idx = 1, 0
            c.eligible = c.g_gap(c.e_end) if c.g_gap else c.e_end
            _advance_empty_stages(c, latency)
        else:
            c.g_end = end + latency
            c.stage = 2


def _advance_empty_stages(c: _Chain, latency: float) -> None:
    if c.stage == 0 and not c.e_sizes:
        c.e_end = c.eligible
        c.stage = 1
        c.eligible = c.g_gap(c.e_end) if c.g_gap else c.e_end
    if c.stage == 1 and not c.g_sizes:
        c.g_end = c.eligible
        c.stage = 2


# ---------------------------------------------------------------------------
# The simulator proper.
# ---------------------------------------------------------------------------


def simulate(
    job: JobSpec,
    n: int,
    mode: Mode,
    params: OccamyParams = DEFAULT_PARAMS,
) -> SimResult:
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}")
    if not (1 <= n <= params.num_clusters):
        raise ValueError(f"n={n} outside [1, {params.num_clusters}]")
    p = params
    spans: List[PhaseSpan] = []

    # ----- Phase A: send job information (host) ------------------------------
    if mode == "ideal":
        a_end = 0.0
    else:
        a_dur = p.host_info_base + p.host_info_per_word * (1 + job.arg_words)
        spans.append(PhaseSpan(Phase.A, -1, 0.0, a_dur))
        a_end = a_dur

    # ----- Phase B: wakeup ----------------------------------------------------
    wake = [0.0] * n
    if mode == "baseline":
        # Sequential IPIs, descending cluster index so that cluster 0 (which
        # hosts the barrier counter) is woken last (§5.5 H).
        for k in range(n):
            i = n - 1 - k
            issue = a_end + p.host_store_first + k * p.host_store_next
            wake[i] = issue + p.noc_propagation
    elif mode == "multicast":
        w = a_end + p.host_store_first + p.noc_propagation
        wake = [w] * n
    for i in range(n):
        if mode != "ideal":
            spans.append(PhaseSpan(Phase.B, i, a_end, wake[i]))

    # ----- Phase C: retrieve job pointer ---------------------------------------
    c_end = list(wake)
    if mode == "baseline":
        for i in range(n):
            c_end[i] = wake[i] + p.narrow_latency(i, 0)
    elif mode == "multicast":
        # Job info already multicast into every TCDM: local load only.
        for i in range(n):
            c_end[i] = wake[i] + p.narrow_local
    for i in range(n):
        if mode != "ideal":
            spans.append(PhaseSpan(Phase.C, i, wake[i], c_end[i]))

    # ----- Phase D: retrieve job arguments -------------------------------------
    d_end = list(c_end)
    if mode == "baseline":
        # Remote clusters DMA the argument block out of cluster 0's TCDM.
        # Serialized at cluster 0's port (FIFO in arrival order).
        order = sorted(range(1, n), key=lambda i: c_end[i] + p.dma_args_setup)
        port_free = 0.0
        for i in order:
            eligible = c_end[i] + p.dma_args_setup
            start = max(port_free, eligible)
            serve_end = start + p.cluster0_port_occupancy
            port_free = serve_end
            d_end[i] = serve_end + p.dma_latency
        d_end[0] = c_end[0]
    for i in range(n):
        if mode != "ideal":
            spans.append(PhaseSpan(Phase.D, i, c_end[i], d_end[i]))

    # ----- Phases E, F, G: operands, compute, writeback -------------------------
    port = WidePort(p.wide_bw_bytes_per_cycle)
    e_starts = [0.0] * n if mode == "ideal" else d_end
    ops = [list(job.operand_transfers(n, i)) for i in range(n)]
    wbs = [list(job.writeback_transfers(n, i)) for i in range(n)]
    f_dur = [
        p.phase_sync + p.f_init + job.compute_cycles(n, i) + p.phase_sync
        for i in range(n)
    ]

    if job.levels <= 1:
        chains = []
        for i in range(n):
            gap = (lambda fd, k: (lambda e_end: e_end + fd + p.dma_setup(k)))(
                f_dur[i], len(wbs[i])
            )
            chains.append(
                _Chain(
                    cluster=i,
                    e_sizes=ops[i],
                    g_sizes=wbs[i],
                    eligible=e_starts[i] + p.dma_setup(len(ops[i])),
                    g_gap=gap,
                )
            )
        _run_port(port, chains, p.dma_latency)
        e_end = [c.e_end for c in chains]
        f_end = [e_end[i] + f_dur[i] for i in range(n)]
        g_end = [c.g_end for c in chains]
    else:
        # Level-synchronous jobs (BFS): complete phase E for all clusters,
        # run `levels` compute segments separated by software global barriers,
        # then write back.  The barriers serialize everything, so the E/G
        # overlap the single-level path models cannot occur.
        chains = [
            _Chain(
                cluster=i,
                e_sizes=ops[i],
                g_sizes=[],
                eligible=e_starts[i] + p.dma_setup(len(ops[i])),
            )
            for i in range(n)
        ]
        _run_port(port, chains, p.dma_latency)
        e_end = [c.e_end for c in chains]
        t = [e + p.phase_sync + p.f_init for e, _ in zip(e_end, range(n))]
        per_level = [job.compute_cycles(n, i) / job.levels for i in range(n)]
        for lvl in range(job.levels):
            t = [t[i] + per_level[i] for i in range(n)]
            if lvl < job.levels - 1:
                joined = max(t) + intra_barrier(n, p)
                t = [joined] * n
        f_end = [t[i] + p.phase_sync for i in range(n)]
        gchains = [
            _Chain(
                cluster=i,
                e_sizes=[],
                g_sizes=wbs[i],
                eligible=f_end[i] + p.dma_setup(len(wbs[i])),
            )
            for i in range(n)
        ]
        _run_port(port, gchains, p.dma_latency)
        g_end = [c.g_end for c in gchains]

    for i in range(n):
        spans.append(PhaseSpan(Phase.E, i, e_starts[i], e_end[i]))
        spans.append(PhaseSpan(Phase.F, i, e_end[i], f_end[i]))
        spans.append(PhaseSpan(Phase.G, i, f_end[i], g_end[i]))

    # ----- Phase H: notify job completion ---------------------------------------
    if mode == "ideal":
        total = max(g_end)
        return SimResult(job.name, mode, n, total, spans, g_end)

    h_start = max(g_end)
    if mode == "baseline":
        # Software central-counter barrier in cluster 0's TCDM: each DMA core
        # runs the arrival routine, AMO-increments the counter (serialized),
        # and the last arriver IPIs the host.
        arrivals = sorted(
            (g_end[i] + p.phase_sync + p.sw_barrier_code + p.narrow_latency(i, 0), i)
            for i in range(n)
        )
        counter_free = 0.0
        for t_arr, _ in arrivals:
            counter_free = max(counter_free, t_arr) + p.amo_service
        host_irq = counter_free + p.host_store_first + p.noc_propagation
    else:
        # Job completion unit (§4.3): posted writes to the CLINT arrivals
        # register; the unit fires the host IPI when arrivals == offload.
        arrivals = [
            g_end[i] + p.phase_sync + p.unit_arrival_code + p.clint_travel
            for i in range(n)
        ]
        host_irq = max(arrivals) + p.unit_fire + p.noc_propagation
    spans.append(PhaseSpan(Phase.H, -1, h_start, host_irq))

    # ----- Phase I: resume operation on host -------------------------------------
    total = host_irq + p.host_resume
    spans.append(PhaseSpan(Phase.I, -1, host_irq, total))
    return SimResult(job.name, mode, n, total, spans, g_end)


def intra_barrier(n: int, p: OccamyParams = DEFAULT_PARAMS) -> float:
    """In-job software global barrier (BFS level sync): central counter."""
    return p.narrow_cross_quadrant + p.amo_service * n


def offload_overhead(job: JobSpec, n: int, mode: Mode = "baseline",
                     params: OccamyParams = DEFAULT_PARAMS) -> float:
    """The paper's §5.2 metric: t_mode - t_ideal."""
    t = simulate(job, n, mode, params).total
    t_ideal = simulate(job, n, "ideal", params).total
    return t - t_ideal


def speedups(job: JobSpec, n: int, params: OccamyParams = DEFAULT_PARAMS):
    """(ideal speedup, achieved speedup, restoration) — fig. 8 metrics."""
    base = simulate(job, n, "baseline", params).total
    ideal = simulate(job, n, "ideal", params).total
    ext = simulate(job, n, "multicast", params).total
    s_ideal = base / ideal
    s_ext = base / ext
    return s_ideal, s_ext, s_ext / s_ideal


# ---------------------------------------------------------------------------
# Hierarchical staging cost model (the §6 treatment, extended to the
# replicated-operand host-link staging of phases E and G).
# ---------------------------------------------------------------------------

#: staging strategies the cost model distinguishes — "host_fanout" is the
#: O(n) serialized host-link baseline, "tree" the O(1) hierarchical
#: broadcast staging over the derived fan-out tree ("direct" and
#: "tree_reshard" delegate their data path to the substrate, so the model
#: has nothing mechanistic to say about them)
STAGING_MODES = bcast.DATA_PATH_MODES


def _resolve_selection(cluster_ids: Union[int, Iterable[int]]) -> List[int]:
    if isinstance(cluster_ids, int):
        return list(range(cluster_ids))
    return sorted(set(int(c) for c in cluster_ids))


def simulate_staging(nbytes: float, cluster_ids: Union[int, Iterable[int]],
                     mode: str, params: OccamyParams = DEFAULT_PARAMS
                     ) -> float:
    """Discrete-event staging time (cycles) of one replicated operand.

    The phase-E/phase-G counterpart of :func:`simulate` for the host-link
    leg: how long until every selected cluster holds the ``nbytes`` operand.

    * ``host_fanout`` — one host-link transfer per cluster, issued
      sequentially (descriptor programming pipelines behind the busy link,
      but issue is still bounded by the host's outstanding-write budget,
      ``host_store_next``) and served FIFO by the wide port.
    * ``tree`` — one host-link transfer to the fan-out tree root, then the
      tree levels of :func:`repro.core.broadcast.build_tree` in sequence;
      edges within a level ride disjoint links in parallel, each paying the
      per-hop descriptor setup, the link occupancy, the DMA round trip, and
      the *quadrant-dependent* wire latency (the second-order effect the
      closed form ignores).

    Phase G (writeback gather) is the mirror image — same tree, reversed
    edges — so the model doubles as its cost term.
    """
    p = params
    ids = _resolve_selection(cluster_ids)
    n = len(ids)
    if n < 1:
        raise ValueError("empty cluster selection")
    xfer = max(1.0, nbytes / p.wide_bw_bytes_per_cycle)
    if mode == "host_fanout":
        link_free = 0.0
        for i in range(n):
            issue = p.dma_setup_one + i * p.host_store_next
            link_free = max(link_free, issue) + xfer
        return link_free + p.dma_latency
    if mode == "tree":
        tree = bcast.build_tree(ids, p.clusters_per_quadrant)
        t = p.dma_setup_one + xfer + p.dma_latency      # root upload
        for level in tree.levels:
            # per-edge wire latency is the quadrant-aware narrow-network
            # cost of §5.5 C (tree edges never have src == dst)
            t += max(p.dma_setup_one + xfer + p.dma_latency
                     + p.narrow_latency(s, d) for s, d in level)
        return t
    raise ValueError(f"mode must be one of {STAGING_MODES}")


def staging_model(nbytes: float, cluster_ids: Union[int, Iterable[int]],
                  mode: str, params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Closed-form staging time (cycles) — the eq.-5-style prediction.

    ``t_hf ≈ t_setup + n·size/BW + t_lat`` (the O(n) host link) vs
    ``t_tree ≈ (t_setup + size/BW + t_lat) · (1 + depth) + depth·t_wire``
    with a single worst-case cross-quadrant ``t_wire`` constant — the
    per-edge heterogeneity and issue serialization the discrete-event
    model resolves are deliberately dropped, exactly as the paper's
    analytical model drops its second-order effects (§6, <15% error).
    """
    p = params
    ids = _resolve_selection(cluster_ids)
    n = len(ids)
    xfer = max(1.0, nbytes / p.wide_bw_bytes_per_cycle)
    if mode == "host_fanout":
        return p.dma_setup_one + n * xfer + p.dma_latency
    if mode == "tree":
        depth = bcast.depth_bound(ids, p.clusters_per_quadrant)
        hop = p.dma_setup_one + xfer + p.dma_latency + p.narrow_cross_quadrant
        return (p.dma_setup_one + xfer + p.dma_latency) + depth * hop
    raise ValueError(f"mode must be one of {STAGING_MODES}")


def simulate_forward(nbytes: float, src_ids: Union[int, Iterable[int]],
                     dst_ids: Union[int, Iterable[int]], *,
                     replicate: bool = False,
                     params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Discrete-event cost (cycles) of one d2d result-forwarding edge.

    A producer's ``nbytes`` result lives on the ``src_ids`` selection; a
    dependent consumer needs it on ``dst_ids``.  Same selection — the
    aliasing fast path of ``DispatchPlan.forward`` — costs nothing: the
    consumer's program reads the producer's output shards in place.
    Otherwise the result hops device-to-device from the producer's root
    to the consumer's root (paying the quadrant-aware narrow-network
    latency of §5.5 C), and ``replicate=True`` additionally fans it out
    along the consumer selection's broadcast-tree levels — forwarding
    rides the same PR-3 tree as staging, just without the host upload.
    """
    p = params
    src = _resolve_selection(src_ids)
    dst = _resolve_selection(dst_ids)
    if not src or not dst:
        raise ValueError("empty cluster selection")
    if src == dst and not replicate:
        return 0.0
    xfer = max(1.0, nbytes / p.wide_bw_bytes_per_cycle)
    t = 0.0
    if src != dst:
        t += (p.dma_setup_one + xfer + p.dma_latency
              + p.narrow_latency(src[0], dst[0]))
    if replicate and len(dst) > 1:
        tree = bcast.build_tree(dst, p.clusters_per_quadrant)
        for level in tree.levels:
            t += max(p.dma_setup_one + xfer + p.dma_latency
                     + p.narrow_latency(s, d) for s, d in level)
    return t


def forward_model(nbytes: float, src_ids: Union[int, Iterable[int]],
                  dst_ids: Union[int, Iterable[int]], *,
                  replicate: bool = False,
                  params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Closed-form per-hop forward cost — the eq.-5-style prediction.

    ``t_fwd ≈ hop + depth(dst) · hop`` with ``hop = t_setup + size/BW +
    t_lat + t_wire`` and a single worst-case cross-quadrant ``t_wire``,
    dropping the per-edge latency heterogeneity the discrete-event model
    resolves (§6 abstraction level).  Zero for the aliasing fast path.
    """
    p = params
    src = _resolve_selection(src_ids)
    dst = _resolve_selection(dst_ids)
    if src == dst and not replicate:
        return 0.0
    xfer = max(1.0, nbytes / p.wide_bw_bytes_per_cycle)
    hop = p.dma_setup_one + xfer + p.dma_latency + p.narrow_cross_quadrant
    t = hop if src != dst else 0.0
    if replicate and len(dst) > 1:
        t += bcast.depth_bound(dst, p.clusters_per_quadrant) * hop
    return t


def selection_requests(cluster_ids: Union[int, Iterable[int]],
                       num_clusters: Optional[int] = None) -> int:
    """Multicast requests the one-write wakeup needs for a selection.

    The paper's single-request dispatch (§5) holds only when the cluster
    selection is one aligned power-of-two subcube of the mesh; any other
    selection greedily decomposes into several subcube requests
    (:func:`repro.core.multicast.encode_cluster_selection_multi`), each
    replaying the dispatch-constant phases.  The perf linter's OFLP105
    pass and the ``perflint`` bench both key off this count, so it lives
    here in the measurement domain.
    """
    from repro.core import multicast as mc
    ids = _resolve_selection(cluster_ids)
    if not ids:
        raise ValueError("empty cluster selection")
    return len(mc.encode_cluster_selection_multi(
        ids, num_clusters if num_clusters is not None else mc.NUM_CLUSTERS))


def model_error(predicted: float, measured: float) -> float:
    """Relative model error |predicted - measured| / measured (fig.-12
    metric; the paper's bar is < 0.15 everywhere)."""
    if measured == 0:
        raise ValueError("measured time must be non-zero")
    return abs(predicted - measured) / abs(measured)


def staging_model_error(nbytes: float,
                        cluster_ids: Union[int, Iterable[int]], mode: str,
                        params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Closed form vs discrete event for one staging point."""
    return model_error(staging_model(nbytes, cluster_ids, mode, params),
                       simulate_staging(nbytes, cluster_ids, mode, params))


# ---------------------------------------------------------------------------
# Multi-tenant fabric contention (the PR-5 scheduler's measurement domain).
#
# The paper measures ONE host job owning the whole fabric; spatially
# partitioning the mesh between tenants (disjoint cluster leases) leaves
# exactly one shared serial resource: the host core and its link, which
# issues every tenant's phase-A job information, doorbell store, and
# phase-I resume.  This model composes the single-job simulator with that
# shared-host FIFO: each tenant pipelines jobs on its own lease (device
# phases of different leases run concurrently), while all host-side work
# serializes in eligibility order — the contention the FabricScheduler's
# admission model has to predict.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantWorkload:
    """One tenant's job stream on one cluster lease.

    ``clusters`` is the lease's (global) cluster-id selection; workloads
    sharing an *identical* selection share the device resource (how the
    serialized whole-mesh baseline is expressed), disjoint selections run
    concurrently.  ``window`` bounds the tenant's in-flight jobs (the
    completion-unit copies); ``arrival`` is the cycle its first dispatch
    becomes eligible.
    """

    tenant: str
    spec: JobSpec
    clusters: tuple
    jobs: int = 1
    arrival: float = 0.0
    window: int = 4

    def __post_init__(self) -> None:
        if not self.clusters:
            raise ValueError("a workload needs at least one cluster")
        if self.jobs < 1:
            raise ValueError(f"jobs must be >= 1, got {self.jobs}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.arrival < 0:
            raise ValueError(f"arrival must be >= 0, got {self.arrival}")


@dataclasses.dataclass(frozen=True)
class PreemptionEvent:
    """A mid-stream lease revocation in the fabric contention model.

    Once ``tenant`` has dispatched ``after_jobs`` jobs, its lease is
    revoked: the in-flight window must fully *drain* (every dispatched
    job resumes — the model analogue of the scheduler's drain deadline)
    before the next dispatch, which then lands on ``new_clusters`` (the
    re-placement window, possibly a degraded smaller one) after paying
    ``restage_cycles`` (resident operands re-crossing to the new root).
    """

    tenant: str
    after_jobs: int
    new_clusters: tuple
    restage_cycles: float = 0.0

    def __post_init__(self) -> None:
        if self.after_jobs < 1:
            raise ValueError(
                f"after_jobs must be >= 1, got {self.after_jobs}")
        if not self.new_clusters:
            raise ValueError("a re-placement needs at least one cluster")
        if self.restage_cycles < 0:
            raise ValueError(
                f"restage_cycles must be >= 0, got {self.restage_cycles}")


@dataclasses.dataclass
class FabricSimResult:
    """Discrete-event outcome of a multi-tenant fabric schedule."""

    makespan: float                      # first arrival -> last resume done
    completion: Dict[str, float]         # tenant -> last job's resume end
    host_busy: float                     # cycles the shared host was occupied
    work: float                          # sum of ideal serial work (n=1 cycles)
    # tenant -> every job's resume end, dispatch order (token latencies)
    job_completions: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)

    def utilization(self, num_clusters: int) -> float:
        """Useful-work fraction of the fabric: ideal serial cycles of the
        completed jobs over fabric-cycles elapsed.  The numerator is
        schedule-invariant, so utilization ratios between schedules reduce
        to inverse makespan ratios."""
        if self.makespan <= 0:
            return 0.0
        return self.work / (num_clusters * self.makespan)


def _workload_times(w: TenantWorkload, p: OccamyParams
                    ) -> tuple:
    """(t_host, t_dev, t_resume, serial_work) of one job of ``w``.

    ``t_host`` is the host-occupying dispatch leg (phase A + the doorbell
    store of B); ``t_resume`` the phase-I host leg; ``t_dev`` everything in
    between (propagation, C..H) from the single-job simulator at the
    lease's cluster count.
    """
    n = len(w.clusters)
    total = simulate(w.spec, n, "multicast", p).total
    t_host = (p.host_info_base + p.host_info_per_word * (1 + w.spec.arg_words)
              + p.host_store_first)
    t_resume = p.host_resume
    t_dev = total - t_host - t_resume
    work = simulate(w.spec, 1, "ideal", p).total
    return t_host, t_dev, t_resume, work


def _segment_table(w: TenantWorkload,
                   preemptions: Sequence[PreemptionEvent],
                   p: OccamyParams) -> List[tuple]:
    """``w``'s job stream split at its preemption events:
    ``(start_job, lease_key, t_dev, restage_cycles)`` per segment.  The
    host legs (dispatch, resume) are window-size-invariant, so only the
    device time is re-derived for a re-placement window."""
    table = [(0, tuple(w.clusters), _workload_times(w, p)[1], 0.0)]
    for e in sorted((e for e in preemptions if e.tenant == w.tenant),
                    key=lambda e: e.after_jobs):
        if e.after_jobs >= w.jobs or e.after_jobs <= table[-1][0]:
            continue
        seg_w = dataclasses.replace(w, clusters=tuple(e.new_clusters))
        table.append((e.after_jobs, tuple(e.new_clusters),
                      _workload_times(seg_w, p)[1], e.restage_cycles))
    return table


def _segment_at(table: List[tuple], job: int) -> tuple:
    seg = table[0]
    for entry in table:
        if entry[0] <= job:
            seg = entry
    return seg


def simulate_fabric(workloads: Sequence[TenantWorkload],
                    params: OccamyParams = DEFAULT_PARAMS,
                    preemptions: Sequence[PreemptionEvent] = ()
                    ) -> FabricSimResult:
    """Discrete-event multi-tenant schedule over the shared host.

    Per tenant: dispatches are serial on the host and bounded by the
    in-flight ``window``; a job's device phases start when its dispatch
    lands *and* its lease is free (jobs on one lease serialize, leases are
    concurrent); its resume runs on the host after the device phases end.
    The host serves dispatch/resume requests in eligibility order (FIFO,
    resume preferred on ties so windows drain), exactly like the wide-port
    model above.

    ``preemptions`` model revocable leases under contention: at each of a
    tenant's :class:`PreemptionEvent` boundaries its in-flight window
    must fully drain (every dispatched job resumes) before the next
    dispatch, which pays the event's restage delay and lands on the
    re-placement window — the timing shape of
    ``FabricScheduler.preempt`` → drain → snapshot → re-place → restage.
    """
    if not workloads:
        raise ValueError("empty workload set")
    p = params
    times = [_workload_times(w, p) for w in workloads]
    segs = [_segment_table(w, preemptions, p) for w in workloads]
    lease_free: Dict[tuple, float] = {}
    host_free = 0.0
    host_busy = 0.0
    dispatched = [0] * len(workloads)
    completed = [0] * len(workloads)
    last_host_end = [0.0] * len(workloads)
    last_resume_end = [0.0] * len(workloads)
    dev_end: List[List[float]] = [[] for _ in workloads]
    completion: Dict[str, float] = {}
    job_completions: Dict[str, List[float]] = {w.tenant: []
                                               for w in workloads}
    total_jobs = sum(w.jobs for w in workloads)
    done = 0
    while done < total_jobs:
        best = None      # (eligible, kind, idx)
        for k, w in enumerate(workloads):
            # resume of the oldest un-collected job (kind 0: frees windows)
            if completed[k] < dispatched[k]:
                cand = (dev_end[k][completed[k]], 0, k)
                if best is None or cand < best:
                    best = cand
            # next dispatch, if the window has room
            if (dispatched[k] < w.jobs
                    and dispatched[k] - completed[k] < max(1, w.window)):
                seg = _segment_at(segs[k], dispatched[k])
                boundary = (seg[0] == dispatched[k] and seg[0] > 0)
                if boundary and completed[k] < dispatched[k]:
                    pass        # drain gate: window must empty first
                else:
                    elig = max(w.arrival, last_host_end[k])
                    if boundary:
                        # the re-placement dispatch waits out the drain
                        # and pays the operand restage
                        elig = max(elig, last_resume_end[k] + seg[3])
                    cand = (elig, 1, k)
                    if best is None or cand < best:
                        best = cand
        assert best is not None, "scheduler deadlock (window < 1?)"
        eligible, kind, k = best
        w = workloads[k]
        t_host, _, t_resume, _ = times[k]
        start = max(host_free, eligible)
        if kind == 1:                               # dispatch
            seg = _segment_at(segs[k], dispatched[k])
            host_free = start + t_host
            host_busy += t_host
            last_host_end[k] = host_free
            key = seg[1]
            dev_start = max(host_free, lease_free.get(key, 0.0))
            lease_free[key] = dev_start + seg[2]
            dev_end[k].append(dev_start + seg[2])
            dispatched[k] += 1
        else:                                       # resume (job collected)
            host_free = start + t_resume
            host_busy += t_resume
            completed[k] += 1
            last_resume_end[k] = host_free
            completion[w.tenant] = max(completion.get(w.tenant, 0.0),
                                       host_free)
            job_completions[w.tenant].append(host_free)
            done += 1
    # the declared span is first arrival -> last resume done; completion
    # times stay absolute (same clock as the arrivals)
    makespan = (max(completion.values())
                - min(w.arrival for w in workloads))
    work = sum(t[3] * w.jobs for t, w in zip(times, workloads))
    return FabricSimResult(makespan=makespan, completion=completion,
                           host_busy=host_busy, work=work,
                           job_completions=job_completions)


def fabric_makespan_model(workloads: Sequence[TenantWorkload],
                          params: OccamyParams = DEFAULT_PARAMS,
                          preemptions: Sequence[PreemptionEvent] = ()
                          ) -> float:
    """Closed-form makespan prediction — the §6 treatment extended to the
    multi-tenant fabric.  Three lower bounds, composed by max:

    * **tenant pipeline** — a tenant's jobs flow at the pipeline period
      ``max(t_host + t_resume, t_dev)`` (host leg hidden behind the
      previous job's device phases once the window is open); each
      preemption boundary adds a full drain-and-refill — the segment
      tail (``t_dev + t_resume``), the restage delay, and a fresh
      un-hidden host leg — on the segment's own window size;
    * **shared host** — every dispatch and resume serializes on the host,
      plus the shortest device tail after the last dispatch;
    * **shared lease** — workloads on an identical cluster selection
      serialize their device phases (the whole-mesh baseline's bound),
      counted per segment under preemption.

    The second-order effects the discrete-event model resolves (host FIFO
    interleaving, window drain order) are deliberately dropped — the same
    abstraction level as the paper's analytical model (§6, < 15 % error).
    """
    if not workloads:
        raise ValueError("empty workload set")
    times = [_workload_times(w, params) for w in workloads]
    segs = [_segment_table(w, preemptions, params) for w in workloads]
    bounds = []
    lease_work: Dict[tuple, float] = {}      # key -> summed device cycles
    lease_first: Dict[tuple, float] = {}     # key -> earliest dispatch land
    lease_tail: Dict[tuple, float] = {}      # key -> shortest resume leg
    for k, w in enumerate(workloads):
        t_host, _, t_resume, _ = times[k]
        table = segs[k]
        bound = w.arrival
        for i, (start, key, t_dev_s, restage) in enumerate(table):
            jobs_s = (table[i + 1][0] if i + 1 < len(table)
                      else w.jobs) - start
            period = max(t_host + t_resume, t_dev_s)
            bound += (restage + t_host + (jobs_s - 1) * period
                      + t_dev_s + t_resume)
            lease_work[key] = lease_work.get(key, 0.0) + jobs_s * t_dev_s
            lease_first[key] = min(lease_first.get(key, float("inf")),
                                   w.arrival + t_host)
            lease_tail[key] = min(lease_tail.get(key, t_resume), t_resume)
        bounds.append(bound)
    host_work = sum((times[k][0] + times[k][2]) * w.jobs
                    for k, w in enumerate(workloads))
    bounds.append(min(w.arrival for w in workloads) + host_work
                  + min(min(s[2] for s in table) for table in segs))
    for key, dev_work in lease_work.items():
        bounds.append(lease_first[key] + dev_work + lease_tail[key])
    # same span convention as simulate_fabric: first arrival -> last done
    return max(bounds) - min(w.arrival for w in workloads)


# ---------------------------------------------------------------------------
# Dependent job graphs (the PR-8 scoreboard dispatcher's measurement
# domain): an out-of-order host issues a DAG of jobs whose results flow
# device-to-device, so a K-deep chain costs the critical path plus
# per-hop forward legs — not K isolated offloads with host round trips.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphJob:
    """One node of a dependent job graph (simulator vocabulary).

    ``deps`` lists one producer node index per *dataflow edge* — repeat
    an index when a consumer reads the same producer's result through
    several operands (``y ← a·y + y``).  Each edge forwards the
    producer's ``out_bytes`` result from its selection to this node's
    (``replicate_in=True`` if this consumer reads forwarded operands
    replicated — the fan-out-tree case — instead of sharded).
    """

    spec: JobSpec
    clusters: tuple
    deps: Tuple[int, ...] = ()
    out_bytes: float = 0.0
    replicate_in: bool = False

    def __post_init__(self) -> None:
        if not self.clusters:
            raise ValueError("a graph node needs at least one cluster")


@dataclasses.dataclass
class GraphSimResult:
    """Discrete-event outcome of one scoreboarded graph dispatch."""

    makespan: float                  # first dispatch -> last resume done
    node_finish: List[float]         # per node: its resume end
    host_busy: float
    issue_order: List[int]           # the scoreboard's actual issue order


def _graph_times(nodes: Sequence[GraphJob], p: OccamyParams) -> List[tuple]:
    return [_workload_times(
        TenantWorkload(tenant=str(i), spec=nd.spec, clusters=nd.clusters),
        p) for i, nd in enumerate(nodes)]


def _edge_cost(nodes: Sequence[GraphJob], d: int, v: int,
               p: OccamyParams, closed_form: bool) -> float:
    fn = forward_model if closed_form else simulate_forward
    return fn(nodes[d].out_bytes, nodes[d].clusters, nodes[v].clusters,
              replicate=nodes[v].replicate_in, params=p)


def simulate_graph(nodes: Sequence[GraphJob],
                   params: OccamyParams = DEFAULT_PARAMS,
                   window: int = 4) -> GraphSimResult:
    """Discrete-event model of scoreboarded out-of-order graph dispatch.

    The host issues nodes the way ``Session.submit_graph`` does — through
    the Active-List/Integer-Queue scoreboard, a node becoming issuable
    when every producer has *issued* (async dispatch chains the data
    device-side), bounded by ``window`` in-flight completion-unit copies.
    Dispatch and resume legs serialize on the shared host; a node's
    device phases start when its dispatch lands, its lease is free
    (nodes sharing a selection serialize on it), and every producer's
    device phases plus the edge's d2d forward leg
    (:func:`simulate_forward`) have finished.  Retirement fetches only
    the completion cause — intermediate results never ride the host
    link, which is exactly why the chain costs critical path + forward
    hops instead of K round trips.
    """
    if not nodes:
        raise ValueError("empty graph")
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    from repro.core.scoreboard import Scoreboard
    sb = Scoreboard([nd.deps for nd in nodes])
    p = params
    times = _graph_times(nodes, p)
    host_free = 0.0
    host_busy = 0.0
    lease_free: Dict[tuple, float] = {}
    dev_end: Dict[int, float] = {}
    node_finish = [0.0] * len(nodes)
    unretired: List[int] = []         # issued, awaiting resume (age order)
    while not sb.all_retired:
        ready = sb.ready()
        if ready and sb.inflight < window:
            i = ready[0]                         # Integer Queue, age order
            t_host, t_dev, _, _ = times[i]
            start = host_free
            host_free = start + t_host
            host_busy += t_host
            key = tuple(nodes[i].clusters)
            dev_start = max(host_free, lease_free.get(key, 0.0))
            for d in nodes[i].deps:
                dev_start = max(dev_start,
                                dev_end[d] + _edge_cost(nodes, d, i, p,
                                                        closed_form=False))
            dev_end[i] = dev_start + t_dev
            lease_free[key] = dev_end[i]
            sb.issue(i)
            unretired.append(i)
        else:
            # window full or nothing ready: retire the earliest-finishing
            # in-flight node (its resume leg occupies the host)
            i = min(unretired, key=lambda j: dev_end[j])
            unretired.remove(i)
            t_resume = times[i][2]
            start = max(host_free, dev_end[i])
            host_free = start + t_resume
            host_busy += t_resume
            node_finish[i] = host_free
            sb.retire(i)
    return GraphSimResult(makespan=max(node_finish),
                          node_finish=node_finish, host_busy=host_busy,
                          issue_order=list(sb.issue_order))


def graph_critical_path(nodes: Sequence[GraphJob],
                        params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Closed-form graph latency — three lower bounds composed by max.

    * **critical path** — the longest dataflow chain: one un-hidden
      dispatch leg, then ``Σ (t_dev + t_fwd)`` along the path
      (:func:`forward_model` per edge), then the final resume;
    * **shared host** — every dispatch and resume serializes on the
      host core, plus the shortest device time;
    * **shared lease** — nodes on an identical selection serialize
      their device phases.

    Host FIFO interleaving and window-drain order are deliberately
    dropped (§6 abstraction level, < 15 % error vs
    :func:`simulate_graph`).
    """
    if not nodes:
        raise ValueError("empty graph")
    times = _graph_times(nodes, params)
    n = len(nodes)
    g = [0.0] * n                    # dataflow DP in (validated) topo order
    from repro.core.scoreboard import Scoreboard
    sb = Scoreboard([nd.deps for nd in nodes])
    order: List[int] = []
    while not sb.all_issued:
        i = sb.ready()[0]
        sb.issue(i)
        order.append(i)
    for i in order:
        t_dev = times[i][1]
        base = max((g[d] + _edge_cost(nodes, d, i, params, closed_form=True)
                    for d in nodes[i].deps), default=0.0)
        g[i] = base + t_dev
    sources = [i for i in range(n) if not nodes[i].deps]
    cp = (min(times[i][0] for i in sources)
          + max(g[i] + times[i][2] for i in range(n)))
    host = (sum(times[i][0] + times[i][2] for i in range(n))
            + min(times[i][1] for i in range(n)))
    bounds = [cp, host]
    lease_dev: Dict[tuple, float] = {}
    lease_head: Dict[tuple, float] = {}
    lease_tail: Dict[tuple, float] = {}
    for i, nd in enumerate(nodes):
        key = tuple(nd.clusters)
        lease_dev[key] = lease_dev.get(key, 0.0) + times[i][1]
        lease_head[key] = min(lease_head.get(key, float("inf")), times[i][0])
        lease_tail[key] = min(lease_tail.get(key, float("inf")), times[i][2])
    for key, dev in lease_dev.items():
        bounds.append(lease_head[key] + dev + lease_tail[key])
    return max(bounds)


def isolated_graph_cycles(nodes: Sequence[GraphJob],
                          params: OccamyParams = DEFAULT_PARAMS) -> float:
    """The chained ``submit``+``wait`` baseline the graph path replaces.

    Every node runs as an isolated synchronous offload, and every
    dataflow edge bounces through the host: one d2h fetch per *unique*
    producer a consumer reads (``wait()`` fetches the result once) plus
    one h2d restage per edge (each consuming operand is staged — through
    the staging tree when the consumer reads it replicated).  The
    ``dag`` bench's ≤ 0.6× acceptance bar compares
    :func:`simulate_graph` against this.
    """
    if not nodes:
        raise ValueError("empty graph")
    p = params
    total = sum(simulate(nd.spec, len(nd.clusters), "multicast", p).total
                for nd in nodes)
    for i, nd in enumerate(nodes):
        for d in sorted(set(nd.deps)):                     # d2h fetch
            b = nodes[d].out_bytes
            total += (p.dma_setup_one
                      + max(1.0, b / p.wide_bw_bytes_per_cycle)
                      + p.dma_latency)
        for d in nd.deps:                                  # h2d restage
            b = nodes[d].out_bytes
            total += (simulate_staging(b, nd.clusters, "tree", p)
                      if nd.replicate_in else
                      (p.dma_setup_one
                       + max(1.0, b / p.wide_bw_bytes_per_cycle)
                       + p.dma_latency))
    return total


@dataclasses.dataclass(frozen=True)
class StagingCostModel:
    """Calibrated staging-cost model for an arbitrary substrate (wallclock).

    The cycle-level :func:`staging_model` is anchored to Occamy constants;
    real substrates (a CPU device mesh, a TPU pod) have their own link
    costs.  This model keeps the same *shape* — O(n) uploads vs one upload
    plus (n-1) tree-edge copies — with three constants calibrated from
    measured n ∈ {1, 2} points (:meth:`calibrate`), then predicts the
    remaining sweep; ``benchmarks/offload_wallclock.py`` validates the
    prediction against measurement under the paper's <15 % bar.
    """

    t_up: float          # one host->device transfer of the operand
    t_edge: float        # one tree-edge device-to-device copy
    t_fixed: float = 0.0  # per-staging fixed overhead

    def predict(self, mode: str, n: int) -> float:
        if n < 1:
            raise ValueError(f"n must be >= 1, got {n}")
        if mode == "host_fanout":
            return self.t_fixed + n * self.t_up
        if mode == "tree":
            return self.t_fixed + self.t_up + (n - 1) * self.t_edge
        raise ValueError(f"mode must be one of {STAGING_MODES}")

    @classmethod
    def calibrate(cls, hf1: float, hf2: float, tree_k: float, k: int = 2
                  ) -> "StagingCostModel":
        """Fit from three measurements: host_fanout at n ∈ {1, 2} and tree
        at n=k.  ``hf2 - hf1`` isolates one upload; ``(tree_k - hf1) /
        (k - 1)`` averages the edge cost over k-1 tree edges (larger k
        smooths per-edge measurement noise)."""
        t_up = hf2 - hf1
        if t_up <= 0:
            raise ValueError(
                f"host_fanout must grow with n (got {hf1} -> {hf2})")
        if k < 2:
            raise ValueError(f"tree calibration point needs k >= 2, got {k}")
        return cls(t_up=t_up, t_edge=(tree_k - hf1) / (k - 1),
                   t_fixed=hf1 - t_up)
