"""Job completion unit — paper §4.3, figure 6.

Host-side mirror of the unit's register semantics, plus the two device-side
completion-synchronization collectives used by the offload runtime:

* ``central_counter`` (baseline): every cluster's "arrival" hops to cluster 0
  through a chain of ``collective-permute``s — an O(n)-depth dependency chain,
  the TPU-mesh analogue of the software central-counter barrier whose latency
  grows with the number of clusters (§5.5 H).
* ``unit`` (the paper's extension): one fused ``psum`` of the per-cluster
  arrival flags — a single all-reduce (O(log n) tree on the ICI), the
  analogue of the CLINT job completion unit: clusters post arrivals, the
  "unit" (the reduction) fires once arrivals == offload register.

The host-side :class:`CompletionUnit` reproduces fig. 6 exactly: an offload
register programmed with the expected arrival count, an arrivals counter that
auto-increments, an interrupt that fires when they match (deferred if one is
already pending), auto-reset, and multiple instances addressable by job ID
for outstanding-job tracking (§4.3: "multiple copies of this logic can be
instantiated to support multiple outstanding jobs").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.analysis import sanitizer as _san


# ---------------------------------------------------------------------------
# Device-side completion collectives (used inside shard_map).
# ---------------------------------------------------------------------------


def completion_unit_arrivals(done: jnp.ndarray, axis: str) -> jnp.ndarray:
    """The extension: one all-reduce == the completion unit's arrivals count."""
    return jax.lax.psum(done, axis)


def central_counter_arrivals(done: jnp.ndarray, axis: str, n: int) -> jnp.ndarray:
    """The baseline: serialize arrivals into cluster 0 hop by hop.

    Builds an O(n) chain of ``collective-permute`` ops: cluster i's flag
    reaches cluster 0 after i hops, and cluster 0 accumulates one increment
    per hop — mirroring the AMO-serialized software barrier.  The returned
    count is meaningful on cluster 0 (other clusters return their partial
    view, as in the real system where only cluster 0 reads the counter).
    """
    if n == 1:
        return done
    idx = jax.lax.axis_index(axis)
    count = done
    hopping = done
    perm = [(i, i - 1) for i in range(1, n)]
    for _ in range(n - 1):
        hopping = jax.lax.ppermute(hopping, axis, perm)
        count = count + jnp.where(idx == 0, hopping, jnp.zeros_like(hopping))
    return count


# ---------------------------------------------------------------------------
# Host-side register-level model of the unit (fig. 6).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _UnitRegs:
    offload: int = 0      # expected arrivals, programmed by the host
    arrivals: int = 0     # auto-incrementing arrivals counter


class CompletionUnit:
    """Fig. 6 logic: offload/arrivals registers + IPI fire + auto-reset.

    ``n_units`` > 1 instantiates multiple copies addressed by job ID
    (supporting multiple outstanding jobs / task overlapping, §4.3).
    """

    def __init__(self, n_units: int = 1):
        self._regs: List[_UnitRegs] = [_UnitRegs() for _ in range(n_units)]
        self._pending_irq: Optional[int] = None   # job id carried as cause
        self._deferred: List[int] = []            # fired while another pending
        self._collected: set = set()              # causes drained early

    @property
    def n_units(self) -> int:
        return len(self._regs)

    def program(self, n_clusters: int, job_id: int = 0) -> None:
        """Host programs the offload register at job dispatch."""
        regs = self._regs[job_id % len(self._regs)]
        if regs.offload != 0:
            raise RuntimeError(f"unit {job_id} already tracking an offload")
        if n_clusters <= 0:
            raise ValueError("n_clusters must be positive")
        s = _san.active()
        if s is not None:
            s.unit_program(self, job_id)
        regs.offload = n_clusters
        regs.arrivals = 0

    def arrive(self, job_id: int = 0, count: int = 1) -> None:
        """A cluster writes the arrivals register (atomic increment)."""
        regs = self._regs[job_id % len(self._regs)]
        if regs.offload == 0:
            raise RuntimeError(f"arrival for unprogrammed unit {job_id}")
        regs.arrivals += count
        if regs.arrivals == regs.offload:
            # Job complete: fire (or defer) the IPI, auto-reset the counter.
            if self._pending_irq is None:
                self._pending_irq = job_id
            else:
                self._deferred.append(job_id)
            regs.offload = 0
            regs.arrivals = 0

    def pending_cause(self) -> Optional[int]:
        """The job ID carried as the interrupt cause (None = no pending IPI)."""
        return self._pending_irq

    def clear(self) -> Optional[int]:
        """Host clears the IPI; a deferred completion fires immediately after
        (fig. 6: "otherwise this will occur as soon as the previous pending
        interrupt is cleared")."""
        cause = self._pending_irq
        self._pending_irq = self._deferred.pop(0) if self._deferred else None
        return cause

    def collect(self, job_id: int) -> None:
        """Drain fired causes until ``job_id``'s completion is observed.

        Handles out-of-order ``wait()`` across multiple outstanding jobs:
        causes belonging to *other* jobs are parked and satisfy their own
        later ``collect()`` calls instead of being treated as protocol
        errors (the host-side analogue of the deferred-interrupt replay in
        fig. 6).
        """
        s = _san.active()
        if s is not None:
            s.unit_collect(self, job_id)
        if job_id in self._collected:
            self._collected.discard(job_id)
            return
        while True:
            cause = self.clear()
            if cause is None:
                raise RuntimeError(
                    f"completion for job {job_id} never fired "
                    f"(collected={sorted(self._collected)})")
            if cause == job_id:
                return
            self._collected.add(cause)

    def cancel(self, job_id: int) -> int:
        """Abandon a stuck offload: reset the unit's registers without
        firing the IPI, returning how many arrivals were still missing.

        The fault-recovery path uses this after a deadline trip — the
        register state (``outstanding()``) has already been read as the
        failure signal, and the unit must be reusable for the resubmit.
        A unit that is not tracking an offload cancels as a no-op (0).

        A cancel also *purges* the job's already-fired interrupt state:
        if its completion raced the cancel (all arrivals landed, cause
        pending or deferred behind another job's IPI — fig. 6's replay
        path), the stale cause must not fire for, or be collected by, a
        later job sharing the unit.
        """
        s = _san.active()
        if s is not None:
            s.unit_cancel(self, job_id)
        regs = self._regs[job_id % len(self._regs)]
        missing = 0
        if regs.offload != 0:
            missing = regs.offload - regs.arrivals
            regs.offload = 0
            regs.arrivals = 0
        # purge a completion that raced the cancel (the deferred-IRQ
        # replay in clear() would otherwise resurrect it later)
        if self._pending_irq == job_id:
            self._pending_irq = (self._deferred.pop(0) if self._deferred
                                 else None)
        self._deferred = [j for j in self._deferred if j != job_id]
        self._collected.discard(job_id)
        return missing

    def outstanding(self) -> Dict[int, int]:
        """job-id -> arrivals still missing, for every in-flight unit."""
        return {
            jid: r.offload - r.arrivals
            for jid, r in enumerate(self._regs)
            if r.offload > 0
        }
