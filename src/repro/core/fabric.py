"""Fabric scheduler — multi-tenant cluster leases over the offload mesh.

The paper's measurements assume one host job owns the whole 200+-core
fabric, but its own scaling data argues against that as an operating
point: offload overheads grow with n while fine-grained jobs stop
profiting from extra clusters early (fig. 7 / §5.3), so a small job on
the full mesh wastes most of it.  ESP-style SoC research treats
accelerator tiles as *schedulable resources*, and the companion offload
work (arXiv:2404.01908) chooses offload modes from a cost model — this
module applies both ideas to the fabric itself:

* :class:`ClusterLease` — ownership of a contiguous cluster window.
  Sessions bind a lease instead of the global mesh; disjoint leases run
  concurrently and bit-identically to sequential full-mesh runs (the
  sub-mesh, shardings, and compiled programs depend only on the lease's
  device window — asserted in ``tests/test_fabric.py``).  Aligned
  power-of-two windows encode as ONE multicast request
  (:func:`repro.core.multicast.encode_contiguous_window`), so the
  paper's O(1) wakeup and the PR-3 fan-out tree stay legal per lease.
* :class:`FabricScheduler` — admits, places, queues, and resizes leases.
  Placement and slice sizing are *model-driven*: candidate windows are
  scored by the §6 cost model (dispatch + staging + compute via
  ``repro.core.session.estimate`` and the quadrant-aware
  ``simulate_staging``), so a lease lands where the predicted makespan
  is smallest — e.g. inside one quadrant rather than straddling two.
* :class:`Tenant` / :class:`SchedulerPolicy` — the typed vocabulary:
  resident ``SERVE`` tenants hold a floor lease and burst between decode
  batches (``resize``), bursty ``OFFLOAD`` tenants lease for a job
  stream and release.

The multi-tenant *contention* these placements imply (every tenant's
dispatch and resume serializes on the one host core) is modeled by
:func:`repro.core.simulator.simulate_fabric`; the ``scheduler`` bench
suite validates utilization, placement regret vs. exhaustive search,
and the closed-form makespan prediction against it.

PR 7 makes the scheduler *overload-robust* the way PR 6 made it
fault-robust: leases are revocable (:meth:`FabricScheduler.preempt`
drains the victim under a §6-model drain deadline, snapshots residency
through the failover host-snapshot path, and re-places it later with
resident operands restaged through the broadcast tree — bit-identical
outputs), admission is SLO-aware (``Tenant(slo=..., priority=...)``, a
typed :class:`Overloaded` instead of silent queue growth), grant
ordering uses ``Tenant.weight`` with aging so backfill cannot starve
large requests, and pressure walks a graceful-degradation ladder
(compaction → elastic floor shrink → pow2 degrade → priority
preemption) before anything is shed.  The ``preempt`` bench suite
gates it with a trace-driven serve×offload churn scenario.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import weakref
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple, Union

from repro.analysis import sanitizer as _san
from repro.core import broadcast as bc
from repro.core import multicast as mc
from repro.core import simulator
from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.policy import TenantKind
from repro.core.scoreboard import GraphError

#: replicated-operand footprint assumed when a lease request names no job —
#: placement still prefers quadrant-local windows over straddling ones
NOMINAL_STAGE_BYTES = 64 << 10


class LeaseError(RuntimeError):
    """A lease operation on released/stale/foreign state."""


class LeaseUnavailable(LeaseError):
    """No placement satisfies the request right now (queueable)."""


class Overloaded(LeaseUnavailable):
    """Typed admission backpressure: the contention model predicts the
    request would violate its tenant's SLO (or the queue is at its
    configured depth), so the scheduler *sheds* instead of silently
    queueing.  ``retry_after_cycles`` is the model-predicted virtual
    cycles until capacity next frees — the earliest re-submit worth
    making."""

    def __init__(self, message: str, *, retry_after_cycles: float = 0.0):
        super().__init__(message)
        self.retry_after_cycles = float(retry_after_cycles)


@dataclasses.dataclass
class FabricHealth:
    """Scheduler-side recovery counters (the fabric analogue of
    :class:`repro.core.faults.SessionHealth`)."""

    failed_clusters: int = 0     # clusters ever marked unhealthy
    failovers: int = 0           # leases re-placed onto healthy windows
    degradations: int = 0        # failovers that had to shrink the lease
    lost_leases: int = 0         # leases with no healthy window at all
    restaged_operands: int = 0   # resident operands re-staged on failover
    preemptions: int = 0         # leases revoked (drained + re-queued)
    migrations: int = 0          # leases moved by defragmenting compaction
    floor_shrinks: int = 0       # elastic serve floors halved under pressure
    degraded_grants: int = 0     # requests granted a smaller pow2 window
    overloaded: int = 0          # admissions shed with a typed Overloaded

    def snapshot(self) -> "FabricHealth":
        return dataclasses.replace(self)


@dataclasses.dataclass(frozen=True)
class Tenant:
    """A fabric tenant, to the scheduler's admission model.

    ``weight`` is the fair-share weight inside a priority class (grant
    ordering ages it, see :meth:`FabricScheduler._admit_pending`);
    ``priority`` is the preemption class — under a ``preemption``
    policy, higher-priority requests may revoke lower-priority leases.
    ``slo`` (virtual cycles) arms SLO admission: a request whose
    model-predicted queue wait + makespan exceeds it is shed with a
    typed :class:`Overloaded` instead of queueing.
    """

    name: str
    kind: TenantKind = TenantKind.OFFLOAD
    weight: float = 1.0          # fair-share weight within a priority class
    slo: Optional[float] = None  # max predicted wait+makespan, virtual cycles
    priority: int = 0            # preemption class; higher may revoke lower

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        object.__setattr__(self, "kind", TenantKind(self.kind))
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")
        if self.slo is not None and self.slo <= 0:
            raise ValueError(f"tenant slo must be > 0 cycles, got {self.slo}")
        object.__setattr__(self, "priority", int(self.priority))


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """How the scheduler places and sizes leases.

    * ``placement`` — ``"model"`` scores every feasible contiguous window
      by the predicted staging cost of the request's operand footprint
      (quadrant-aware, ties to the lowest start); ``"first_fit"`` takes
      the lowest free window unscored.
    * ``align`` — prefer windows whose start is aligned to the largest
      power of two in the lease size, so the window encodes as a single
      multicast request and buddy-style packing limits fragmentation.
      Falls back to unaligned windows when no aligned one is free.
    * ``share_slack`` — when the model sizes a slice (``n=None`` with a
      job), any smaller candidate within ``1 + share_slack`` of the best
      predicted makespan wins, leaving head-room for co-tenants.
    * ``preemption`` — ``"off"`` keeps admission cooperative;
      ``"priority"`` arms the overload ladder: a request that cannot
      place first compacts the fabric, then shrinks elastic serve
      floors, then degrades itself to a smaller pow2 window at
      model-equal makespan, then revokes strictly-lower-priority leases
      (drain → snapshot → re-queue), before shedding.
    * ``max_queue_depth`` — ``queue=True`` requests beyond this depth
      are shed with a typed :class:`Overloaded` instead of enqueued
      (``None`` = unbounded).
    * ``aging_grants`` — starvation bound for the pending queue: once a
      blocked entry has been bypassed by this many backfill grants it
      reserves the fabric (no further backfill behind it) until it
      places.
    """

    placement: str = "model"
    align: bool = True
    share_slack: float = 0.05
    preemption: str = "off"
    max_queue_depth: Optional[int] = None
    aging_grants: int = 8

    def __post_init__(self) -> None:
        if self.placement not in ("model", "first_fit"):
            raise ValueError(
                f"placement {self.placement!r} not in ('model', 'first_fit')")
        if self.share_slack < 0:
            raise ValueError(
                f"share_slack must be >= 0, got {self.share_slack}")
        if self.preemption not in ("off", "priority"):
            raise ValueError(
                f"preemption {self.preemption!r} not in ('off', 'priority')")
        if self.max_queue_depth is not None and self.max_queue_depth < 0:
            raise ValueError(
                f"max_queue_depth must be >= 0, got {self.max_queue_depth}")
        if self.aging_grants < 1:
            raise ValueError(
                f"aging_grants must be >= 1, got {self.aging_grants}")


@dataclasses.dataclass(frozen=True)
class ClusterLease:
    """Ownership of a contiguous cluster window of the fabric.

    The window is expressed in *global* cluster ids — they key dispatch
    plans, drive quadrant-aware staging trees, and make concurrent
    sessions on disjoint leases bit-equal to sequential full-mesh runs
    on the same selections.
    """

    lease_id: int
    tenant: str
    clusters: Tuple[int, ...]
    scheduler: Optional["FabricScheduler"] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self) -> None:
        ids = tuple(int(c) for c in self.clusters)
        if not ids:
            raise ValueError("a lease must cover at least one cluster")
        if ids != tuple(sorted(set(ids))) or ids[0] < 0:
            raise ValueError(
                f"lease clusters must be sorted, unique, non-negative "
                f"ids; got {ids}")
        object.__setattr__(self, "clusters", ids)

    @property
    def n(self) -> int:
        return len(self.clusters)

    @property
    def start(self) -> int:
        return self.clusters[0]

    @property
    def active(self) -> bool:
        """True while this exact lease is the scheduler's current grant."""
        if self.scheduler is None:
            return True          # a synthesized whole-mesh descriptor
        return self.scheduler._current(self) is self

    def requests(self) -> List[mc.MulticastRequest]:
        """The multicast cover of this lease's cluster set — ONE request
        when the window is a size-aligned power-of-two block (the
        legality the scheduler's aligned placement preserves).  Encodes
        the *actual* set, so a synthesized lease over a non-contiguous
        runtime window still covers exactly its clusters (with more
        requests)."""
        num = (self.scheduler.num_clusters if self.scheduler is not None
               else max(mc.NUM_CLUSTERS, self.clusters[-1] + 1))
        return mc.encode_cluster_selection_multi(self.clusters, num)

    def tree(self, clusters_per_quadrant: int = mc.CLUSTERS_PER_QUADRANT
             ) -> bc.BroadcastTree:
        """The lease's quadrant-aware fan-out tree (PR-3 staging path)."""
        return bc.build_tree(self.clusters, clusters_per_quadrant)

    @property
    def devices(self) -> List[Any]:
        if self.scheduler is None:
            raise LeaseError("synthesized lease carries no devices")
        return self.scheduler.devices_for(self.clusters)

    def release(self) -> None:
        if self.scheduler is not None:
            self.scheduler.release(self)


class PendingLease:
    """A queued lease request; ``lease`` is set when the grant lands.

    ``skipped`` counts backfill grants that bypassed this entry while it
    was blocked — the aging input to grant ordering and the head
    reservation that bounds starvation.  A pending entry produced by
    :meth:`FabricScheduler.preempt` carries ``resume_id`` (the revoked
    lease's id): its grant re-keys under that id and resumes the
    suspended session with its snapshots restaged.
    """

    def __init__(self, tenant: str, n: Optional[int],
                 clusters: Optional[Tuple[int, ...]],
                 job: Any, batch: int):
        self.tenant = tenant
        self.n = n
        self.clusters = clusters
        self.job = job
        self.batch = batch
        self.lease: Optional[ClusterLease] = None
        self.seq: int = 0                      # FIFO arrival order
        self.skipped: int = 0                  # bypassing backfill grants
        self.cancelled: bool = False
        self.resume_id: Optional[int] = None   # preempted lease to resume

    @property
    def ready(self) -> bool:
        return self.lease is not None


class FabricScheduler:
    """Admission, placement, and resizing of cluster leases.

    ``devices`` (one per cluster) makes leases executable — sessions and
    serve tenants bind them; with ``num_clusters`` alone the scheduler
    runs model-only (the bench suites' mode).  Placement candidates are
    contiguous free windows; the ``"model"`` policy scores them with the
    quadrant-aware staging model, slice sizing (``n=None`` + ``job``)
    minimizes the predicted makespan of the submitted batch.
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None, *,
                 num_clusters: Optional[int] = None,
                 params: OccamyParams = DEFAULT_PARAMS,
                 policy: SchedulerPolicy = SchedulerPolicy()):
        if devices is None and num_clusters is None:
            import jax
            devices = jax.devices()
        self._devices = list(devices) if devices is not None else None
        if num_clusters is None:
            num_clusters = len(self._devices)
        elif self._devices is not None and num_clusters != len(self._devices):
            raise ValueError(
                f"num_clusters={num_clusters} != {len(self._devices)} devices")
        if num_clusters < 1:
            raise ValueError(f"num_clusters must be >= 1, got {num_clusters}")
        self.num_clusters = int(num_clusters)
        self.params = params
        self.policy = policy
        self._owner: Dict[int, int] = {}          # cluster -> lease_id
        self._leases: Dict[int, ClusterLease] = {}
        self._tenants: Dict[str, Tenant] = {}
        self._pending: Deque[PendingLease] = collections.deque()
        self._next_id = itertools.count(1)
        self._next_seq = itertools.count(1)       # pending arrival order
        self._unhealthy: set = set()              # failed global cluster ids
        self._health = FabricHealth()
        # lease_id -> weakref to the bound Session (failover callback)
        self._sessions: Dict[int, Any] = {}
        # lease_id -> (job, batch) as granted — drain deadlines + ETAs
        self._grant_info: Dict[int, Tuple[Any, int]] = {}
        # lease_id -> predicted makespan at grant (admission ETA model)
        self._eta: Dict[int, float] = {}
        # lease_id -> elastic floor (serve tenants; pressure ladder rung 2)
        self._elastic: Dict[int, int] = {}
        self._hold_admit = False                  # defer grants mid-ladder

    # -- introspection ------------------------------------------------------

    @property
    def leases(self) -> Tuple[ClusterLease, ...]:
        return tuple(self._leases[i] for i in sorted(self._leases))

    @property
    def pending(self) -> Tuple[PendingLease, ...]:
        return tuple(self._pending)

    def free_clusters(self) -> Tuple[int, ...]:
        return tuple(c for c in range(self.num_clusters)
                     if c not in self._owner and c not in self._unhealthy)

    def unhealthy_clusters(self) -> Tuple[int, ...]:
        return tuple(sorted(self._unhealthy))

    def health(self) -> FabricHealth:
        """A snapshot of the scheduler's recovery counters."""
        return self._health.snapshot()

    def current_lease(self, lease: ClusterLease) -> Optional[ClusterLease]:
        """The scheduler's current grant for ``lease``'s id (the lease
        object a failover or resize replaced it with), or ``None`` when
        the lease is gone — holders refresh stale references through
        this instead of keying scheduler calls on a dead object."""
        return self._leases.get(lease.lease_id)

    def tenant(self, name: str) -> Optional[Tenant]:
        return self._tenants.get(name)

    def devices_for(self, clusters: Sequence[int]) -> List[Any]:
        if self._devices is None:
            raise LeaseError(
                "model-only scheduler (constructed with num_clusters, no "
                "devices) cannot back executable leases")
        return [self._devices[c] for c in clusters]

    def _current(self, lease: ClusterLease) -> Optional[ClusterLease]:
        return self._leases.get(lease.lease_id)

    # -- placement ----------------------------------------------------------

    def _free_runs(self) -> List[Tuple[int, int]]:
        """Contiguous free runs as (start, length), ascending."""
        runs: List[Tuple[int, int]] = []
        start = None
        for c in range(self.num_clusters + 1):
            free = (c < self.num_clusters and c not in self._owner
                    and c not in self._unhealthy)
            if free and start is None:
                start = c
            elif not free and start is not None:
                runs.append((start, c - start))
                start = None
        return runs

    def _windows(self, n: int) -> List[Tuple[int, ...]]:
        """Feasible contiguous windows of size ``n``, aligned-first."""
        all_starts = [s + k for s, length in self._free_runs()
                      for k in range(length - n + 1)]
        if not all_starts:
            return []
        starts = all_starts
        if self.policy.align:
            align = 1 << (n.bit_length() - 1)     # largest pow2 <= n
            aligned = [s for s in all_starts if s % align == 0]
            starts = aligned or all_starts
        return [tuple(range(s, s + n)) for s in starts]

    def placement_cost(self, clusters: Sequence[int],
                       stage_bytes: int = NOMINAL_STAGE_BYTES) -> float:
        """Predicted staging cycles of one replicated operand on this
        window — the placement-sensitive model term (quadrant-aware tree
        legs; windows inside one quadrant beat straddling ones)."""
        return simulator.simulate_staging(
            max(1, stage_bytes), list(clusters), "tree", self.params)

    def _stage_bytes(self, job: Any) -> int:
        if job is None:
            return NOMINAL_STAGE_BYTES
        from repro.core.session import Planner
        return max(1, Planner(self.params).replicated_bytes(job))

    def predict_makespan(self, job: Any, clusters: Sequence[int],
                         batch: int = 1) -> float:
        """§6 model of a batch of ``job`` on this window: first launch
        end-to-end plus the amortized per-job pipeline period for the
        rest (dispatch + staging + compute, placement-aware)."""
        from repro.core.session import estimate
        est = estimate(job, clusters=list(clusters), batch=batch,
                       params=self.params)
        stage = est.staging_cycles.get("direct", 0.0)
        return est.job_cycles + stage + max(0, batch - 1) * est.per_job_cycles

    def _place(self, n: int, job: Any = None, batch: int = 1
               ) -> Optional[Tuple[int, ...]]:
        windows = self._windows(n)
        if not windows:
            return None
        if self.policy.placement == "first_fit":
            return min(windows, key=lambda w: w[0])
        nbytes = self._stage_bytes(job)
        return min(windows,
                   key=lambda w: (self.placement_cost(w, nbytes), w[0]))

    def _pick_slice(self, job: Any, batch: int) -> Optional[Tuple[int, ...]]:
        """Model-driven slice sizing: among power-of-two sizes that fit
        the free fabric, place each candidate and keep the smallest one
        whose predicted makespan is within ``1 + share_slack`` of the
        best — small enough to share, big enough to be near-optimal."""
        largest = max((length for _, length in self._free_runs()),
                      default=0)
        if largest < 1:
            return None
        sizes = [1 << k for k in range(largest.bit_length())
                 if (1 << k) <= largest]
        scored: List[Tuple[float, int, Tuple[int, ...]]] = []
        for n in sizes:
            window = self._place(n, job=job, batch=batch)
            if window is not None:
                scored.append(
                    (self.predict_makespan(job, window, batch), n, window))
        if not scored:
            return None
        best = min(s[0] for s in scored)
        eligible = [s for s in scored
                    if s[0] <= best * (1.0 + self.policy.share_slack)]
        return min(eligible, key=lambda s: (s[1], s[0]))[2]

    # -- the lease lifecycle ------------------------------------------------

    def request(self, tenant: Union[str, Tenant],
                n: Optional[int] = None, *,
                clusters: Optional[Sequence[int]] = None,
                job: Any = None,
                batch: int = 1,
                queue: bool = False
                ) -> Union[ClusterLease, PendingLease]:
        """Admit a lease request and place it.

        Exactly one sizing input: ``n`` (place a window of that size),
        ``clusters`` (an explicit global window — rejected when it
        overlaps a live lease), or ``job`` alone (the model picks the
        slice size for ``batch`` instances).  When no placement fits
        and ``policy.preemption`` is armed, the overload ladder runs
        (compact → shrink elastic floors → degrade to a smaller pow2 at
        model-equal makespan → revoke lower-priority leases) before the
        request queues or sheds.  With no placement, raises
        :class:`LeaseUnavailable` — or, with ``queue=True``, returns a
        :class:`PendingLease` granted in weighted-aging priority order
        as capacity frees, unless admission control sheds the request
        with a typed :class:`Overloaded` (queue at ``max_queue_depth``,
        or the contention model predicts the tenant's ``slo`` would be
        violated).
        """
        tenant = (tenant if isinstance(tenant, Tenant)
                  else self._tenants.get(tenant, Tenant(tenant)))
        self._tenants[tenant.name] = tenant
        if clusters is not None and n is not None:
            raise ValueError("give n or clusters, not both")
        if clusters is not None:
            window = tuple(sorted(int(c) for c in clusters))
            if not window:
                raise ValueError("empty cluster selection")
            if window != tuple(range(window[0], window[0] + len(window))):
                raise ValueError(
                    f"lease windows are contiguous; {window} is not")
            if window[-1] >= self.num_clusters or window[0] < 0:
                raise ValueError(
                    f"clusters {window} outside the "
                    f"{self.num_clusters}-cluster fabric")
            sick = [c for c in window if c in self._unhealthy]
            if sick:
                raise LeaseUnavailable(
                    f"clusters {sick} are marked unhealthy "
                    f"(fail_clusters); request a different window")
            taken = [c for c in window if c in self._owner]
            if taken:
                holders = sorted({self._leases[self._owner[c]].tenant
                                  for c in taken})
                if queue:
                    return self._enqueue(tenant, None, window, job,
                                         batch)
                raise LeaseUnavailable(
                    f"clusters {taken} already leased (by "
                    f"{', '.join(holders)})")
            return self._grant(tenant.name, window, job=job, batch=batch)
        if n is not None:
            if n < 1:
                raise ValueError(f"lease size must be >= 1, got {n}")
            if n > self.num_clusters:
                raise ValueError(
                    f"lease of {n} clusters exceeds the "
                    f"{self.num_clusters}-cluster fabric")
            window = self._place(n, job=job, batch=batch)
        elif job is not None:
            window = self._pick_slice(job, batch)
        else:
            raise ValueError("give one of n / clusters / job")
        if window is None and self.policy.preemption != "off":
            window = self._pressure_place(tenant, n, job, batch)
            if window is not None:
                lease = self._grant(tenant.name, window, job=job,
                                    batch=batch)
                # preempted victims / queued entries take what's left
                self._admit_pending()
                return lease
        if window is None:
            if queue:
                return self._enqueue(tenant, n, None, job, batch)
            raise LeaseUnavailable(
                f"no contiguous window of "
                f"{n if n is not None else 'model-sized'} free clusters "
                f"(free: {self.free_clusters()})")
        return self._grant(tenant.name, window, job=job, batch=batch)

    # -- admission control ---------------------------------------------------

    def predict_retry_after(self, job: Any = None, batch: int = 1) -> float:
        """Model-predicted virtual cycles until fabric capacity next
        frees: the smallest grant-time predicted makespan among live
        leases (the first lease the §6 model expects to complete).
        Carried on :class:`Overloaded` so shed tenants know the
        earliest re-submit worth making."""
        etas = [self._eta[i] for i in self._leases if i in self._eta]
        return min(etas, default=0.0)

    def _admission_gate(self, tenant: Tenant, n: Optional[int],
                        job: Any, batch: int) -> None:
        """Shed (typed ``Overloaded``) instead of queueing when the
        queue is at depth or the contention model predicts the
        tenant's SLO cannot be met: predicted queue wait (smallest
        live-lease ETA) plus the request's own predicted makespan on a
        hypothetical freed window must fit inside ``tenant.slo``."""
        pol = self.policy
        if (pol.max_queue_depth is not None
                and len(self._pending) >= pol.max_queue_depth):
            self._health.overloaded += 1
            raise Overloaded(
                f"pending queue at max_queue_depth={pol.max_queue_depth}; "
                f"request shed",
                retry_after_cycles=self.predict_retry_after(job, batch))
        if tenant.slo is None:
            return
        wait = self.predict_retry_after(job, batch)
        own = 0.0
        if job is not None:
            size = n if n is not None else 1
            hypothetical = tuple(range(min(size, self.num_clusters)))
            own = self.predict_makespan(job, hypothetical, batch)
        if wait + own > tenant.slo:
            self._health.overloaded += 1
            raise Overloaded(
                f"tenant {tenant.name!r} slo={tenant.slo:.0f} cycles < "
                f"predicted wait {wait:.0f} + makespan {own:.0f}; "
                f"request shed",
                retry_after_cycles=wait)

    def _enqueue(self, tenant: Tenant, n: Optional[int],
                 clusters: Optional[Tuple[int, ...]], job: Any,
                 batch: int) -> PendingLease:
        self._admission_gate(tenant, n if n is not None else
                             (len(clusters) if clusters else None),
                             job, batch)
        pend = PendingLease(tenant.name, n, clusters, job, batch)
        pend.seq = next(self._next_seq)
        self._pending.append(pend)
        return pend

    def cancel(self, pending: PendingLease) -> None:
        """Withdraw a queued request.  Without this a dead tenant's
        entry pins the queue (and, once aged, reserves the fabric)
        forever.  Raises :class:`LeaseError` if the request was already
        granted (release the lease instead), already cancelled, or was
        never queued here."""
        if pending.ready:
            raise LeaseError(
                f"pending request for tenant {pending.tenant!r} was "
                "already granted; release the lease instead")
        if pending.cancelled or pending not in self._pending:
            raise LeaseError(
                f"pending request for tenant {pending.tenant!r} is not "
                "queued on this scheduler")
        self._pending.remove(pending)
        pending.cancelled = True
        # a cancelled aged head may have been reserving the fabric
        self._admit_pending()

    def _grant(self, tenant: str, window: Tuple[int, ...], *,
               job: Any = None, batch: int = 1,
               lease_id: Optional[int] = None) -> ClusterLease:
        lease = ClusterLease(
            lease_id if lease_id is not None else next(self._next_id),
            tenant, window, scheduler=self)
        s = _san.active()
        if s is not None:
            s.lease_grant(lease.lease_id, tuple(window), self._owner)
        for c in window:
            self._owner[c] = lease.lease_id
        self._leases[lease.lease_id] = lease
        self._grant_info[lease.lease_id] = (job, batch)
        if job is not None:
            self._eta[lease.lease_id] = self.predict_makespan(
                job, window, batch)
        else:
            self._eta[lease.lease_id] = self.placement_cost(window)
        return lease

    def _forget(self, lease_id: int) -> None:
        self._leases.pop(lease_id, None)
        self._grant_info.pop(lease_id, None)
        self._eta.pop(lease_id, None)
        self._elastic.pop(lease_id, None)

    def release(self, lease: ClusterLease) -> None:
        """Return the lease's clusters and grant queued requests."""
        current = self._current(lease)
        if current is None:
            raise LeaseError(f"lease {lease.lease_id} is not active")
        if current is not lease and current != lease:
            raise LeaseError(
                f"stale lease object for id {lease.lease_id} (it was "
                "resized; release the current one)")
        for c in current.clusters:
            self._owner.pop(c, None)
        self._forget(lease.lease_id)
        self._admit_pending()

    def _rank(self, pend: PendingLease) -> Tuple[int, float, int]:
        """Grant order: priority class desc, aged fair-share weight
        desc (``weight × (1 + skipped)`` — every bypassing backfill
        grant raises a blocked entry's effective weight), FIFO last."""
        ten = self._tenants.get(pend.tenant, Tenant(pend.tenant))
        return (-ten.priority, -ten.weight * (1.0 + pend.skipped), pend.seq)

    def _try_place(self, pend: PendingLease) -> Optional[Tuple[int, ...]]:
        if pend.clusters is not None:
            if any(c in self._owner or c in self._unhealthy
                   for c in pend.clusters):
                return None
            return pend.clusters
        if pend.n is not None:
            return self._place(pend.n, job=pend.job, batch=pend.batch)
        return self._pick_slice(pend.job, pend.batch)

    def _admit_pending(self) -> None:
        """Grant queued requests in weighted-aging priority order.

        Candidates are ranked by :meth:`_rank` and re-ranked after every
        grant (each grant changes the placement state).  A grant that
        lands *behind* a blocked higher-ranked entry is backfill: it
        ages the blocked entry (``skipped += 1``).  Once the top blocked
        entry has been bypassed ``policy.aging_grants`` times it
        reserves the fabric — no further backfill is granted past it,
        so freed capacity accrues until the starved request fits.  This
        bounds head-of-line starvation at ``aging_grants`` bypasses
        (regression-tested in ``tests/test_fabric.py``).
        """
        if self._hold_admit:
            return
        while True:
            for p in list(self._pending):
                if p.ready:
                    self._pending.remove(p)
            queue = sorted(self._pending, key=self._rank)
            if not queue:
                return
            blocked: List[PendingLease] = []
            granted = None
            for pend in queue:
                if (blocked
                        and blocked[0].skipped >= self.policy.aging_grants):
                    break           # head reservation: stop backfilling
                window = self._try_place(pend)
                if window is None:
                    blocked.append(pend)
                    continue
                granted = pend
                lease = self._grant(pend.tenant, window, job=pend.job,
                                    batch=pend.batch,
                                    lease_id=pend.resume_id)
                self._pending.remove(pend)
                for b in blocked:
                    b.skipped += 1
                if pend.resume_id is not None:
                    sess = self._bound_session(lease.lease_id)
                    if sess is not None:
                        self._health.restaged_operands += sess._resume(lease)
                pend.lease = lease
                break
            if granted is None:
                return

    def resize(self, lease: ClusterLease, n: int) -> ClusterLease:
        """Elastic grow/shrink — the serve tenant's burst mechanism.

        Shrinking keeps the window's start (trailing clusters return to
        the pool and queued requests are granted).  Growing extends the
        window in place when adjacent clusters are free (right first,
        then left), relocating to a fresh window only when it cannot —
        callers keying state by ``lease.clusters`` (e.g. a serve tenant's
        per-mesh engines) keep their warm state across a burst cycle.
        """
        current = self._current(lease)
        if current is None or (current is not lease and current != lease):
            raise LeaseError(
                f"lease {lease.lease_id} is not the scheduler's current "
                "grant (released or resized)")
        if n < 1:
            raise ValueError(f"lease size must be >= 1, got {n}")
        if n > self.num_clusters:
            raise ValueError(
                f"lease of {n} clusters exceeds the "
                f"{self.num_clusters}-cluster fabric")
        old = current.clusters
        if n == len(old):
            return current
        if n < len(old):
            window = old[:n]
            dropped = old[n:]
            replaced = dataclasses.replace(current, clusters=window)
            self._leases[current.lease_id] = replaced
            for c in dropped:
                self._owner.pop(c, None)
            self._admit_pending()
            return replaced
        grow = n - len(old)
        right = tuple(range(old[-1] + 1, old[-1] + 1 + grow))
        left = tuple(range(old[0] - grow, old[0]))
        if all(0 <= c < self.num_clusters and c not in self._owner
               and c not in self._unhealthy for c in right):
            window = old + right
        elif all(0 <= c < self.num_clusters and c not in self._owner
                 and c not in self._unhealthy for c in left):
            window = left + old
        else:
            # cannot extend in place: relocate (a fresh window scored by
            # the placement model, ignoring our own current holding)
            for c in old:
                self._owner.pop(c, None)
            window_opt = self._place(n)
            if window_opt is None and self.policy.preemption != "off":
                # the overload ladder may free room for the grown window
                # (a serve burst outranking offload churn); our own
                # holding stays out of the pool and off the victim list
                ten = self._tenants.get(current.tenant,
                                        Tenant(current.tenant))
                job, batch = self._grant_info.get(current.lease_id,
                                                  (None, 1))
                window_opt = self._pressure_place(
                    ten, n, job, batch, exclude={current.lease_id},
                    degrade=False)
            if window_opt is None:
                for c in old:           # roll back
                    self._owner[c] = current.lease_id
                raise LeaseUnavailable(
                    f"cannot grow lease {current.lease_id} to {n} "
                    f"clusters (free: {self.free_clusters()})")
            window = window_opt
        for c in old:
            self._owner.pop(c, None)
        replaced = dataclasses.replace(current, clusters=tuple(window))
        for c in replaced.clusters:
            self._owner[c] = replaced.lease_id
        self._leases[replaced.lease_id] = replaced
        # a relocation freed the old window: queued requests may fit now
        self._admit_pending()
        return replaced

    # -- preemption & the overload ladder -----------------------------------

    def drain_deadline(self, lease: ClusterLease) -> float:
        """§6-model drain deadline for revoking ``lease``: the predicted
        makespan of the work granted on it (job + staging + batch
        pipeline; nominal staging footprint when the grant named no
        job), times the retry-ladder deadline factor —
        ``deadline_factor × predict_makespan(job, window, batch)``.
        The victim's in-flight window must drain within this budget;
        jobs that miss it are the fault ladder's problem
        (:class:`repro.core.faults.CompletionTimeout`), not the
        preemption path's."""
        from repro.core.faults import deadline_cycles
        from repro.core.policy import RetryPolicy
        job, batch = self._grant_info.get(lease.lease_id, (None, 1))
        if job is not None:
            base = self.predict_makespan(job, lease.clusters, batch)
        else:
            base = self.placement_cost(lease.clusters)
        return deadline_cycles(base, RetryPolicy())

    def preempt(self, lease: ClusterLease, *,
                queue: bool = True) -> Optional[PendingLease]:
        """Revoke ``lease``'s window now; with ``queue=True`` re-queue
        it for re-placement under the same lease id.

        The bound session is *suspended*: its in-flight window drains
        under the model-predicted :meth:`drain_deadline`, resident
        operands are snapshotted on the host via the failover snapshot
        path, and its runtimes are dropped.  The window returns to the
        pool.  When the queued entry re-places, the snapshots are
        restaged through the lease's broadcast tree and the session
        resumes — outputs are bit-identical across the preemption (the
        ``preempt`` bench asserts it).  With ``queue=False`` the lease
        ends permanently and the bound session is closed (see
        :meth:`revoke`).  Returns the re-placement :class:`PendingLease`
        (possibly already ``ready`` — re-placed immediately elsewhere,
        which is exactly a compaction migration), or ``None`` with
        ``queue=False``.
        """
        current = self._current(lease)
        if current is None:
            raise LeaseError(f"lease {lease.lease_id} is not active")
        deadline = self.drain_deadline(current)
        sess = self._bound_session(current.lease_id)
        if sess is not None:
            sess._suspend(deadline)
        for c in current.clusters:
            self._owner.pop(c, None)
        job, batch = self._grant_info.get(current.lease_id, (None, 1))
        n = current.n
        self._forget(current.lease_id)
        self._health.preemptions += 1
        if not queue:
            self._sessions.pop(current.lease_id, None)
            if sess is not None:
                sess._close_revoked()
            self._admit_pending()
            return None
        pend = PendingLease(current.tenant, n, None, job, batch)
        pend.seq = next(self._next_seq)
        pend.resume_id = current.lease_id
        self._pending.append(pend)
        self._admit_pending()
        return pend

    def revoke(self, lease: ClusterLease) -> None:
        """Permanently revoke ``lease``: drain the victim's in-flight
        window under the model deadline, then end the lease without
        re-queueing (the bound session is closed and the window goes to
        the pool / pending queue)."""
        self.preempt(lease, queue=False)

    def compact(self, max_moves: Optional[int] = None) -> int:
        """Defragmenting compaction: migrate leases to the lowest free
        start (revoke→re-place through the bit-exact snapshot/restage
        path) until no lease can move left, so free capacity coalesces
        into large aligned windows instead of unusable gaps.  Returns
        the number of migrations."""
        moves = 0
        while max_moves is None or moves < max_moves:
            moved = False
            for lease in sorted(self.leases, key=lambda l: l.start):
                for c in lease.clusters:
                    self._owner.pop(c, None)
                windows = self._windows(lease.n)
                target = min((w for w in windows if w[0] < lease.start),
                             key=lambda w: w[0], default=None)
                if target is None:
                    for c in lease.clusters:
                        self._owner[c] = lease.lease_id
                    continue
                self._migrate(lease, target)
                moved = True
                moves += 1
                break
            if not moved:
                break
        return moves

    def _migrate(self, lease: ClusterLease,
                 window: Tuple[int, ...]) -> ClusterLease:
        """Move ``lease`` (owners already freed by the caller) onto
        ``window``, rebinding and restaging its session in place."""
        replaced = dataclasses.replace(lease, clusters=window)
        for c in window:
            self._owner[c] = replaced.lease_id
        self._leases[replaced.lease_id] = replaced
        self._health.migrations += 1
        sess = self._bound_session(replaced.lease_id)
        if sess is not None:
            self._health.restaged_operands += sess._rebind(replaced)
        return replaced

    def register_elastic(self, lease: ClusterLease, floor: int) -> None:
        """Mark ``lease`` as an elastic serve lease with a shrinkable
        ``floor`` — the overload ladder shrinks it back to (and under
        pressure, below) the floor before revoking anything."""
        if self._current(lease) is None:
            raise LeaseError(f"lease {lease.lease_id} is not active")
        self._elastic[lease.lease_id] = max(1, int(floor))

    def unregister_elastic(self, lease: ClusterLease) -> None:
        self._elastic.pop(lease.lease_id, None)

    def elastic_floor(self, lease: ClusterLease) -> Optional[int]:
        """The scheduler's current floor for an elastic lease (pressure
        may have shrunk it below what the tenant registered)."""
        return self._elastic.get(lease.lease_id)

    def _shrink_elastic(self, exclude: frozenset = frozenset()) -> bool:
        """Pressure rung 2: shrink elastic (serve) leases back to their
        floors; if every lease already sits at its floor, halve the
        floors themselves (never below 1) — graceful degradation of
        serving capacity before anything is revoked."""
        changed = False
        for lid, floor in sorted(self._elastic.items()):
            if lid in exclude:
                continue
            lease = self._leases.get(lid)
            if lease is None:
                self._elastic.pop(lid, None)
                continue
            if lease.n > floor:
                self.resize(lease, floor)
                changed = True
        if changed:
            return True
        for lid, floor in sorted(self._elastic.items()):
            if lid in exclude or floor <= 1:
                continue
            lease = self._leases.get(lid)
            if lease is None:
                continue
            self._elastic[lid] = floor // 2
            self._health.floor_shrinks += 1
            if lease.n > floor // 2:
                self.resize(lease, floor // 2)
            changed = True
        return changed

    def _preempt_for(self, tenant: Tenant, place: Any,
                     exclude: frozenset = frozenset()
                     ) -> Optional[Tuple[int, ...]]:
        """Pressure rung 4: revoke (drain + re-queue) leases whose
        tenants sit in a strictly lower priority class — lowest
        priority, lowest weight, youngest first — one at a time, until
        ``place()`` succeeds or the victims run out.  Elastic serve
        leases are never victims (rung 2 shrinks them instead)."""
        victims = [l for l in self.leases
                   if l.lease_id not in exclude
                   and l.lease_id not in self._elastic
                   and self._tenant_of(l).priority < tenant.priority]
        victims.sort(key=lambda l: (self._tenant_of(l).priority,
                                    self._tenant_of(l).weight,
                                    -l.lease_id))
        for victim in victims:
            self.preempt(victim)
            window = place()
            if window is not None:
                return window
        return None

    def _tenant_of(self, lease: ClusterLease) -> Tenant:
        return self._tenants.get(lease.tenant, Tenant(lease.tenant))

    def _pressure_place(self, tenant: Tenant, n: Optional[int], job: Any,
                        batch: int, *, exclude: frozenset = frozenset(),
                        degrade: bool = True
                        ) -> Optional[Tuple[int, ...]]:
        """The overload ladder, run when a request cannot place under a
        ``preemption`` policy.  Rungs, least disruptive first; each is
        followed by a placement retry:

        1. **compact** — defragment so existing free capacity coalesces;
        2. **shrink elastic floors** — serve tenants give back burst
           room, then halve their floors;
        3. **degrade the request** — a smaller power-of-two window whose
           predicted makespan is model-equal (within ``share_slack``) to
           the full-size ask;
        4. **revoke lower-priority leases** — drain, snapshot, re-queue.

        Grants to the pending queue are held while the ladder runs so
        freed capacity goes to the requester first; the caller admits
        the queue right after granting."""
        def place() -> Optional[Tuple[int, ...]]:
            if n is not None:
                return self._place(n, job=job, batch=batch)
            return self._pick_slice(job, batch)

        self._hold_admit = True
        try:
            if self.compact():
                window = place()
                if window is not None:
                    return window
            if self._shrink_elastic(exclude):
                window = place()
                if window is not None:
                    return window
            if degrade and n is not None and job is not None and n > 1:
                ref = self.predict_makespan(
                    job, tuple(range(min(n, self.num_clusters))), batch)
                m = 1 << (n.bit_length() - 1)
                if m == n:
                    m //= 2
                while m >= 1:
                    window = self._place(m, job=job, batch=batch)
                    if (window is not None
                            and self.predict_makespan(job, window, batch)
                            <= ref * (1.0 + self.policy.share_slack)):
                        self._health.degraded_grants += 1
                        return window
                    m //= 2
            return self._preempt_for(tenant, place, exclude)
        finally:
            self._hold_admit = False

    # -- failure handling ---------------------------------------------------

    def fail_clusters(self, clusters: Sequence[int]
                      ) -> Tuple[ClusterLease, ...]:
        """Mark clusters unhealthy and fail over every affected lease.

        Unhealthy clusters leave the placement pool (free runs, resize
        growth, explicit windows) until :meth:`restore_clusters`.  Each
        lease that intersects the newly failed set is drained and
        re-placed on a model-scored healthy window of equal size —
        bound sessions are rebound in place and their resident operands
        re-staged through the broadcast tree from the root host
        snapshots.  When no equal-size healthy window exists the lease
        *degrades*: the largest healthy power-of-two window that fits
        (counted in :meth:`health`); with no healthy window at all the
        lease is lost and its session closed.  Returns the replacement
        leases.
        """
        bad = {int(c) for c in clusters}
        out = [c for c in bad if not (0 <= c < self.num_clusters)]
        if out:
            raise ValueError(
                f"clusters {sorted(out)} outside the "
                f"{self.num_clusters}-cluster fabric")
        newly = bad - self._unhealthy
        self._unhealthy |= newly
        self._health.failed_clusters += len(newly)
        affected = [lease for lease in self.leases
                    if set(lease.clusters) & newly]
        replaced = []
        for lease in affected:
            new_lease = self._failover(lease)
            if new_lease is not None:
                replaced.append(new_lease)
        self._admit_pending()
        return tuple(replaced)

    def restore_clusters(self, clusters: Sequence[int]) -> None:
        """Return repaired clusters to the placement pool (queued
        requests may be granted immediately)."""
        self._unhealthy -= {int(c) for c in clusters}
        self._admit_pending()

    def _failover(self, lease: ClusterLease) -> Optional[ClusterLease]:
        """Re-place one lease off the unhealthy set, shrinking if needed."""
        for c in lease.clusters:
            self._owner.pop(c, None)
        n = lease.n
        window = self._place(n)
        degraded = False
        while window is None and n > 1:
            # graceful degradation: the largest pow2 healthy window left
            n //= 2
            window = self._place(n)
            degraded = window is not None
        sess = self._bound_session(lease.lease_id)
        if window is None:
            self._forget(lease.lease_id)
            self._sessions.pop(lease.lease_id, None)
            self._health.lost_leases += 1
            if sess is not None:
                sess._rebind(None)
            return None
        replaced = dataclasses.replace(lease, clusters=window)
        for c in window:
            self._owner[c] = replaced.lease_id
        self._leases[replaced.lease_id] = replaced
        self._health.failovers += 1
        if degraded:
            self._health.degradations += 1
        if sess is not None:
            self._health.restaged_operands += sess._rebind(replaced)
        return replaced

    # -- session glue -------------------------------------------------------

    def _bind_session(self, lease: ClusterLease, session: Any) -> None:
        """Register the session owning ``lease`` for failover callbacks
        (held weakly — an abandoned session never pins the fabric)."""
        self._sessions[lease.lease_id] = weakref.ref(session)

    def _unbind_session(self, lease: ClusterLease) -> None:
        self._sessions.pop(lease.lease_id, None)

    def _bound_session(self, lease_id: int) -> Any:
        ref = self._sessions.get(lease_id)
        return ref() if ref is not None else None

    def session(self, tenant: Union[str, Tenant],
                n: Optional[int] = None, *,
                clusters: Optional[Sequence[int]] = None,
                job: Any = None,
                batch: int = 1,
                **session_kwargs: Any) -> Any:
        """Lease and open a :class:`repro.core.session.Session` on it —
        the one-call tenant entry point (``session.close()`` releases
        the lease)."""
        lease = self.request(tenant, n, clusters=clusters, job=job,
                             batch=batch)
        from repro.core.session import Session
        return Session(lease=lease, params=self.params, **session_kwargs)

    def submit_graph(self, nodes: Sequence[Any], *,
                     policy: Any = None) -> Any:
        """Dispatch a dependency graph spanning this fabric's leases.

        Each node names the session (and thereby the lease window) it
        dispatches through via ``GraphNode.session`` — typically one
        session per lease from :meth:`session`; nodes leaving it unset
        run on the first named session.  Delegates to
        :meth:`Session.submit_graph <repro.core.session.Session.submit_graph>`
        on that driver, which issues independent sub-DAGs concurrently
        across the leases' in-flight windows and forwards producer
        results device-to-device between their fabric windows (the
        cross-lease reshard counted per edge in
        ``GraphHandle.forwarded``).
        """
        nodes = list(nodes)
        if not nodes:
            raise GraphError("empty graph")
        driver = next((nd.session for nd in nodes
                       if getattr(nd, "session", None) is not None), None)
        if driver is None:
            raise GraphError(
                "a fabric-level graph names at least one node's session= "
                "(open one per lease with FabricScheduler.session)")
        return driver.submit_graph(nodes, policy=policy)
