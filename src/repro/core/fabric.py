"""Fabric scheduler — multi-tenant cluster leases over the offload mesh.

The paper's measurements assume one host job owns the whole 200+-core
fabric, but its own scaling data argues against that as an operating
point: offload overheads grow with n while fine-grained jobs stop
profiting from extra clusters early (fig. 7 / §5.3), so a small job on
the full mesh wastes most of it.  ESP-style SoC research treats
accelerator tiles as *schedulable resources*, and the companion offload
work (arXiv:2404.01908) chooses offload modes from a cost model — this
module applies both ideas to the fabric itself:

* :class:`ClusterLease` — ownership of a contiguous cluster window.
  Sessions bind a lease instead of the global mesh; disjoint leases run
  concurrently and bit-identically to sequential full-mesh runs (the
  sub-mesh, shardings, and compiled programs depend only on the lease's
  device window — asserted in ``tests/test_fabric.py``).  Aligned
  power-of-two windows encode as ONE multicast request
  (:func:`repro.core.multicast.encode_contiguous_window`), so the
  paper's O(1) wakeup and the PR-3 fan-out tree stay legal per lease.
* :class:`FabricScheduler` — admits, places, queues, and resizes leases.
  Placement and slice sizing are *model-driven*: candidate windows are
  scored by the §6 cost model (dispatch + staging + compute via
  ``repro.core.session.estimate`` and the quadrant-aware
  ``simulate_staging``), so a lease lands where the predicted makespan
  is smallest — e.g. inside one quadrant rather than straddling two.
* :class:`Tenant` / :class:`SchedulerPolicy` — the typed vocabulary:
  resident ``SERVE`` tenants hold a floor lease and burst between decode
  batches (``resize``), bursty ``OFFLOAD`` tenants lease for a job
  stream and release.

The multi-tenant *contention* these placements imply (every tenant's
dispatch and resume serializes on the one host core) is modeled by
:func:`repro.core.simulator.simulate_fabric`; the ``scheduler`` bench
suite validates utilization, placement regret vs. exhaustive search,
and the closed-form makespan prediction against it.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import weakref
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import broadcast as bc
from repro.core import multicast as mc
from repro.core import simulator
from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.policy import TenantKind

#: replicated-operand footprint assumed when a lease request names no job —
#: placement still prefers quadrant-local windows over straddling ones
NOMINAL_STAGE_BYTES = 64 << 10


class LeaseError(RuntimeError):
    """A lease operation on released/stale/foreign state."""


class LeaseUnavailable(LeaseError):
    """No placement satisfies the request right now (queueable)."""


@dataclasses.dataclass
class FabricHealth:
    """Scheduler-side recovery counters (the fabric analogue of
    :class:`repro.core.faults.SessionHealth`)."""

    failed_clusters: int = 0     # clusters ever marked unhealthy
    failovers: int = 0           # leases re-placed onto healthy windows
    degradations: int = 0        # failovers that had to shrink the lease
    lost_leases: int = 0         # leases with no healthy window at all
    restaged_operands: int = 0   # resident operands re-staged on failover

    def snapshot(self) -> "FabricHealth":
        return dataclasses.replace(self)


@dataclasses.dataclass(frozen=True)
class Tenant:
    """A fabric tenant, to the scheduler's admission model."""

    name: str
    kind: TenantKind = TenantKind.OFFLOAD
    weight: float = 1.0          # informational fair-share weight

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        object.__setattr__(self, "kind", TenantKind(self.kind))


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """How the scheduler places and sizes leases.

    * ``placement`` — ``"model"`` scores every feasible contiguous window
      by the predicted staging cost of the request's operand footprint
      (quadrant-aware, ties to the lowest start); ``"first_fit"`` takes
      the lowest free window unscored.
    * ``align`` — prefer windows whose start is aligned to the largest
      power of two in the lease size, so the window encodes as a single
      multicast request and buddy-style packing limits fragmentation.
      Falls back to unaligned windows when no aligned one is free.
    * ``share_slack`` — when the model sizes a slice (``n=None`` with a
      job), any smaller candidate within ``1 + share_slack`` of the best
      predicted makespan wins, leaving head-room for co-tenants.
    """

    placement: str = "model"
    align: bool = True
    share_slack: float = 0.05

    def __post_init__(self) -> None:
        if self.placement not in ("model", "first_fit"):
            raise ValueError(
                f"placement {self.placement!r} not in ('model', 'first_fit')")
        if self.share_slack < 0:
            raise ValueError(
                f"share_slack must be >= 0, got {self.share_slack}")


@dataclasses.dataclass(frozen=True)
class ClusterLease:
    """Ownership of a contiguous cluster window of the fabric.

    The window is expressed in *global* cluster ids — they key dispatch
    plans, drive quadrant-aware staging trees, and make concurrent
    sessions on disjoint leases bit-equal to sequential full-mesh runs
    on the same selections.
    """

    lease_id: int
    tenant: str
    clusters: Tuple[int, ...]
    scheduler: Optional["FabricScheduler"] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self) -> None:
        ids = tuple(int(c) for c in self.clusters)
        if not ids:
            raise ValueError("a lease must cover at least one cluster")
        if ids != tuple(sorted(set(ids))) or ids[0] < 0:
            raise ValueError(
                f"lease clusters must be sorted, unique, non-negative "
                f"ids; got {ids}")
        object.__setattr__(self, "clusters", ids)

    @property
    def n(self) -> int:
        return len(self.clusters)

    @property
    def start(self) -> int:
        return self.clusters[0]

    @property
    def active(self) -> bool:
        """True while this exact lease is the scheduler's current grant."""
        if self.scheduler is None:
            return True          # a synthesized whole-mesh descriptor
        return self.scheduler._current(self) is self

    def requests(self) -> List[mc.MulticastRequest]:
        """The multicast cover of this lease's cluster set — ONE request
        when the window is a size-aligned power-of-two block (the
        legality the scheduler's aligned placement preserves).  Encodes
        the *actual* set, so a synthesized lease over a non-contiguous
        runtime window still covers exactly its clusters (with more
        requests)."""
        num = (self.scheduler.num_clusters if self.scheduler is not None
               else max(mc.NUM_CLUSTERS, self.clusters[-1] + 1))
        return mc.encode_cluster_selection_multi(self.clusters, num)

    def tree(self, clusters_per_quadrant: int = mc.CLUSTERS_PER_QUADRANT
             ) -> bc.BroadcastTree:
        """The lease's quadrant-aware fan-out tree (PR-3 staging path)."""
        return bc.build_tree(self.clusters, clusters_per_quadrant)

    @property
    def devices(self) -> List[Any]:
        if self.scheduler is None:
            raise LeaseError("synthesized lease carries no devices")
        return self.scheduler.devices_for(self.clusters)

    def release(self) -> None:
        if self.scheduler is not None:
            self.scheduler.release(self)


class PendingLease:
    """A queued lease request; ``lease`` is set when the grant lands."""

    def __init__(self, tenant: str, n: Optional[int],
                 clusters: Optional[Tuple[int, ...]],
                 job: Any, batch: int):
        self.tenant = tenant
        self.n = n
        self.clusters = clusters
        self.job = job
        self.batch = batch
        self.lease: Optional[ClusterLease] = None

    @property
    def ready(self) -> bool:
        return self.lease is not None


class FabricScheduler:
    """Admission, placement, and resizing of cluster leases.

    ``devices`` (one per cluster) makes leases executable — sessions and
    serve tenants bind them; with ``num_clusters`` alone the scheduler
    runs model-only (the bench suites' mode).  Placement candidates are
    contiguous free windows; the ``"model"`` policy scores them with the
    quadrant-aware staging model, slice sizing (``n=None`` + ``job``)
    minimizes the predicted makespan of the submitted batch.
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None, *,
                 num_clusters: Optional[int] = None,
                 params: OccamyParams = DEFAULT_PARAMS,
                 policy: SchedulerPolicy = SchedulerPolicy()):
        if devices is None and num_clusters is None:
            import jax
            devices = jax.devices()
        self._devices = list(devices) if devices is not None else None
        if num_clusters is None:
            num_clusters = len(self._devices)
        elif self._devices is not None and num_clusters != len(self._devices):
            raise ValueError(
                f"num_clusters={num_clusters} != {len(self._devices)} devices")
        if num_clusters < 1:
            raise ValueError(f"num_clusters must be >= 1, got {num_clusters}")
        self.num_clusters = int(num_clusters)
        self.params = params
        self.policy = policy
        self._owner: Dict[int, int] = {}          # cluster -> lease_id
        self._leases: Dict[int, ClusterLease] = {}
        self._tenants: Dict[str, Tenant] = {}
        self._pending: Deque[PendingLease] = collections.deque()
        self._next_id = itertools.count(1)
        self._unhealthy: set = set()              # failed global cluster ids
        self._health = FabricHealth()
        # lease_id -> weakref to the bound Session (failover callback)
        self._sessions: Dict[int, Any] = {}

    # -- introspection ------------------------------------------------------

    @property
    def leases(self) -> Tuple[ClusterLease, ...]:
        return tuple(self._leases[i] for i in sorted(self._leases))

    @property
    def pending(self) -> Tuple[PendingLease, ...]:
        return tuple(self._pending)

    def free_clusters(self) -> Tuple[int, ...]:
        return tuple(c for c in range(self.num_clusters)
                     if c not in self._owner and c not in self._unhealthy)

    def unhealthy_clusters(self) -> Tuple[int, ...]:
        return tuple(sorted(self._unhealthy))

    def health(self) -> FabricHealth:
        """A snapshot of the scheduler's recovery counters."""
        return self._health.snapshot()

    def current_lease(self, lease: ClusterLease) -> Optional[ClusterLease]:
        """The scheduler's current grant for ``lease``'s id (the lease
        object a failover or resize replaced it with), or ``None`` when
        the lease is gone — holders refresh stale references through
        this instead of keying scheduler calls on a dead object."""
        return self._leases.get(lease.lease_id)

    def tenant(self, name: str) -> Optional[Tenant]:
        return self._tenants.get(name)

    def devices_for(self, clusters: Sequence[int]) -> List[Any]:
        if self._devices is None:
            raise LeaseError(
                "model-only scheduler (constructed with num_clusters, no "
                "devices) cannot back executable leases")
        return [self._devices[c] for c in clusters]

    def _current(self, lease: ClusterLease) -> Optional[ClusterLease]:
        return self._leases.get(lease.lease_id)

    # -- placement ----------------------------------------------------------

    def _free_runs(self) -> List[Tuple[int, int]]:
        """Contiguous free runs as (start, length), ascending."""
        runs: List[Tuple[int, int]] = []
        start = None
        for c in range(self.num_clusters + 1):
            free = (c < self.num_clusters and c not in self._owner
                    and c not in self._unhealthy)
            if free and start is None:
                start = c
            elif not free and start is not None:
                runs.append((start, c - start))
                start = None
        return runs

    def _windows(self, n: int) -> List[Tuple[int, ...]]:
        """Feasible contiguous windows of size ``n``, aligned-first."""
        all_starts = [s + k for s, length in self._free_runs()
                      for k in range(length - n + 1)]
        if not all_starts:
            return []
        starts = all_starts
        if self.policy.align:
            align = 1 << (n.bit_length() - 1)     # largest pow2 <= n
            aligned = [s for s in all_starts if s % align == 0]
            starts = aligned or all_starts
        return [tuple(range(s, s + n)) for s in starts]

    def placement_cost(self, clusters: Sequence[int],
                       stage_bytes: int = NOMINAL_STAGE_BYTES) -> float:
        """Predicted staging cycles of one replicated operand on this
        window — the placement-sensitive model term (quadrant-aware tree
        legs; windows inside one quadrant beat straddling ones)."""
        return simulator.simulate_staging(
            max(1, stage_bytes), list(clusters), "tree", self.params)

    def _stage_bytes(self, job: Any) -> int:
        if job is None:
            return NOMINAL_STAGE_BYTES
        from repro.core.session import Planner
        return max(1, Planner(self.params).replicated_bytes(job))

    def predict_makespan(self, job: Any, clusters: Sequence[int],
                         batch: int = 1) -> float:
        """§6 model of a batch of ``job`` on this window: first launch
        end-to-end plus the amortized per-job pipeline period for the
        rest (dispatch + staging + compute, placement-aware)."""
        from repro.core.session import estimate
        est = estimate(job, clusters=list(clusters), batch=batch,
                       params=self.params)
        stage = est.staging_cycles.get("direct", 0.0)
        return est.job_cycles + stage + max(0, batch - 1) * est.per_job_cycles

    def _place(self, n: int, job: Any = None, batch: int = 1
               ) -> Optional[Tuple[int, ...]]:
        windows = self._windows(n)
        if not windows:
            return None
        if self.policy.placement == "first_fit":
            return min(windows, key=lambda w: w[0])
        nbytes = self._stage_bytes(job)
        return min(windows,
                   key=lambda w: (self.placement_cost(w, nbytes), w[0]))

    def _pick_slice(self, job: Any, batch: int) -> Optional[Tuple[int, ...]]:
        """Model-driven slice sizing: among power-of-two sizes that fit
        the free fabric, place each candidate and keep the smallest one
        whose predicted makespan is within ``1 + share_slack`` of the
        best — small enough to share, big enough to be near-optimal."""
        largest = max((length for _, length in self._free_runs()),
                      default=0)
        if largest < 1:
            return None
        sizes = [1 << k for k in range(largest.bit_length())
                 if (1 << k) <= largest]
        scored: List[Tuple[float, int, Tuple[int, ...]]] = []
        for n in sizes:
            window = self._place(n, job=job, batch=batch)
            if window is not None:
                scored.append(
                    (self.predict_makespan(job, window, batch), n, window))
        if not scored:
            return None
        best = min(s[0] for s in scored)
        eligible = [s for s in scored
                    if s[0] <= best * (1.0 + self.policy.share_slack)]
        return min(eligible, key=lambda s: (s[1], s[0]))[2]

    # -- the lease lifecycle ------------------------------------------------

    def request(self, tenant: Union[str, Tenant],
                n: Optional[int] = None, *,
                clusters: Optional[Sequence[int]] = None,
                job: Any = None,
                batch: int = 1,
                queue: bool = False
                ) -> Union[ClusterLease, PendingLease]:
        """Admit a lease request and place it.

        Exactly one sizing input: ``n`` (place a window of that size),
        ``clusters`` (an explicit global window — rejected when it
        overlaps a live lease), or ``job`` alone (the model picks the
        slice size for ``batch`` instances).  When no placement fits,
        raises :class:`LeaseUnavailable` — or, with ``queue=True``,
        returns a :class:`PendingLease` granted FIFO as capacity frees.
        """
        tenant = (tenant if isinstance(tenant, Tenant)
                  else self._tenants.get(tenant, Tenant(tenant)))
        self._tenants[tenant.name] = tenant
        if clusters is not None and n is not None:
            raise ValueError("give n or clusters, not both")
        if clusters is not None:
            window = tuple(sorted(int(c) for c in clusters))
            if not window:
                raise ValueError("empty cluster selection")
            if window != tuple(range(window[0], window[0] + len(window))):
                raise ValueError(
                    f"lease windows are contiguous; {window} is not")
            if window[-1] >= self.num_clusters or window[0] < 0:
                raise ValueError(
                    f"clusters {window} outside the "
                    f"{self.num_clusters}-cluster fabric")
            sick = [c for c in window if c in self._unhealthy]
            if sick:
                raise LeaseUnavailable(
                    f"clusters {sick} are marked unhealthy "
                    f"(fail_clusters); request a different window")
            taken = [c for c in window if c in self._owner]
            if taken:
                holders = sorted({self._leases[self._owner[c]].tenant
                                  for c in taken})
                if queue:
                    return self._enqueue(tenant.name, None, window, job,
                                         batch)
                raise LeaseUnavailable(
                    f"clusters {taken} already leased (by "
                    f"{', '.join(holders)})")
            return self._grant(tenant.name, window)
        if n is not None:
            if n < 1:
                raise ValueError(f"lease size must be >= 1, got {n}")
            if n > self.num_clusters:
                raise ValueError(
                    f"lease of {n} clusters exceeds the "
                    f"{self.num_clusters}-cluster fabric")
            window = self._place(n, job=job, batch=batch)
        elif job is not None:
            window = self._pick_slice(job, batch)
        else:
            raise ValueError("give one of n / clusters / job")
        if window is None:
            if queue:
                return self._enqueue(tenant.name, n, None, job, batch)
            raise LeaseUnavailable(
                f"no contiguous window of "
                f"{n if n is not None else 'model-sized'} free clusters "
                f"(free: {self.free_clusters()})")
        return self._grant(tenant.name, window)

    def _enqueue(self, tenant: str, n: Optional[int],
                 clusters: Optional[Tuple[int, ...]], job: Any,
                 batch: int) -> PendingLease:
        pend = PendingLease(tenant, n, clusters, job, batch)
        self._pending.append(pend)
        return pend

    def _grant(self, tenant: str, window: Tuple[int, ...]) -> ClusterLease:
        lease = ClusterLease(next(self._next_id), tenant, window,
                             scheduler=self)
        for c in window:
            self._owner[c] = lease.lease_id
        self._leases[lease.lease_id] = lease
        return lease

    def release(self, lease: ClusterLease) -> None:
        """Return the lease's clusters and grant queued requests FIFO."""
        current = self._current(lease)
        if current is None:
            raise LeaseError(f"lease {lease.lease_id} is not active")
        if current is not lease and current != lease:
            raise LeaseError(
                f"stale lease object for id {lease.lease_id} (it was "
                "resized; release the current one)")
        for c in current.clusters:
            self._owner.pop(c, None)
        del self._leases[lease.lease_id]
        self._admit_pending()

    def _admit_pending(self) -> None:
        """FIFO grant of queued requests, backfilling past blocked heads."""
        for pend in list(self._pending):
            if pend.ready:
                self._pending.remove(pend)
                continue
            if pend.clusters is not None:
                if any(c in self._owner for c in pend.clusters):
                    continue
                window: Optional[Tuple[int, ...]] = pend.clusters
            elif pend.n is not None:
                window = self._place(pend.n, job=pend.job, batch=pend.batch)
            else:
                window = self._pick_slice(pend.job, pend.batch)
            if window is None:
                continue
            pend.lease = self._grant(pend.tenant, window)
            self._pending.remove(pend)

    def resize(self, lease: ClusterLease, n: int) -> ClusterLease:
        """Elastic grow/shrink — the serve tenant's burst mechanism.

        Shrinking keeps the window's start (trailing clusters return to
        the pool and queued requests are granted).  Growing extends the
        window in place when adjacent clusters are free (right first,
        then left), relocating to a fresh window only when it cannot —
        callers keying state by ``lease.clusters`` (e.g. a serve tenant's
        per-mesh engines) keep their warm state across a burst cycle.
        """
        current = self._current(lease)
        if current is None or (current is not lease and current != lease):
            raise LeaseError(
                f"lease {lease.lease_id} is not the scheduler's current "
                "grant (released or resized)")
        if n < 1:
            raise ValueError(f"lease size must be >= 1, got {n}")
        if n > self.num_clusters:
            raise ValueError(
                f"lease of {n} clusters exceeds the "
                f"{self.num_clusters}-cluster fabric")
        old = current.clusters
        if n == len(old):
            return current
        if n < len(old):
            window = old[:n]
            dropped = old[n:]
            replaced = dataclasses.replace(current, clusters=window)
            self._leases[current.lease_id] = replaced
            for c in dropped:
                self._owner.pop(c, None)
            self._admit_pending()
            return replaced
        grow = n - len(old)
        right = tuple(range(old[-1] + 1, old[-1] + 1 + grow))
        left = tuple(range(old[0] - grow, old[0]))
        if all(0 <= c < self.num_clusters and c not in self._owner
               and c not in self._unhealthy for c in right):
            window = old + right
        elif all(0 <= c < self.num_clusters and c not in self._owner
                 and c not in self._unhealthy for c in left):
            window = left + old
        else:
            # cannot extend in place: relocate (a fresh window scored by
            # the placement model, ignoring our own current holding)
            for c in old:
                self._owner.pop(c, None)
            window_opt = self._place(n)
            if window_opt is None:
                for c in old:           # roll back
                    self._owner[c] = current.lease_id
                raise LeaseUnavailable(
                    f"cannot grow lease {current.lease_id} to {n} "
                    f"clusters (free: {self.free_clusters()})")
            window = window_opt
        for c in old:
            self._owner.pop(c, None)
        replaced = dataclasses.replace(current, clusters=tuple(window))
        for c in replaced.clusters:
            self._owner[c] = replaced.lease_id
        self._leases[replaced.lease_id] = replaced
        # a relocation freed the old window: queued requests may fit now
        self._admit_pending()
        return replaced

    # -- failure handling ---------------------------------------------------

    def fail_clusters(self, clusters: Sequence[int]
                      ) -> Tuple[ClusterLease, ...]:
        """Mark clusters unhealthy and fail over every affected lease.

        Unhealthy clusters leave the placement pool (free runs, resize
        growth, explicit windows) until :meth:`restore_clusters`.  Each
        lease that intersects the newly failed set is drained and
        re-placed on a model-scored healthy window of equal size —
        bound sessions are rebound in place and their resident operands
        re-staged through the broadcast tree from the root host
        snapshots.  When no equal-size healthy window exists the lease
        *degrades*: the largest healthy power-of-two window that fits
        (counted in :meth:`health`); with no healthy window at all the
        lease is lost and its session closed.  Returns the replacement
        leases.
        """
        bad = {int(c) for c in clusters}
        out = [c for c in bad if not (0 <= c < self.num_clusters)]
        if out:
            raise ValueError(
                f"clusters {sorted(out)} outside the "
                f"{self.num_clusters}-cluster fabric")
        newly = bad - self._unhealthy
        self._unhealthy |= newly
        self._health.failed_clusters += len(newly)
        affected = [lease for lease in self.leases
                    if set(lease.clusters) & newly]
        replaced = []
        for lease in affected:
            new_lease = self._failover(lease)
            if new_lease is not None:
                replaced.append(new_lease)
        self._admit_pending()
        return tuple(replaced)

    def restore_clusters(self, clusters: Sequence[int]) -> None:
        """Return repaired clusters to the placement pool (queued
        requests may be granted immediately)."""
        self._unhealthy -= {int(c) for c in clusters}
        self._admit_pending()

    def _failover(self, lease: ClusterLease) -> Optional[ClusterLease]:
        """Re-place one lease off the unhealthy set, shrinking if needed."""
        for c in lease.clusters:
            self._owner.pop(c, None)
        n = lease.n
        window = self._place(n)
        degraded = False
        while window is None and n > 1:
            # graceful degradation: the largest pow2 healthy window left
            n //= 2
            window = self._place(n)
            degraded = window is not None
        sess = self._bound_session(lease.lease_id)
        if window is None:
            del self._leases[lease.lease_id]
            self._sessions.pop(lease.lease_id, None)
            self._health.lost_leases += 1
            if sess is not None:
                sess._rebind(None)
            return None
        replaced = dataclasses.replace(lease, clusters=window)
        for c in window:
            self._owner[c] = replaced.lease_id
        self._leases[replaced.lease_id] = replaced
        self._health.failovers += 1
        if degraded:
            self._health.degradations += 1
        if sess is not None:
            self._health.restaged_operands += sess._rebind(replaced)
        return replaced

    # -- session glue -------------------------------------------------------

    def _bind_session(self, lease: ClusterLease, session: Any) -> None:
        """Register the session owning ``lease`` for failover callbacks
        (held weakly — an abandoned session never pins the fabric)."""
        self._sessions[lease.lease_id] = weakref.ref(session)

    def _unbind_session(self, lease: ClusterLease) -> None:
        self._sessions.pop(lease.lease_id, None)

    def _bound_session(self, lease_id: int) -> Any:
        ref = self._sessions.get(lease_id)
        return ref() if ref is not None else None

    def session(self, tenant: Union[str, Tenant],
                n: Optional[int] = None, *,
                clusters: Optional[Sequence[int]] = None,
                job: Any = None,
                batch: int = 1,
                **session_kwargs: Any) -> Any:
        """Lease and open a :class:`repro.core.session.Session` on it —
        the one-call tenant entry point (``session.close()`` releases
        the lease)."""
        lease = self.request(tenant, n, clusters=clusters, job=job,
                             batch=batch)
        from repro.core.session import Session
        return Session(lease=lease, params=self.params, **session_kwargs)
