"""The paper's contribution: multicast offload runtime, job completion unit,
cycle-accurate phase simulator, and the analytical offload-runtime model."""

from repro.core.completion import CompletionUnit
from repro.core.jobs import PAPER_JOBS, PaperJob, make_instances, stack_instances
from repro.core.model import (
    axpy_closed_form,
    atax_closed_form_paper,
    optimal_clusters,
    predict,
    predict_total,
    predict_total_v2,
    should_offload,
    validate,
)
from repro.core.multicast import (
    AddressMap,
    MulticastRequest,
    decode_cluster_selection,
    decode_match,
    encode_cluster_selection,
    encode_cluster_selection_multi,
)
from repro.core.offload import (
    DispatchPlan,
    FusedHandle,
    JobHandle,
    OffloadConfig,
    OffloadRuntime,
    PlanStats,
    count_collectives,
)
from repro.core.stream import OffloadStream
from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.phases import Phase, PhaseStats
from repro.core.simulator import JobSpec, SimResult, offload_overhead, simulate, speedups

__all__ = [
    "AddressMap", "CompletionUnit", "DEFAULT_PARAMS", "DispatchPlan",
    "FusedHandle", "JobHandle", "JobSpec",
    "MulticastRequest", "OccamyParams", "OffloadConfig", "OffloadRuntime",
    "OffloadStream", "PlanStats",
    "PAPER_JOBS", "PaperJob", "Phase", "PhaseStats", "SimResult",
    "atax_closed_form_paper", "axpy_closed_form", "count_collectives",
    "decode_cluster_selection", "decode_match", "encode_cluster_selection",
    "encode_cluster_selection_multi", "make_instances", "offload_overhead",
    "optimal_clusters",
    "predict", "predict_total", "predict_total_v2", "should_offload",
    "simulate", "speedups", "stack_instances", "validate",
]
