"""The paper's contribution: multicast offload runtime, job completion unit,
cycle-accurate phase simulator, and the analytical offload-runtime model."""

from repro.core.broadcast import (
    BroadcastTree,
    TreeStager,
    build_tree,
    depth_bound,
    place_pytree,
    tree_from_request,
)
from repro.core.completion import CompletionUnit
from repro.core.fabric import (
    ClusterLease,
    FabricHealth,
    FabricScheduler,
    LeaseError,
    LeaseUnavailable,
    SchedulerPolicy,
    Tenant,
)
from repro.core.faults import (
    CompletionTimeout,
    FaultError,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    SessionHealth,
    deadline_cycles,
    predict_recovery,
)
from repro.core.jobs import PAPER_JOBS, PaperJob, make_instances, stack_instances
from repro.core.model import (
    axpy_closed_form,
    atax_closed_form_paper,
    optimal_clusters,
    predict,
    predict_total,
    predict_total_v2,
    should_offload,
    validate,
)
from repro.core.multicast import (
    AddressMap,
    MulticastRequest,
    decode_cluster_selection,
    decode_match,
    encode_cluster_selection,
    encode_cluster_selection_multi,
)
from repro.core.offload import (
    DispatchPlan,
    FusedHandle,
    JobHandle,
    OffloadConfig,
    OffloadRuntime,
    PlanStats,
    count_collectives,
)
from repro.core.policy import (
    AUTO,
    Completion,
    InfoDist,
    OffloadPolicy,
    Residency,
    RetryPolicy,
    Staging,
    TenantKind,
)
from repro.core.session import (
    Estimate,
    Explain,
    PlanDecision,
    Planner,
    ReliableHandle,
    Session,
    SessionHandle,
    estimate,
    predict_staging,
)
from repro.core.stream import OffloadStream
from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.phases import Phase, PhaseStats
from repro.core.simulator import (
    FabricSimResult,
    JobSpec,
    SimResult,
    StagingCostModel,
    TenantWorkload,
    fabric_makespan_model,
    model_error,
    offload_overhead,
    simulate,
    simulate_fabric,
    simulate_staging,
    speedups,
    staging_model,
    staging_model_error,
)

__all__ = [
    "AUTO", "AddressMap", "BroadcastTree", "ClusterLease", "Completion",
    "CompletionTimeout", "CompletionUnit",
    "DEFAULT_PARAMS",
    "DispatchPlan", "Estimate", "Explain", "FabricHealth", "FabricScheduler",
    "FabricSimResult",
    "FaultError", "FaultInjector", "FaultKind", "FaultPlan", "FaultSpec",
    "FusedHandle", "InfoDist", "JobHandle", "JobSpec",
    "LeaseError", "LeaseUnavailable",
    "MulticastRequest", "OccamyParams", "OffloadConfig", "OffloadPolicy",
    "OffloadRuntime",
    "OffloadStream", "PlanDecision", "PlanStats", "Planner",
    "PAPER_JOBS", "PaperJob", "Phase", "PhaseStats", "ReliableHandle",
    "Residency", "RetryPolicy",
    "SchedulerPolicy",
    "Session", "SessionHandle", "SessionHealth", "SimResult",
    "Staging", "StagingCostModel", "Tenant", "TenantKind",
    "TenantWorkload", "TreeStager",
    "deadline_cycles", "predict_recovery",
    "fabric_makespan_model", "simulate_fabric",
    "atax_closed_form_paper", "axpy_closed_form", "count_collectives",
    "build_tree", "decode_cluster_selection", "decode_match",
    "depth_bound", "encode_cluster_selection",
    "encode_cluster_selection_multi", "estimate", "make_instances",
    "model_error",
    "offload_overhead", "place_pytree",
    "optimal_clusters",
    "predict", "predict_staging", "predict_total", "predict_total_v2",
    "should_offload",
    "simulate", "simulate_staging", "speedups", "stack_instances",
    "staging_model", "staging_model_error", "tree_from_request", "validate",
]
