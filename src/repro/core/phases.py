"""Offload phase taxonomy — paper §3.2 / §4.1, figure 3.

Every offloaded job decomposes into nine phases.  Phases C and D only exist in
the *baseline* implementation (the multicast extension eliminates them), and
phase H has two implementations (software central-counter barrier vs the job
completion unit).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List


class Phase(enum.Enum):
    A = "send_job_information"
    B = "wakeup"
    C = "retrieve_job_pointer"
    D = "retrieve_job_arguments"
    E = "retrieve_job_operands"
    F = "job_execution"
    G = "writeback_job_outputs"
    H = "notify_job_completion"
    I = "resume_operation_on_host"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name} ({self.value})"


#: Phases belonging to each fundamental offload task (paper fig. 3 brackets).
FUNDAMENTAL_TASKS: Dict[str, List[Phase]] = {
    "communicate_job_information": [Phase.A, Phase.C, Phase.D],
    "wakeup": [Phase.B],
    "communicate_job_operands": [Phase.E],
    "job_execution": [Phase.F],
    "communicate_job_results": [Phase.G],
    "notify_job_completion": [Phase.H, Phase.I],
}

#: Phases whose runtime is (nearly) independent of the offloaded job (§5.6).
JOB_INDEPENDENT_PHASES = (Phase.A, Phase.B, Phase.C, Phase.D, Phase.H, Phase.I)


@dataclasses.dataclass
class PhaseSpan:
    """One cluster's (or the host's) occupancy of a phase, in cycles."""

    phase: Phase
    cluster: int  # -1 for host-side phases (A, I)
    start: float
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class PhaseStats:
    """Aggregate of a phase across clusters — fig. 11's min/avg/max bands."""

    phase: Phase
    min: float
    avg: float
    max: float

    @staticmethod
    def of(phase: Phase, durations: List[float]) -> "PhaseStats":
        if not durations:
            return PhaseStats(phase, 0.0, 0.0, 0.0)
        return PhaseStats(
            phase,
            min(durations),
            sum(durations) / len(durations),
            max(durations),
        )
