"""The offload runtime — the paper's host-centric execution model on a JAX
device mesh.

``OffloadRuntime`` is the dispatch layer of this framework: it carries a job
(the paper's six kernels, or a training/serving step) onto a set of
accelerator "clusters" (devices of a 1-D mesh), reproducing the paper's two
implementations:

* ``baseline``  — job information is materialized on cluster 0 only and
  distributed hop-by-hop through a chain of ``collective-permute``s (the
  sequential P2P writes of §4.1, phases C/D), and completion is synchronized
  through the central-counter chain (§5.5 H).  The lowered HLO contains an
  O(n)-deep chain of collectives — the paper's O(n) offload critical path,
  structurally visible in ``compiled.as_text()``.
* ``multicast`` — job information is replicated (a single logical broadcast,
  XLA lowers it to an O(log n) tree), phases C/D vanish, and completion is a
  single fused ``psum`` (the job completion unit).  This is the paper's
  co-designed fast path and the default for every training/serving step in
  this framework.

Cluster selection uses the paper's address-mask multicast encoding (§4.2):
``select=MulticastRequest(...)`` picks any power-of-two subcube of clusters,
exactly like fig. 5; arbitrary sets fall back to a minimal multi-request
cover.  The selected clusters become a sub-mesh.

Completion is tracked host-side by the :class:`~repro.core.completion.
CompletionUnit` (fig. 6 semantics, multiple outstanding jobs by job ID), fed
by the device-side arrivals count that every offloaded program returns.

Dispatch fast path
------------------

The paper's thesis applies to this framework's *own* host-side critical
path: re-resolving the sub-mesh, re-deriving shardings, and re-``device_put``
-ing identical operands on every ``offload()`` is exactly the per-job
overhead §4 sets out to kill.  The runtime therefore caches a
:class:`DispatchPlan` per (job, cluster selection, operand shapes/dtypes):

* **plan reuse** — the resolved sub-mesh, the ``NamedSharding`` for every
  operand and for the job args, and the compiled program are computed once
  and reused; a warm dispatch performs zero sharding/compile work.
* **resident operands** — ``offload(job, "resident", ...)`` reuses the
  operand buffers staged by the previous dispatch (or by an explicit
  ``plan.stage(operands)``), skipping phase-E ``device_put`` entirely.
  ``plan.invalidate()`` drops residency explicitly; staging fresh operands
  through a normal ``offload(job, {...})`` call refreshes it implicitly.
* **job-args cache** — job args are tiny but re-uploaded on every seed-style
  dispatch; the plan keeps the last staged value and skips the upload when
  the host value is unchanged (exact ``array_equal`` check).
* **buffer donation** — ``OffloadConfig.donate_operands=True`` donates the
  operand buffers to XLA (phase-E buffers can back phase-G outputs).  A
  donated dispatch consumes the resident buffers; the plan keeps the host
  references and transparently re-stages on the next dispatch, so donation
  never corrupts reuse (it only trades residency for memory).
* **one-fetch completion** — ``JobHandle.wait()`` fetches result and
  arrivals in a single ``device_get`` and drains completion-unit causes
  out of order, so outstanding handles (up to the runtime's ``n_units``
  completion-unit copies, §4.3) can be waited on in any order.

Fused dispatch batching
-----------------------

The fast path shrinks the per-job overhead; it cannot remove the floor of
one host dispatch per job.  ``offload_fused(job, [ops_0, ..., ops_B-1])``
removes it by fusing B independent instances of the same job into **one**
XLA launch: operands and job args gain a leading batch axis, the kernel is
``vmap``-ed over it inside the sharded program, and any cross-cluster
reduction happens once on the batched array — so the HLO collective count
is independent of B while the fixed host dispatch cost is amortized to
~1/B per job.  This is the software analogue of the paper's O(1) multicast
wakeup (one doorbell wakes n clusters; here one dispatch launches B jobs),
applied to this framework's own host critical path.  Fused plans support
the same residency/donation semantics as single-job plans, and
``lowered_text(job, n, fuse=B)`` exposes the batched program's HLO for the
B-independence assertions.

Streaming
---------

:class:`repro.core.stream.OffloadStream` builds on two hooks here: slot
staging (``DispatchPlan.stage(operands, slot=k)`` uploads into a numbered
buffer slot without touching residency, so job k+1's phase-E transfer can
proceed while job k computes out of the other slot) and ``_launch`` (the
dispatch tail shared by ``offload``/``offload_fused``/the stream).

Hierarchical broadcast staging
------------------------------

Replicated operands used to be the last O(n) segment of the dispatch path:
``device_put`` against a replicated sharding moves the array over the host
link once *per cluster*.  ``DispatchPlan.stage(..., via="tree")`` instead
derives a quadrant-aware fan-out tree from the cluster selection
(:mod:`repro.core.broadcast`) and stages the operand with **one** host
upload to the tree root plus device-to-device copies along the tree — the
paper's multicast algebra lowered to a phase-E data path.  ``via=
"host_fanout"`` keeps the explicit O(n) sequential-upload baseline
measurable, and ``OffloadConfig.staging`` sets the per-runtime default.
``stats.h2d_bytes`` / ``stats.d2d_bytes`` account the logical link bytes so
the O(n) -> O(1) host-link claim is asserted by tests, not just timed; the
staging-cost model in :mod:`repro.core.simulator` (``staging_model`` /
``model_error``) closes the loop against the paper's §6 analytical
treatment.

``DispatchPlan.stats`` / ``OffloadRuntime.stats`` count device_puts, plan
hits/misses, resident hits, and staging bytes — the hooks the fast-path
tests and ``benchmarks/offload_wallclock.py`` assert against.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import sanitizer as _san
from repro.compat import shard_map
from repro.core import broadcast as bc
from repro.core import multicast as mc
from repro.core.completion import (
    CompletionUnit,
    central_counter_arrivals,
    completion_unit_arrivals,
)
from repro.core.faults import CompletionTimeout, FaultInjector
from repro.core.jobs import PaperJob, stack_instances
from repro.core.policy import (
    Completion, InfoDist, Residency, Staging, coerce_enum, warn_legacy,
)

AXIS = "clusters"

#: legacy sentinel accepted by ``offload(job, "resident", ...)`` — the
#: typed spelling is ``repro.core.policy.Residency.RESIDENT``
RESIDENT = "resident"


def _is_resident(operands: Any, legacy_surface: str) -> bool:
    """True when ``operands`` selects resident redispatch.

    Accepts the typed :class:`Residency` enum silently and the legacy
    ``"resident"`` string with a :class:`DeprecationWarning`; any other
    string (or ``Residency.FRESH``, which names no buffers) is an error.
    """
    if isinstance(operands, Residency):
        if operands is not Residency.RESIDENT:
            raise ValueError(
                f"{operands!r} is not a dispatchable operand mode; pass "
                "an operand dict or Residency.RESIDENT")
        return True
    if isinstance(operands, str):
        if operands != RESIDENT:
            raise ValueError(f"unknown operands mode {operands!r}")
        warn_legacy(f"{legacy_surface}(job, 'resident')",
                    f"{legacy_surface}(job, Residency.RESIDENT)")
        return True
    return False


#: valid phase-E staging strategies for replicated operands (see
#: ``DispatchPlan.stage``; the canonical set lives in ``repro.core.
#: broadcast``):
#:   "direct"       one ``device_put`` against the replicated sharding — the
#:                  substrate's native path (O(n) logical host-link bytes)
#:   "host_fanout"  explicit sequential per-device uploads, one outstanding
#:                  transfer at a time — the measurable O(n) host-link
#:                  baseline, mirroring the paper's serialized P2P writes
#:                  (CVA6's limited outstanding-write budget, §4.2)
#:   "tree"         hierarchical broadcast staging: ONE host upload to the
#:                  fan-out tree root, then device-to-device copies along
#:                  the quadrant-aware tree (``repro.core.broadcast``) —
#:                  O(1) host-link bytes
#:   "tree_reshard" tree semantics through the replicated-resharding fast
#:                  path (root upload + one resharding ``device_put``)
STAGING_MODES = bc.STAGING_MODES


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    """First-class framework feature: how jobs are dispatched (§4.2/§4.3).

    Every mode field is validated on construction (a typo like
    ``info_dist="mulicast"`` raises instead of silently misconfiguring
    the run) and coerced to its :mod:`repro.core.policy` enum; raw
    strings still work but raise :class:`DeprecationWarning` — the typed
    session surface (``repro.api.OffloadPolicy``) is the replacement.
    """

    info_dist: InfoDist = InfoDist.MULTICAST
    completion: Completion = Completion.UNIT
    donate_operands: bool = False
    staging: Staging = Staging.DIRECT  # default phase-E mode, see STAGING_MODES

    def __post_init__(self):
        coerce = object.__setattr__
        coerce(self, "info_dist",
               coerce_enum(InfoDist, self.info_dist, "info_dist",
                           warn_legacy=True))
        coerce(self, "completion",
               coerce_enum(Completion, self.completion, "completion",
                           warn_legacy=True))
        coerce(self, "staging",
               coerce_enum(Staging, self.staging, "staging",
                           warn_legacy=True))

    @staticmethod
    def baseline() -> "OffloadConfig":
        return OffloadConfig(info_dist=InfoDist.P2P_CHAIN,
                             completion=Completion.CENTRAL_COUNTER)

    @staticmethod
    def extended() -> "OffloadConfig":
        return OffloadConfig(info_dist=InfoDist.MULTICAST,
                             completion=Completion.UNIT)


@dataclasses.dataclass
class PlanStats:
    """Host-side dispatch-overhead counters (per plan / per runtime)."""

    device_puts: int = 0          # operand/arg buffers uploaded
    resident_hits: int = 0        # operands reused without any upload
    args_hits: int = 0            # job-args upload skipped (unchanged value)
    dispatches: int = 0           # XLA launches through this plan
    donation_restages: int = 0    # re-uploads forced by a donated dispatch
    fused_jobs: int = 0           # logical jobs carried by fused dispatches
    h2d_bytes: int = 0            # logical host-link bytes (see broadcast.py)
    d2d_bytes: int = 0            # logical device-to-device fan-out bytes
    tree_stages: int = 0          # operand/arg stagings routed via the tree
    d2h_bytes: int = 0            # result payload fetched to host by wait()
    forwards: int = 0             # operands forwarded from producer results
    forward_bytes: int = 0        # logical d2d bytes of those forwards
    renames: int = 0              # rename copies breaking WAR/WAW hazards

    def accumulate(self, other: "PlanStats") -> "PlanStats":
        """Add ``other``'s counters into this instance (returns self) —
        the one aggregation used by every stats rollup surface."""
        for f in dataclasses.fields(PlanStats):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self


class DonatedOperandError(RuntimeError):
    """A device buffer was reused after a donating dispatch consumed it.

    Donation (``OffloadConfig.donate_operands``) hands the operand
    buffers to XLA, which deletes them on launch.  Reusing one —
    re-staging it, forwarding it to a dependent job, or fetching a
    result whose buffer a later donating consumer swallowed — used to
    surface as an opaque substrate error deep inside ``device_put`` /
    ``device_get``.  This typed error names the operand and the remedy
    instead (restage from host, or let the graph dispatcher *rename* —
    copy — the buffer before the donating consumer).
    """

    #: stable diagnostic code (``repro.analysis.diagnostics.CODES``)
    code = "OFL003"

    def __init__(self, what: str):
        from repro.analysis.diagnostics import use_after_donate
        self.diagnostic = use_after_donate(what)
        super().__init__(
            f"{what} was deleted by a donating dispatch; restage it from "
            "the host copy (plan.resident_operands restores resident "
            "buffers automatically) or disable donate_operands for "
            "buffers that must stay readable")


def _check_live(value: Any, what: str) -> Any:
    """Raise the typed donation error for a deleted jax buffer."""
    if getattr(value, "is_deleted", None) is not None and value.is_deleted():
        raise DonatedOperandError(what)
    return value


def _nbytes_of(data: Any) -> int:
    """Host bytes of a fetched result (arrays or pytrees of them)."""
    return sum(int(getattr(leaf, "nbytes", 0))
               for leaf in jax.tree_util.tree_leaves(data))


@dataclasses.dataclass
class JobHandle:
    """An in-flight offloaded job (async dispatch = multiple outstanding)."""

    job_id: int
    result: Any                      # jax arrays (async until blocked on)
    arrivals: Any                    # device-side arrivals count
    n_clusters: int
    dispatched_at: float
    runtime: "OffloadRuntime"
    cluster_ids: Tuple[int, ...] = ()
    plan: Optional["DispatchPlan"] = None
    _data: Any = None
    _done: bool = False
    _retired: bool = False
    _fault: Optional[CompletionTimeout] = None

    def _complete(self, arrivals: int) -> None:
        """Feed the completion unit, resolving any injected fault."""
        inj = self.runtime.fault_injector
        lost = (inj.lost_arrivals(self.runtime, self.job_id)
                if inj is not None else 0)
        if lost:
            self.runtime.unit.arrive(self.job_id, arrivals - lost)
            missing = self.runtime.unit.cancel(self.job_id)
            self.result = self.arrivals = None
            self._fault = CompletionTimeout(self.job_id, missing,
                                            self.cluster_ids)
            raise self._fault
        self.runtime.unit.arrive(self.job_id, arrivals)
        self.runtime.unit.collect(self.job_id)
        self._retired = True

    def retire(self) -> None:
        """Collect *completion only*, leaving the result on the fabric.

        Fetches the arrivals scalar (a host-side doorbell read, not the
        result payload), feeds the completion unit, and frees this job's
        unit copy — ``stats.d2h_bytes`` does not grow.  The graph
        dispatcher retires intermediate nodes this way: their results are
        forwarded device-to-device to consumers and never fetched.
        Idempotent; ``wait()`` after ``retire()`` fetches only the data.
        """
        if self._fault is not None:
            raise self._fault
        if self._retired or self._done:
            return
        arrivals = jax.device_get(self.arrivals)
        self._complete(int(arrivals))
        self.arrivals = None

    def wait(self) -> Any:
        """Block until complete; feeds the completion unit and returns data.

        One blocking ``device_get`` fetches result and arrivals together,
        and completion causes are drained out of order through
        :meth:`CompletionUnit.collect` — handles may be waited on in any
        order relative to dispatch (the number of *outstanding* jobs is
        bounded by the runtime's ``n_units``, as in the paper's fig. 6).
        Idempotent: a second call returns the cached result without
        touching the device or the completion unit again.  The result
        payload's bytes are counted in the plan's ``stats.d2h_bytes`` —
        the counter proving graph intermediates never take this path.

        Under fault injection, a dispatch whose arrivals were dropped
        raises :class:`~repro.core.faults.CompletionTimeout` instead:
        the partial arrivals are fed to the unit first (so
        ``outstanding()`` shows the missing count — the actionable
        signal) and the stuck register is cancelled so the unit is
        immediately reusable for the resubmit.
        """
        if self._fault is not None:
            raise self._fault
        if self._done:
            return self._data
        _check_live(self.result, f"job {self.job_id}'s result buffer")
        s = _san.active()
        if s is not None:
            s.read(self.result, f"wait() on job {self.job_id}")
        if self._retired:
            data = jax.device_get(self.result)
        else:
            data, arrivals = jax.device_get((self.result, self.arrivals))
            self._complete(int(arrivals))
        if self.plan is not None:
            self.plan.stats.d2h_bytes += _nbytes_of(data)
        self._data, self._done = data, True
        self.result = self.arrivals = None   # drop device refs
        return data


@dataclasses.dataclass
class FusedHandle(JobHandle):
    """Handle for B jobs fused into one launch; ``wait()`` returns the
    stacked (B, ...) output, ``wait_each()`` the per-job results."""

    batch: int = 1

    def wait_each(self) -> list:
        data = self.wait()
        return [np.asarray(data[i]) for i in range(self.batch)]


class DispatchPlan:
    """Cached dispatch state for one (job, cluster selection, operand shapes).

    Holds everything ``offload()`` would otherwise recompute per job: the
    sub-mesh, per-operand ``NamedSharding``s, the compiled program, the last
    staged job-args value, and (optionally) *resident* operand buffers that
    repeated dispatch reuses without any host->device transfer.

    ``fuse=B`` makes this a *fused* plan: operand shapes in ``op_meta``
    carry a leading batch axis of length B, shard axes shift right by one,
    and the compiled program vmaps the kernel over the batch — one launch
    for B jobs.
    """

    def __init__(self, runtime: "OffloadRuntime", job: PaperJob,
                 devices: Sequence[jax.Device], cluster_ids: Sequence[int],
                 op_meta: Tuple[Tuple[str, Tuple[int, ...], str], ...],
                 args_shape: Tuple[int, ...],
                 fuse: Optional[int] = None):
        self.runtime = runtime
        self.job = job
        self.cluster_ids = tuple(cluster_ids)
        self.n_clusters = len(cluster_ids)
        self.mesh = Mesh(np.asarray(devices), (AXIS,))
        self.op_meta = op_meta
        self.args_shape = tuple(args_shape)
        self.fuse = fuse
        self.stats = PlanStats()

        cfg = runtime.config
        if cfg.info_dist == "multicast":
            self.args_sharding = NamedSharding(self.mesh, P())
        else:
            self.args_sharding = NamedSharding(self.mesh, P(AXIS))
        lead = 0 if fuse is None else 1   # fused shapes: (B,) + per-job shape
        self.op_shardings: Dict[str, NamedSharding] = {}
        for name, shape, _ in op_meta:
            axis = job.shard_axes[name]
            spec = (P() if axis is None
                    else P(*([None] * (axis + lead) + [AXIS])))
            if axis is not None and shape[axis + lead] % self.n_clusters:
                raise ValueError(
                    f"operand {name} axis {axis} ({shape[axis + lead]}) "
                    f"not divisible by {self.n_clusters} clusters"
                )
            self.op_shardings[name] = NamedSharding(self.mesh, spec)

        self.fn = runtime._build(
            job, self.mesh, self.n_clusters,
            tuple(name for name, _, _ in op_meta), self.args_shape,
            fuse=fuse)

        self._resident: Dict[str, Any] = {}       # name -> device buffer
        self._resident_src: Dict[str, np.ndarray] = {}  # name -> host array
        self._slots: Dict[int, Dict[str, Any]] = {}  # stream staging slots
        self._args_val: Optional[np.ndarray] = None
        self._args_dev: Any = None
        self._devices = list(devices)
        self._stager: Optional[bc.TreeStager] = None   # built lazily
        self._staged_via: str = runtime.config.staging  # residency's mode

    # -- staging ---------------------------------------------------------------

    @property
    def has_resident(self) -> bool:
        return len(self._resident) == len(self.op_meta) > 0 or not self.op_meta

    def _resolve_via(self, via: Optional[Union[str, Staging]]) -> Staging:
        if via is None:
            return self.runtime.config.staging
        if isinstance(via, Staging):
            return via
        return coerce_enum(Staging, via, "via", warn_legacy=True)

    def _tree_stager(self) -> bc.TreeStager:
        if self._stager is None:
            # one tree per plan: the quadrant-aware fan-out derived from the
            # cluster selection, shared by every staging (and every job of a
            # fused batch — the stacked operands ride one tree)
            self._stager = bc.TreeStager(self._devices, self.cluster_ids)
        return self._stager

    def _put(self, arr: np.ndarray, sharding: NamedSharding, via: str) -> Any:
        """One operand/args upload under a staging strategy, bytes counted.

        Sharded arrays cross the host link once regardless of mode (each
        device receives only its shard); the strategies differ only for
        replicated arrays — the O(n) host-link offenders.
        """
        n = self.n_clusters
        if not bc.is_replicated(sharding):
            self.stats.h2d_bytes += arr.nbytes
            return jax.device_put(arr, sharding)
        if via in bc.TREE_MODES:
            self.stats.tree_stages += 1
            return self._tree_stager().put_replicated(
                arr, sharding, reshard=(via == "tree_reshard"),
                stats=self.stats)
        if via == "host_fanout":
            # the measurable O(n) baseline: one host->device transfer per
            # cluster, one outstanding at a time (the serialized host-link
            # writes of §4.1 — CVA6's outstanding-transaction budget)
            bufs = []
            for d in self._devices:
                b = jax.device_put(arr, d)
                b.block_until_ready()
                bufs.append(b)
            self.stats.h2d_bytes += arr.nbytes * n
            return jax.make_array_from_single_device_arrays(
                tuple(arr.shape), sharding, bufs)
        self.stats.h2d_bytes += arr.nbytes * n
        return jax.device_put(arr, sharding)

    def stage(self, operands: Dict[str, np.ndarray], *,
              _caller_owned: bool = True,
              slot: Optional[int] = None,
              via: Optional[Union[str, Staging]] = None) -> Dict[str, Any]:
        """Phase-E upload of ``operands``.

        With ``slot=None`` (default) the buffers become *resident* — the
        warm ``offload(job, "resident")`` path reuses them.  With a slot
        number they land in that numbered staging slot instead, leaving
        residency untouched: the double-buffering hook
        :class:`~repro.core.stream.OffloadStream` uses to overlap job k+1's
        upload with job k's compute.

        ``via`` picks the staging strategy for replicated operands (see
        ``STAGING_MODES``), defaulting to ``OffloadConfig.staging``.  With
        ``"tree"``, each replicated operand crosses the host link exactly
        once (to the fan-out tree root) and reaches the remaining clusters
        through device-to-device copies — ``stats.h2d_bytes`` grows by
        size, not n·size.
        """
        via = self._resolve_via(via)
        names = tuple(sorted(operands))
        if names != tuple(name for name, _, _ in self.op_meta):
            raise ValueError(
                f"operand names {names} do not match plan {self.op_meta}")
        staged = {}
        donating = self.runtime.config.donate_operands
        for name, shape, dtype in self.op_meta:
            arr = np.asarray(_check_live(operands[name],
                                         f"staged operand {name!r}"))
            if tuple(arr.shape) != shape:
                raise ValueError(
                    f"operand {name} shape {arr.shape} != planned {shape}")
            if str(arr.dtype) != dtype:
                raise ValueError(
                    f"operand {name} dtype {arr.dtype} != planned {dtype} "
                    "(a dtype change needs a new plan, not a silent retrace)")
            staged[name] = self._put(arr, self.op_shardings[name], via)
            self.stats.device_puts += 1
            if slot is None:
                # donation restages from these refs later — snapshot caller
                # arrays so in-place mutation cannot skew the redo (restages
                # from our own snapshots skip the copy).  One snapshot per
                # operand at the tree root only: the per-device fan-out
                # copies live on the devices, never on the host.
                self._resident_src[name] = (
                    arr.copy() if donating and _caller_owned else arr)
        if slot is None:
            self._resident = staged
            self._staged_via = via
        else:
            # slot buffers are single-use: each stream submit stages fresh
            # operands, so a donated dispatch consuming them needs no redo
            self._slots[slot] = staged
        s = _san.active()
        if s is not None:
            for name, buf in staged.items():
                s.track(buf, f"staged operand {name!r}")
        return staged

    def forward(self, name: str, value: Any, *,
                rename: bool = False) -> Tuple[Any, int]:
        """Stage operand ``name`` from a *device-resident* producer result.

        The device-to-device leg of dependent dispatch: ``value`` (a jax
        array, possibly still in flight — async dispatch chains it) is
        resharded to this plan's operand sharding without ever visiting
        the host.  Replicated consumer operands fan out along the PR-3
        broadcast tree (root hop from the producer, then the levelled
        d2d copies); sharding-identical forwards alias the producer's
        buffer outright (zero copies) unless ``rename`` or a donating
        config forces a fresh buffer — the WAR/WAW rename that keeps the
        producer's result alive for its remaining readers.

        Returns ``(staged, nbytes)`` where ``nbytes`` is the logical d2d
        byte count of this edge (also accumulated into
        ``stats.forward_bytes``; ``stats.h2d_bytes``/``d2h_bytes`` do
        not move — that is the point).
        """
        names = tuple(n for n, _, _ in self.op_meta)
        if name not in names:
            raise ValueError(f"operand {name!r} not in plan {names}")
        _check_live(value, f"forwarded operand {name!r}")
        s = _san.active()
        if s is not None:
            s.read(value, f"forward of operand {name!r}")
        shape, dtype = next((s, d) for n, s, d in self.op_meta if n == name)
        if tuple(value.shape) != shape or str(value.dtype) != dtype:
            raise ValueError(
                f"forwarded operand {name!r} is {value.shape}/{value.dtype},"
                f" plan expects {shape}/{dtype}")
        sharding = self.op_shardings[name]
        must_rename = rename or self.runtime.config.donate_operands
        moved = 0
        src_sharding = getattr(value, "sharding", None)
        if (src_sharding is not None
                and src_sharding.is_equivalent_to(sharding, value.ndim)):
            # same placement: alias (free) or rename-copy (per-device
            # local, so the logical link bytes stay zero — no edge of
            # the fabric is crossed)
            if must_rename:
                staged = jnp.copy(value)
                self.stats.renames += 1
            else:
                staged = value
        elif bc.is_replicated(sharding):
            staged = self._tree_stager().forward_replicated(
                value, sharding, stats=self.stats)
            moved = value.nbytes * self.n_clusters
        else:
            # sharded consumer: each shard crosses the fabric once
            staged = jax.device_put(value, sharding)
            moved = value.nbytes
            self.stats.forward_bytes += moved
        self.stats.forwards += 1
        if s is not None and staged is not value:
            s.track(staged, f"forwarded operand {name!r}")
        return staged, moved

    def stage_renamed(self, operands: Dict[str, Any], *,
                      via: Optional[Union[str, Staging]] = None
                      ) -> Tuple[Dict[str, Any], Dict[str, int]]:
        """Graph-node staging: host arrays *and* forwarded device arrays.

        Every buffer is fresh (renamed) — residency and stream slots are
        never overwritten, so a graph node whose operands collide with a
        resident buffer or an earlier node's staging proceeds instead of
        stalling (the WAW side of the scoreboard's renaming).  Host
        arrays take the ordinary :meth:`_put` path under ``via``;
        device-resident values take :meth:`forward`.  Returns
        ``(staged, forwarded_bytes_per_operand)``.
        """
        via = self._resolve_via(via)
        names = tuple(sorted(operands))
        if names != tuple(name for name, _, _ in self.op_meta):
            raise ValueError(
                f"operand names {names} do not match plan {self.op_meta}")
        staged: Dict[str, Any] = {}
        fwd_bytes: Dict[str, int] = {}
        for name, shape, dtype in self.op_meta:
            value = operands[name]
            if isinstance(value, jax.Array):
                staged[name], fwd_bytes[name] = self.forward(name, value)
            else:
                arr = np.asarray(value)
                if tuple(arr.shape) != shape:
                    raise ValueError(
                        f"operand {name} shape {arr.shape} != planned "
                        f"{shape}")
                if str(arr.dtype) != dtype:
                    raise ValueError(
                        f"operand {name} dtype {arr.dtype} != planned "
                        f"{dtype}")
                staged[name] = self._put(arr, self.op_shardings[name], via)
                self.stats.device_puts += 1
                s = _san.active()
                if s is not None:
                    s.track(staged[name], f"renamed operand {name!r}")
        return staged, fwd_bytes

    def invalidate(self, names: Optional[Sequence[str]] = None) -> None:
        """Drop resident operand buffers (all, or a named subset)."""
        s = _san.active()
        if s is not None:
            dropped = (self._resident.items() if names is None else
                       ((n, self._resident[n]) for n in names
                        if n in self._resident))
            for name, buf in dropped:
                s.revoke(buf, f"resident operand {name!r}")
        if names is None:
            self._resident.clear()
            self._resident_src.clear()
            self._slots.clear()
        else:
            for name in names:
                self._resident.pop(name, None)
                self._resident_src.pop(name, None)

    def resident_operands(self) -> Dict[str, Any]:
        """The resident device buffers, re-staging any consumed by donation."""
        if not self._resident and self._resident_src:
            # a donated dispatch consumed the buffers; restore from host
            # refs through the same staging strategy they arrived by — a
            # tree-staged operand re-crosses the host link once (root
            # upload), not once per device
            self.stage(dict(self._resident_src), _caller_owned=False,
                       via=self._staged_via)
            self.stats.donation_restages += len(self.op_meta)
        if len(self._resident) != len(self.op_meta):
            raise RuntimeError(
                "no resident operands staged for this plan — dispatch once "
                "with real operands (or call plan.stage) before "
                "offload(job, 'resident', ...)")
        self.stats.resident_hits += len(self.op_meta)
        s = _san.active()
        if s is not None:
            for name, buf in self._resident.items():
                s.read(buf, f"resident operand {name!r}")
        return dict(self._resident)

    def stage_args(self, job_args: np.ndarray, *,
                   via: Optional[Union[str, Staging]] = None) -> Any:
        """Upload job args, skipping the transfer when the value is unchanged.

        Replicated job args (multicast mode) honour the ``via`` staging
        strategy too — they are the paper's actual multicast payload (the
        phase-A job information), so ``"tree"`` sends them over the host
        link once.  Baseline (p2p_chain) args are materialized on cluster 0
        and tiled, an O(n)-byte host transfer by construction.
        """
        if (self._args_dev is not None and self._args_val is not None
                and np.array_equal(self._args_val, job_args)):
            self.stats.args_hits += 1
            return self._args_dev
        if self.runtime.config.info_dist == "multicast":
            host = job_args
        else:
            tiled = np.zeros((self.n_clusters,) + job_args.shape,
                             job_args.dtype)
            tiled[0] = job_args
            host = tiled
        self._args_dev = self._put(np.asarray(host), self.args_sharding,
                                   self._resolve_via(via))
        self.stats.device_puts += 1
        self._args_val = job_args.copy()
        return self._args_dev

    def _after_dispatch(self, consumed_resident: bool = True) -> None:
        self.stats.dispatches += 1
        self.stats.fused_jobs += self.fuse if self.fuse else 1
        if self.runtime.config.donate_operands and consumed_resident:
            # donated buffers are dead; keep host refs so reuse self-heals
            self._resident.clear()


def _chain_distribute(args: jnp.ndarray, n: int) -> jnp.ndarray:
    """Baseline phases C/D: args hop cluster-0 -> 1 -> ... -> n-1.

    Builds n-1 dependent collective-permutes (the O(n) critical path).
    """
    if n == 1:
        return args
    idx = jax.lax.axis_index(AXIS)
    have = args
    perm = [(i, i + 1) for i in range(n - 1)]
    for k in range(n - 1):
        received = jax.lax.ppermute(have, AXIS, perm)
        have = jnp.where(idx <= k, have, received)
    return have


class OffloadRuntime:
    """Host-centric offload of jobs onto a 1-D cluster mesh."""

    def __init__(
        self,
        devices: Optional[Sequence[jax.Device]] = None,
        config: OffloadConfig = OffloadConfig.extended(),
        n_units: int = 4,
        cluster_ids: Optional[Sequence[int]] = None,
        fault_injector: Optional[FaultInjector] = None,
    ):
        self.all_devices = list(devices if devices is not None else jax.devices())
        # the fabric window this runtime owns: global cluster ids, one per
        # device.  A whole-mesh runtime is the identity window; a runtime
        # backing a ClusterLease carries the lease's ids so dispatch plans
        # are keyed by placement and staging trees stay quadrant-aware
        # relative to the real fabric position.
        ids = (range(len(self.all_devices)) if cluster_ids is None
               else cluster_ids)
        self.cluster_ids = tuple(int(c) for c in ids)
        if len(self.cluster_ids) != len(self.all_devices):
            raise ValueError(
                f"{len(self.cluster_ids)} cluster ids for "
                f"{len(self.all_devices)} devices")
        if len(set(self.cluster_ids)) != len(self.cluster_ids):
            raise ValueError(f"duplicate cluster ids in {self.cluster_ids}")
        self.config = config
        self.fault_injector = fault_injector
        self.unit = CompletionUnit(n_units=n_units)
        self._job_counter = 0
        self._compiled: Dict[Tuple, Any] = {}
        self._hlo_text: Dict[Tuple, str] = {}   # lowered_text cache
        self._plans: Dict[Tuple, DispatchPlan] = {}
        self._retired_stats = PlanStats()   # counts from replaced plans
        self.plan_hits = 0
        self.plan_misses = 0

    @property
    def stats(self) -> PlanStats:
        """Running dispatch-overhead totals across all plans (monotonic —
        replaced plans' counts are retained)."""
        agg = dataclasses.replace(self._retired_stats)
        for p in self._plans.values():
            agg.accumulate(p.stats)
        return agg

    # -- cluster selection (paper §4.2 semantics) ---------------------------------

    def select_clusters(
        self,
        n: Optional[int] = None,
        request: Optional[mc.MulticastRequest] = None,
        clusters: Optional[Sequence[int]] = None,
    ) -> Tuple[Sequence[jax.Device], Sequence[int]]:
        """Resolve a cluster selection to a device subset.

        Exactly one of ``n`` (first n clusters), ``request`` (an address-mask
        multicast request, fig. 5) or ``clusters`` (an explicit set, greedily
        covered by subcube requests) must be given.  All three are
        *window-relative*: they select within the runtime's fabric window
        (``cluster_ids``), and the returned ids are the selected clusters'
        **global** fabric ids — a lease-backed runtime keys its plans and
        derives its staging trees from the real placement.  For a
        whole-mesh runtime the window is the identity and nothing changes.
        """
        if sum(x is not None for x in (n, request, clusters)) != 1:
            raise ValueError("give exactly one of n / request / clusters")
        if request is not None:
            ids = mc.decode_cluster_selection(request, len(self.all_devices))
        elif clusters is not None:
            reqs = mc.encode_cluster_selection_multi(clusters, len(self.all_devices))
            ids = sorted(
                {c for r in reqs for c in mc.decode_cluster_selection(r, len(self.all_devices))}
            )
            assert set(ids) == set(clusters)
        else:
            if not (1 <= n <= len(self.all_devices)):
                raise ValueError(f"n={n} outside [1, {len(self.all_devices)}]")
            ids = list(range(n))
        return ([self.all_devices[i] for i in ids],
                [self.cluster_ids[i] for i in ids])

    # -- planning -------------------------------------------------------------------

    def plan(
        self,
        job: PaperJob,
        operands: Optional[Dict[str, np.ndarray]] = None,
        n: Optional[int] = None,
        request: Optional[mc.MulticastRequest] = None,
        clusters: Optional[Sequence[int]] = None,
        args_shape: Tuple[int, ...] = (8,),
        fuse: Optional[int] = None,
    ) -> DispatchPlan:
        """Resolve (and cache) the dispatch plan for a job/selection pair.

        With ``operands`` given, their shapes/dtypes seed (or validate) the
        plan; staging is separate (``plan.stage`` / a dict ``offload``).
        Without operands, the plan must already exist (from a prior dispatch
        or ``plan()`` call) and is returned as-is.  ``fuse=B`` resolves the
        fused-batch plan (operand shapes carry the leading B axis).
        """
        devices, ids = self.select_clusters(
            n=n if (request is None and clusters is None) else None,
            request=request, clusters=clusters,
        )
        key = (job.spec.name, tuple(ids), tuple(args_shape), fuse)
        if operands is None:
            plan = self._plans.get(key)
            if plan is None:
                raise KeyError(
                    f"no dispatch plan for {key}; pass operands once first")
            self.plan_hits += 1
            return plan

        op_meta = tuple(
            (name, tuple(np.asarray(operands[name]).shape),
             str(np.asarray(operands[name]).dtype))
            for name in sorted(operands)
        )
        plan = self._plans.get(key)
        if plan is not None and plan.op_meta == op_meta:
            self.plan_hits += 1
            return plan
        self.plan_misses += 1
        new_plan = DispatchPlan(self, job, devices, ids, op_meta,
                                tuple(args_shape), fuse=fuse)
        if plan is not None:   # replaced: keep its counts (after the build
            # succeeded, so a failing build leaves the old plan untouched)
            self._retired_stats.accumulate(plan.stats)
        self._plans[key] = new_plan
        return new_plan

    # -- dispatch -------------------------------------------------------------------

    def offload(
        self,
        job: PaperJob,
        operands: Union[Dict[str, np.ndarray], str, Residency],
        job_args: Optional[np.ndarray] = None,
        n: Optional[int] = None,
        request: Optional[mc.MulticastRequest] = None,
        clusters: Optional[Sequence[int]] = None,
    ) -> JobHandle:
        """Phase A..I, as one jitted program on the selected sub-mesh.

        ``operands`` is either the host operand dict (phase-E staged on this
        call, and left resident on the plan) or the string ``"resident"`` to
        reuse the buffers staged by the previous dispatch of the same plan —
        the zero-``device_put`` warm path.
        """
        if job_args is None:
            job_args = np.ones((8,), dtype=np.float64)
        job_args = np.asarray(job_args, dtype=np.float64)

        resident = _is_resident(operands, "offload")
        plan = self.plan(
            job, operands=None if resident else operands,
            n=n, request=request, clusters=clusters,
            args_shape=job_args.shape,
        )

        # Phase A / job-info placement (multicast replicates, baseline
        # materializes on cluster 0) — skipped when the value is unchanged.
        args_dev = plan.stage_args(job_args)

        # Phase E staging: resident mode reuses the prior buffers outright.
        if resident:
            op_dev = plan.resident_operands()
        else:
            op_dev = plan.stage(operands)
        return self._launch(plan, args_dev, op_dev)

    def offload_fused(
        self,
        job: PaperJob,
        instances: Union[Sequence[Dict[str, np.ndarray]], str, Residency],
        job_args: Optional[np.ndarray] = None,
        n: Optional[int] = None,
        request: Optional[mc.MulticastRequest] = None,
        clusters: Optional[Sequence[int]] = None,
        batch: Optional[int] = None,
    ) -> FusedHandle:
        """Deprecated direct entry point — fuse B instances into one launch.

        The session API subsumes this: ``Session.submit(job, instances,
        policy=OffloadPolicy(fuse=B))`` (or ``policy=AUTO`` to let the
        planner pick B).  Kept as a warning shim over the same
        implementation.
        """
        warn_legacy("direct OffloadRuntime.offload_fused()",
                    "Session.submit(job, instances, policy=...)")
        return self._offload_fused(job, instances, job_args=job_args, n=n,
                                   request=request, clusters=clusters,
                                   batch=batch)

    def _offload_fused(
        self,
        job: PaperJob,
        instances: Union[Sequence[Dict[str, np.ndarray]], str, Residency],
        job_args: Optional[np.ndarray] = None,
        n: Optional[int] = None,
        request: Optional[mc.MulticastRequest] = None,
        clusters: Optional[Sequence[int]] = None,
        batch: Optional[int] = None,
        staging: Optional[Staging] = None,
    ) -> FusedHandle:
        """Fuse B instances of ``job`` into one XLA launch.

        ``instances`` is a sequence of B operand dicts (stacked host-side
        along a new leading batch axis and phase-E staged as one transfer
        per operand) or ``Residency.RESIDENT`` to redispatch the
        previously staged batch (``batch=B`` then selects the fused plan).
        ``job_args`` may be one (A,) vector shared by all jobs or a (B, A)
        array of per-job args.  ``staging`` picks the phase-E strategy for
        the stacked replicated operands (default: the runtime config's).
        Returns a :class:`FusedHandle` whose ``wait()`` yields the stacked
        (B, ...) results.

        The host pays ~1/B of the per-job dispatch cost while the lowered
        program's collective count stays independent of B (asserted by
        tests over ``lowered_text(job, n, fuse=B)``).
        """
        resident = _is_resident(instances, "offload_fused")
        if resident:
            if batch is None:
                raise ValueError("resident fused dispatch needs batch=B")
            B = batch
        else:
            B = len(instances)
            if B < 1:
                raise ValueError("offload_fused needs at least one instance")
            if batch is not None and batch != B:
                raise ValueError(f"batch={batch} != len(instances)={B}")

        if job_args is None:
            job_args = np.ones((8,), dtype=np.float64)
        job_args = np.asarray(job_args, dtype=np.float64)
        if job_args.ndim == 1:
            job_args = np.broadcast_to(job_args, (B,) + job_args.shape).copy()
        if job_args.shape[0] != B:
            raise ValueError(
                f"job_args leading axis {job_args.shape[0]} != batch {B}")

        stacked = None if resident else stack_instances(instances)
        plan = self.plan(
            job, operands=stacked,
            n=n, request=request, clusters=clusters,
            args_shape=job_args.shape, fuse=B,
        )
        args_dev = plan.stage_args(job_args, via=staging)
        # the stacked dict is ours (fresh arrays from stack_instances), so
        # donation needs no defensive snapshot of it
        op_dev = (plan.resident_operands() if resident
                  else plan.stage(stacked, _caller_owned=False, via=staging))
        handle = self._launch(plan, args_dev, op_dev)
        return FusedHandle(handle.job_id, handle.result, handle.arrivals,
                           plan.n_clusters, handle.dispatched_at, self,
                           plan.cluster_ids, plan, batch=B)

    def _launch(self, plan: DispatchPlan, args_dev: Any,
                op_dev: Dict[str, Any],
                consumed_resident: bool = True) -> JobHandle:
        """The dispatch tail shared by offload/offload_fused/OffloadStream:
        program a completion unit, launch the compiled program (async),
        return the in-flight handle."""
        job_id = self._job_counter
        self._job_counter += 1
        self.unit.program(plan.n_clusters, job_id)
        if self.fault_injector is not None:
            # fault-injection hook: resolves this dispatch's scheduled
            # effect (dropped arrivals / virtual delay) deterministically
            self.fault_injector.on_dispatch(self, job_id, plan.cluster_ids,
                                            plan.job.spec)
        s = _san.active()
        if s is not None:
            # op_dev may alias plan._resident, which a donating
            # _after_dispatch clears — snapshot the buffers first
            op_bufs = [(name, op_dev[name]) for name, _, _ in plan.op_meta]
            for name, buf in op_bufs:
                s.read(buf, f"launch {job_id} operand {name!r}")
        result, arrivals = plan.fn(
            args_dev, *(op_dev[name] for name, _, _ in plan.op_meta))
        plan._after_dispatch(consumed_resident=consumed_resident)
        if s is not None:
            if self.config.donate_operands:
                for name, buf in op_bufs:
                    s.donate(buf, f"operand {name!r}")
            s.track(result, f"job {job_id}'s result buffer")
        return JobHandle(job_id, result, arrivals, plan.n_clusters,
                         time.monotonic(), self, plan.cluster_ids, plan)

    def run(self, job: PaperJob, seed: int = 0, **sel) -> Tuple[Any, Any]:
        """Convenience: build an instance, offload it, return (got, expected)."""
        operands, expected = job.make_instance(seed)
        handle = self.offload(job, operands, **sel)
        return handle.wait(), expected

    # -- program construction ---------------------------------------------------------

    def _build(self, job, mesh, n, op_names, args_shape, fuse=None):
        key = (job.spec.name, self.config, n, op_names, args_shape,
               tuple(d.id for d in mesh.devices.flat), fuse)
        if key in self._compiled:
            return self._compiled[key]

        cfg = self.config
        shard_axes = job.shard_axes
        out_axis = job.out_axis
        reduce = job.reduce
        compute = job.compute
        lead = 0 if fuse is None else 1

        in_specs = [P(AXIS) if cfg.info_dist == "p2p_chain" else P()]
        for name in op_names:
            ax = shard_axes[name]
            in_specs.append(
                P() if ax is None else P(*([None] * (ax + lead) + [AXIS])))
        out_specs = (
            P() if out_axis is None
            else P(*([None] * (out_axis + lead) + [AXIS])),
            P(),
        )

        def program(args, *ops):
            # Phases B/C/D: job-information distribution.
            if cfg.info_dist == "p2p_chain":
                local_args = _chain_distribute(args[0], n)
            else:
                local_args = args
            # The job-info scale rides through the computation so the
            # distribution chain is live in the HLO (and so a wrong
            # distribution corrupts the result -> tested).

            # Phase F: the kernel, on this cluster's shard.
            if fuse is None:
                out = compute(*ops)
                out = out * local_args[0].astype(out.dtype)
            else:
                # B fused jobs: vmap the kernel over the leading batch axis;
                # each job keeps its own args scale.  The cross-cluster
                # reduction below acts on the batched array, so the
                # collective count stays independent of B.
                def one_job(job_ops, scale):
                    out = compute(*job_ops)
                    return out * scale.astype(out.dtype)
                out = jax.vmap(one_job)(ops, local_args[:, 0])
            if out_axis is None and reduce == "sum":
                out = jax.lax.psum(out, AXIS)
            elif out_axis is None and reduce == "mean":
                out = jax.lax.pmean(out, AXIS)

            # Phase H: completion notification (one per launch, fused or not).
            done = jnp.float32(1.0)
            if cfg.completion == "unit":
                arrivals = completion_unit_arrivals(done, AXIS)
            else:
                arrivals = central_counter_arrivals(done, AXIS, n)
            return out, arrivals

        donate = tuple(range(1, 1 + len(op_names))) if cfg.donate_operands else ()
        fn = jax.jit(
            shard_map(
                program, mesh=mesh, in_specs=tuple(in_specs),
                out_specs=out_specs,
            ),
            donate_argnums=donate,
        )
        self._compiled[key] = fn
        return fn

    # -- introspection -------------------------------------------------------------

    def lowered_text(self, job: PaperJob, n: int, seed: int = 0,
                     fuse: Optional[int] = None) -> str:
        """Compiled HLO of the offloaded program — used by tests/benchmarks to
        assert the collective structure (chain depth vs broadcast tree).

        The text is cached per (job, n, config, fuse, device set): repeated
        structure assertions read the cache instead of paying a fresh
        lower+compile each call.  ``fuse=B`` lowers the fused-batch program.
        """
        devices, _ = self.select_clusters(n=n)
        key = (job.spec.name, self.config, n, fuse,
               tuple(d.id for d in devices))
        cached = self._hlo_text.get(key)
        if cached is not None:
            return cached
        operands, _ = job.make_instance(seed)
        mesh = Mesh(np.asarray(devices), (AXIS,))
        fn = self._build(job, mesh, n, tuple(sorted(operands)), (8,) if
                         fuse is None else (fuse, 8), fuse=fuse)
        ftype = jnp.zeros((), jnp.float64).dtype  # honours jax_enable_x64
        lead = () if fuse is None else (fuse,)
        args_shape = lead + (8,)
        if self.config.info_dist == "p2p_chain":
            args_shape = (n,) + args_shape
        sds = [jax.ShapeDtypeStruct(args_shape, ftype)]
        for name in sorted(operands):
            arr = np.asarray(operands[name])
            sds.append(jax.ShapeDtypeStruct(lead + arr.shape, ftype))
        text = fn.lower(*sds).compile().as_text()
        self._hlo_text[key] = text
        return text


def count_collectives(hlo: str) -> Dict[str, int]:
    """Occurrences of each collective op kind in an HLO dump."""
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    counts = {}
    for k in kinds:
        counts[k] = sum(
            1 for line in hlo.splitlines()
            if f" {k}" in line or line.lstrip().startswith(f"{k}")
            if "start" not in line.split("=")[0]
        )
    return counts
