"""The offload runtime — the paper's host-centric execution model on a JAX
device mesh.

``OffloadRuntime`` is the dispatch layer of this framework: it carries a job
(the paper's six kernels, or a training/serving step) onto a set of
accelerator "clusters" (devices of a 1-D mesh), reproducing the paper's two
implementations:

* ``baseline``  — job information is materialized on cluster 0 only and
  distributed hop-by-hop through a chain of ``collective-permute``s (the
  sequential P2P writes of §4.1, phases C/D), and completion is synchronized
  through the central-counter chain (§5.5 H).  The lowered HLO contains an
  O(n)-deep chain of collectives — the paper's O(n) offload critical path,
  structurally visible in ``compiled.as_text()``.
* ``multicast`` — job information is replicated (a single logical broadcast,
  XLA lowers it to an O(log n) tree), phases C/D vanish, and completion is a
  single fused ``psum`` (the job completion unit).  This is the paper's
  co-designed fast path and the default for every training/serving step in
  this framework.

Cluster selection uses the paper's address-mask multicast encoding (§4.2):
``select=MulticastRequest(...)`` picks any power-of-two subcube of clusters,
exactly like fig. 5; arbitrary sets fall back to a minimal multi-request
cover.  The selected clusters become a sub-mesh.

Completion is tracked host-side by the :class:`~repro.core.completion.
CompletionUnit` (fig. 6 semantics, multiple outstanding jobs by job ID), fed
by the device-side arrivals count that every offloaded program returns.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import multicast as mc
from repro.core.completion import (
    CompletionUnit,
    central_counter_arrivals,
    completion_unit_arrivals,
)
from repro.core.jobs import PaperJob

AXIS = "clusters"


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    """First-class framework feature: how jobs are dispatched (§4.2/§4.3)."""

    info_dist: str = "multicast"       # "multicast" | "p2p_chain"
    completion: str = "unit"           # "unit" | "central_counter"
    donate_operands: bool = False

    @staticmethod
    def baseline() -> "OffloadConfig":
        return OffloadConfig(info_dist="p2p_chain", completion="central_counter")

    @staticmethod
    def extended() -> "OffloadConfig":
        return OffloadConfig(info_dist="multicast", completion="unit")


@dataclasses.dataclass
class JobHandle:
    """An in-flight offloaded job (async dispatch = multiple outstanding)."""

    job_id: int
    result: Any                      # jax arrays (async until blocked on)
    arrivals: Any                    # device-side arrivals count
    n_clusters: int
    dispatched_at: float
    runtime: "OffloadRuntime"

    def wait(self) -> Any:
        """Block until complete; feeds the completion unit and returns data."""
        arrivals = int(jax.device_get(self.arrivals))
        self.runtime.unit.arrive(self.job_id, arrivals)
        cause = self.runtime.unit.clear()
        if cause != self.job_id:
            raise RuntimeError(
                f"completion-unit cause {cause} != job {self.job_id}"
            )
        return jax.device_get(self.result)


def _chain_distribute(args: jnp.ndarray, n: int) -> jnp.ndarray:
    """Baseline phases C/D: args hop cluster-0 -> 1 -> ... -> n-1.

    Builds n-1 dependent collective-permutes (the O(n) critical path).
    """
    if n == 1:
        return args
    idx = jax.lax.axis_index(AXIS)
    have = args
    perm = [(i, i + 1) for i in range(n - 1)]
    for k in range(n - 1):
        received = jax.lax.ppermute(have, AXIS, perm)
        have = jnp.where(idx <= k, have, received)
    return have


class OffloadRuntime:
    """Host-centric offload of jobs onto a 1-D cluster mesh."""

    def __init__(
        self,
        devices: Optional[Sequence[jax.Device]] = None,
        config: OffloadConfig = OffloadConfig.extended(),
        n_units: int = 4,
    ):
        self.all_devices = list(devices if devices is not None else jax.devices())
        self.config = config
        self.unit = CompletionUnit(n_units=n_units)
        self._job_counter = 0
        self._compiled: Dict[Tuple, Any] = {}

    # -- cluster selection (paper §4.2 semantics) ---------------------------------

    def select_clusters(
        self,
        n: Optional[int] = None,
        request: Optional[mc.MulticastRequest] = None,
        clusters: Optional[Sequence[int]] = None,
    ) -> Tuple[Sequence[jax.Device], Sequence[int]]:
        """Resolve a cluster selection to a device subset.

        Exactly one of ``n`` (first n clusters), ``request`` (an address-mask
        multicast request, fig. 5) or ``clusters`` (an explicit set, greedily
        covered by subcube requests) must be given.
        """
        if sum(x is not None for x in (n, request, clusters)) != 1:
            raise ValueError("give exactly one of n / request / clusters")
        if request is not None:
            ids = mc.decode_cluster_selection(request, len(self.all_devices))
        elif clusters is not None:
            reqs = mc.encode_cluster_selection_multi(clusters, len(self.all_devices))
            ids = sorted(
                {c for r in reqs for c in mc.decode_cluster_selection(r, len(self.all_devices))}
            )
            assert set(ids) == set(clusters)
        else:
            if not (1 <= n <= len(self.all_devices)):
                raise ValueError(f"n={n} outside [1, {len(self.all_devices)}]")
            ids = list(range(n))
        return [self.all_devices[i] for i in ids], ids

    # -- dispatch -------------------------------------------------------------------

    def offload(
        self,
        job: PaperJob,
        operands: Dict[str, np.ndarray],
        job_args: Optional[np.ndarray] = None,
        n: Optional[int] = None,
        request: Optional[mc.MulticastRequest] = None,
        clusters: Optional[Sequence[int]] = None,
    ) -> JobHandle:
        """Phase A..I, as one jitted program on the selected sub-mesh."""
        devices, ids = self.select_clusters(
            n=n if (request is None and clusters is None) else None,
            request=request,
            clusters=clusters,
        )
        n_sel = len(devices)
        mesh = Mesh(np.asarray(devices), (AXIS,))
        job_id = self._job_counter
        self._job_counter += 1

        if job_args is None:
            job_args = np.ones((8,), dtype=np.float64)
        job_args = np.asarray(job_args, dtype=np.float64)

        fn = self._build(job, mesh, n_sel, tuple(sorted(operands)), job_args.shape)

        # Phase A / job-info placement: multicast replicates (one broadcast);
        # baseline materializes on cluster 0 only and the program chains it.
        if self.config.info_dist == "multicast":
            args_sharding = NamedSharding(mesh, P())
            args_dev = jax.device_put(job_args, args_sharding)
        else:
            tiled = np.zeros((n_sel,) + job_args.shape, job_args.dtype)
            tiled[0] = job_args
            args_dev = jax.device_put(tiled, NamedSharding(mesh, P(AXIS)))

        # Phase E staging: operands enter via their job sharding (chunked or
        # replicated), the wide-path data movement the paper does NOT multicast.
        op_dev = {}
        for name in sorted(operands):
            axis = job.shard_axes[name]
            spec = P() if axis is None else P(*([None] * axis + [AXIS]))
            arr = np.asarray(operands[name])
            if axis is not None and arr.shape[axis] % n_sel:
                raise ValueError(
                    f"operand {name} axis {axis} ({arr.shape[axis]}) "
                    f"not divisible by {n_sel} clusters"
                )
            op_dev[name] = jax.device_put(arr, NamedSharding(mesh, spec))

        self.unit.program(n_sel, job_id)
        result, arrivals = fn(args_dev, *(op_dev[k] for k in sorted(op_dev)))
        return JobHandle(job_id, result, arrivals, n_sel, time.monotonic(), self)

    def run(self, job: PaperJob, seed: int = 0, **sel) -> Tuple[Any, Any]:
        """Convenience: build an instance, offload it, return (got, expected)."""
        operands, expected = job.make_instance(seed)
        handle = self.offload(job, operands, **sel)
        return handle.wait(), expected

    # -- program construction ---------------------------------------------------------

    def _build(self, job, mesh, n, op_names, args_shape):
        key = (job.spec.name, self.config, n, op_names, args_shape,
               tuple(d.id for d in mesh.devices.flat))
        if key in self._compiled:
            return self._compiled[key]

        cfg = self.config
        shard_axes = job.shard_axes
        out_axis = job.out_axis
        reduce = job.reduce
        compute = job.compute

        in_specs = [P(AXIS) if cfg.info_dist == "p2p_chain" else P()]
        for name in op_names:
            ax = shard_axes[name]
            in_specs.append(P() if ax is None else P(*([None] * ax + [AXIS])))
        out_specs = (
            P() if out_axis is None else P(*([None] * out_axis + [AXIS])),
            P(),
        )

        def program(args, *ops):
            # Phases B/C/D: job-information distribution.
            if cfg.info_dist == "p2p_chain":
                local_args = _chain_distribute(args[0], n)
            else:
                local_args = args
            # The job-info scale rides through the computation so the
            # distribution chain is live in the HLO (and so a wrong
            # distribution corrupts the result -> tested).
            scale = local_args[0]

            # Phase F: the kernel, on this cluster's shard.
            out = compute(*ops)
            out = out * scale.astype(out.dtype)
            if out_axis is None and reduce == "sum":
                out = jax.lax.psum(out, AXIS)
            elif out_axis is None and reduce == "mean":
                out = jax.lax.pmean(out, AXIS)

            # Phase H: completion notification.
            done = jnp.float32(1.0)
            if cfg.completion == "unit":
                arrivals = completion_unit_arrivals(done, AXIS)
            else:
                arrivals = central_counter_arrivals(done, AXIS, n)
            return out, arrivals

        fn = jax.jit(
            jax.shard_map(
                program, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs,
                check_vma=False,
            )
        )
        self._compiled[key] = fn
        return fn

    # -- introspection -------------------------------------------------------------

    def lowered_text(self, job: PaperJob, n: int, seed: int = 0) -> str:
        """Compiled HLO of the offloaded program — used by tests/benchmarks to
        assert the collective structure (chain depth vs broadcast tree)."""
        operands, _ = job.make_instance(seed)
        devices, _ = self.select_clusters(n=n)
        mesh = Mesh(np.asarray(devices), (AXIS,))
        fn = self._build(job, mesh, n, tuple(sorted(operands)), (8,))
        ftype = jnp.zeros((), jnp.float64).dtype  # honours jax_enable_x64
        args_shape = (n, 8) if self.config.info_dist == "p2p_chain" else (8,)
        sds = [jax.ShapeDtypeStruct(args_shape, ftype)]
        for name in sorted(operands):
            arr = np.asarray(operands[name])
            sds.append(jax.ShapeDtypeStruct(arr.shape, ftype))
        return fn.lower(*sds).compile().as_text()


def count_collectives(hlo: str) -> Dict[str, int]:
    """Occurrences of each collective op kind in an HLO dump."""
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    counts = {}
    for k in kinds:
        counts[k] = sum(
            1 for line in hlo.splitlines()
            if f" {k}" in line or line.lstrip().startswith(f"{k}")
            if "start" not in line.split("=")[0]
        )
    return counts
