"""Multicast address-mask encoding — paper §4.2, figures 4 & 5.

The paper extends the AXI XBAR address decoder so that a single write request
can target many clusters.  A request carries an address plus a *mask*: bits of
the address covered by a set mask bit are "don't care", i.e. they encode both
0 and 1.  Masking ``k`` bits therefore addresses ``2**k`` destinations.  All
clusters share the same local address map, offset by a constant stride
(0x40000 bytes in Occamy), so one (address, mask) pair reaches the same local
offset within every selected cluster.

The decode condition from the paper (verbatim, §4.2)::

    match = &((req.mask | am.mask) | ~(req.addr ^ am.addr));

i.e. a master port whose address map is (am.addr, am.mask) matches the request
(req.addr, req.mask) iff every bit either belongs to one of the two masks or
agrees between the two addresses.

In the TPU adaptation this algebra is reused one level up: it selects *which
clusters (chips) of the accelerator mesh participate in a job*.  The offload
runtime expresses "clusters 1 and 3 of quadrants 0 and 2" exactly as in
fig. 5 of the paper, and lowers the selection to a device subset of the JAX
mesh.  The hardware realization (NoC multicast) becomes a replicated-sharding
broadcast tree; the *selection semantics* are identical and are property-
tested against a brute-force oracle in ``tests/test_multicast.py``.

Occamy constants (fig. 5): bits [0,17] are the in-cluster offset, bits
[18,19] index the cluster within a quadrant, bits [20,22] index the quadrant.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Sequence, Tuple

# --- Occamy address-map constants (paper fig. 5) -------------------------------
CLUSTER_ADDR_STRIDE = 0x40000          # 256 KiB of address space per cluster
CLUSTER_OFFSET_BITS = 18               # bits [0, 17]: offset inside a cluster
CLUSTER_IDX_BITS = 2                   # bits [18, 19]: cluster within quadrant
QUADRANT_IDX_BITS = 3                  # bits [20, 22]: quadrant index
CLUSTERS_PER_QUADRANT = 1 << CLUSTER_IDX_BITS
NUM_QUADRANTS = 1 << QUADRANT_IDX_BITS
NUM_CLUSTERS = CLUSTERS_PER_QUADRANT * NUM_QUADRANTS   # 32 clusters / 256 cores
ADDR_BITS = CLUSTER_OFFSET_BITS + CLUSTER_IDX_BITS + QUADRANT_IDX_BITS


@dataclasses.dataclass(frozen=True)
class MulticastRequest:
    """A (addr, mask) pair encoding up to ``2**popcount(mask)`` destinations."""

    addr: int
    mask: int = 0

    def __post_init__(self) -> None:
        if self.addr < 0 or self.mask < 0:
            raise ValueError("addr and mask must be non-negative")
        if self.addr & self.mask:
            # Canonical form: don't-care bits are stored as 0 in the address.
            object.__setattr__(self, "addr", self.addr & ~self.mask)

    @property
    def fanout(self) -> int:
        return 1 << bin(self.mask).count("1")

    def addresses(self) -> Iterator[int]:
        """Enumerate every concrete address encoded by this request."""
        mask_bits = [b for b in range(self.mask.bit_length()) if (self.mask >> b) & 1]
        for combo in range(1 << len(mask_bits)):
            addr = self.addr
            for i, b in enumerate(mask_bits):
                if (combo >> i) & 1:
                    addr |= 1 << b
            yield addr


@dataclasses.dataclass(frozen=True)
class AddressMap:
    """A master port's address map: a power-of-two-sized, aligned interval.

    Encoded exactly like a request: ``addr`` is the base, ``mask`` covers the
    low bits spanned by the interval (length ``2**popcount(mask)``, which for
    a contiguous region means mask = length - 1).
    """

    addr: int
    mask: int

    def __post_init__(self) -> None:
        if self.addr & self.mask:
            raise ValueError(
                f"address map base {self.addr:#x} not aligned to mask {self.mask:#x}"
            )

    def contains(self, address: int) -> bool:
        return (address & ~self.mask) == self.addr


def decode_match(req: MulticastRequest, am: AddressMap, addr_bits: int = ADDR_BITS) -> bool:
    """The paper's decoder condition, bit-for-bit.

    ``match = &((req.mask | am.mask) | ~(req.addr ^ am.addr))`` — the AND-
    reduction over ``addr_bits`` bits of (either bit is don't-care) OR (the
    address bits agree).
    """
    full = (1 << addr_bits) - 1
    dont_care = (req.mask | am.mask) & full
    agree = ~(req.addr ^ am.addr) & full
    return (dont_care | agree) == full


def matching_ports(
    req: MulticastRequest, address_maps: Sequence[AddressMap], addr_bits: int = ADDR_BITS
) -> List[int]:
    """Indices of every master port matched by a (possibly multicast) request."""
    return [i for i, am in enumerate(address_maps) if decode_match(req, am, addr_bits)]


# --- Cluster-selection layer (used by the offload runtime) ---------------------

def occamy_cluster_maps(num_clusters: int = NUM_CLUSTERS) -> List[AddressMap]:
    """One address map per cluster, stride 0x40000, as in Occamy."""
    stride_bits = CLUSTER_OFFSET_BITS
    return [
        AddressMap(addr=i << stride_bits, mask=(1 << stride_bits) - 1)
        for i in range(num_clusters)
    ]


def encode_cluster_selection(
    clusters: Iterable[int], num_clusters: int = NUM_CLUSTERS
) -> MulticastRequest:
    """Encode a set of cluster indices as a single multicast request.

    Only sets expressible as a subcube (base OR any subset of masked bits)
    can be encoded in one request; this mirrors the hardware, which sends one
    request per subcube.  Raises ``ValueError`` for non-subcube sets — the
    runtime then falls back to :func:`encode_cluster_selection_multi`.
    """
    cl = sorted(set(clusters))
    if not cl:
        raise ValueError("empty cluster selection")
    if cl[-1] >= num_clusters:
        raise ValueError(f"cluster index {cl[-1]} out of range ({num_clusters})")
    base = cl[0]
    # Bits that vary across the selection.
    varying = 0
    for c in cl:
        varying |= c ^ base
    base &= ~varying
    # The selection is a subcube iff every (base | subset(varying)) is present.
    expected = 1 << bin(varying).count("1")
    if expected != len(cl):
        raise ValueError(f"selection {cl} is not a subcube")
    covered = {base | s for s in _submasks(varying)}
    if covered != set(cl):
        raise ValueError(f"selection {cl} is not a subcube")
    return MulticastRequest(
        addr=base << CLUSTER_OFFSET_BITS, mask=varying << CLUSTER_OFFSET_BITS
    )


def encode_cluster_selection_multi(
    clusters: Iterable[int], num_clusters: int = NUM_CLUSTERS
) -> List[MulticastRequest]:
    """Greedy cover of an arbitrary cluster set by subcube multicast requests.

    The hardware can multicast any subcube in one transaction; arbitrary sets
    need several.  We greedily take the largest subcube fully contained in the
    remaining set (classical logic-minimization flavour; optimal covers are
    NP-hard and unnecessary here).
    """
    remaining = set(clusters)
    if not remaining:
        raise ValueError("empty cluster selection")
    if max(remaining) >= num_clusters:
        raise ValueError("cluster index out of range")
    idx_bits = max(1, (num_clusters - 1).bit_length())
    reqs: List[MulticastRequest] = []
    while remaining:
        best: Tuple[int, int] | None = None  # (base, varying)
        best_size = 0
        for base in sorted(remaining):
            for varying in _subcubes_at(base, idx_bits):
                size = 1 << bin(varying).count("1")
                if size <= best_size:
                    continue
                members = {(base & ~varying) | s for s in _submasks(varying)}
                if members <= remaining:
                    best = (base & ~varying, varying)
                    best_size = size
        assert best is not None  # singletons always qualify
        base, varying = best
        reqs.append(
            MulticastRequest(
                addr=base << CLUSTER_OFFSET_BITS, mask=varying << CLUSTER_OFFSET_BITS
            )
        )
        remaining -= {base | s for s in _submasks(varying)}
    return reqs


def decode_cluster_selection(
    req: MulticastRequest, num_clusters: int = NUM_CLUSTERS
) -> List[int]:
    """Which clusters does a request reach?  (Drives the runtime's device set.)"""
    maps = occamy_cluster_maps(num_clusters)
    return matching_ports(req, maps)


def encode_contiguous_window(
    start: int, n: int, num_clusters: int = NUM_CLUSTERS
) -> List[MulticastRequest]:
    """Encode the contiguous cluster window ``[start, start + n)``.

    The fabric scheduler's lease placement uses this as its *legality*
    contract: a window whose start is aligned to its (power-of-two) size is
    a single subcube and encodes as **one** multicast request — the paper's
    one-write wakeup stays O(1) for the whole lease.  Unaligned or
    non-power-of-two windows decompose greedily into the minimal aligned
    subcubes (binary buddy decomposition), so any contiguous lease is still
    addressable, just with more requests.
    """
    if n < 1:
        raise ValueError(f"window size must be >= 1, got {n}")
    if start < 0 or start + n > num_clusters:
        raise ValueError(
            f"window [{start}, {start + n}) outside [0, {num_clusters})")
    # a contiguous window is just a cluster set: the greedy subcube cover
    # already yields the buddy decomposition (one request per maximal
    # aligned power-of-two block, a single request for aligned windows)
    return encode_cluster_selection_multi(range(start, start + n),
                                          num_clusters)


def _submasks(mask: int) -> Iterator[int]:
    """All subsets of the set bits of ``mask`` (including 0 and mask)."""
    sub = mask
    while True:
        yield sub
        if sub == 0:
            return
        sub = (sub - 1) & mask


def _subcubes_at(base: int, idx_bits: int) -> Iterator[int]:
    """All 'varying' masks over idx_bits, largest-popcount candidates included."""
    for varying in range(1 << idx_bits):
        yield varying
