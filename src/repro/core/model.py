"""Analytical offload-runtime model — paper §5.6, equations 1–6.

The paper models the runtime of a job offloaded (with the multicast + job
completion unit extensions) onto ``n`` clusters as the sum over phases of the
per-phase maximum across clusters (eq. 4):

    t̂(n) = Σ_{p ∈ [A, I]} max_{i ∈ [0, n)} t_p(n, N, i)

We build the model *structurally* from the machine parameters and the job's
phase description (the same :class:`~repro.core.simulator.JobSpec` the
simulator consumes), exactly as the paper composes its closed forms:

  phase A,B,C,D,H,I — constants from :class:`~repro.core.params.OccamyParams`
  phase E — eq. 1: t_setup + t_latency + total_bytes / bw   (port drain: with
            multicast all clusters start together and the single SPM port
            serializes every transfer)
  phase F — eq. 2: t_init + max_i compute(n, i)
  phase G — eq. 3: t_setup + t_latency + max_i wb_bytes(i) / bw  (the phase-E
            skew separates the writebacks, so each is a lone transfer)

For the AXPY job this reduces *exactly* to eq. 5,
``t̂(n) = 400 + N/4 + 2.47·N/(8n)`` (asserted in tests/test_model.py), and for
ATAX to the eq.-6 form ``C + a·N·M + b·N/n + N(1+M)/8 · n``.

The model answers the paper's offload decision (§1): `optimal_clusters`
returns the analytically best number of clusters for a job instance, and
`should_offload` compares against a host-only execution estimate.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.phases import Phase
from repro.core.simulator import JobSpec, intra_barrier, simulate


@dataclasses.dataclass
class ModelBreakdown:
    """Per-phase contributions of the analytical model (cycles)."""

    terms: Dict[Phase, float]

    @property
    def total(self) -> float:
        return sum(self.terms.values())


def offload_constant(p: OccamyParams, arg_words: int) -> Dict[Phase, float]:
    """The job-independent phases A, B, C, D, H, I (§5.6) for the extensions."""
    return {
        Phase.A: p.host_info_base + p.host_info_per_word * (1 + arg_words),
        Phase.B: p.host_store_first + p.noc_propagation,
        Phase.C: p.narrow_local,
        Phase.D: 0.0,
        Phase.H: (
            p.phase_sync
            + p.unit_arrival_code
            + p.clint_travel
            + p.unit_fire
            + p.noc_propagation
        ),
        Phase.I: p.host_resume,
    }


def predict(job: JobSpec, n: int, p: OccamyParams = DEFAULT_PARAMS) -> ModelBreakdown:
    """Eq. 4: per-phase max composition for the multicast implementation."""
    terms = dict(offload_constant(p, job.arg_words))

    # Phase E (eq. 1): simultaneous starts -> the single port drains the
    # total traffic; the last-granted cluster sees the full drain.  Refinement
    # over the paper's closed form: each granted transfer occupies at least
    # one beat of the 512-bit port (sub-beat bursts cannot pack), which
    # matters only in the extreme fine-grained corner (chunk < 64 B); for the
    # paper's sizes the two coincide and eq. 5 is recovered exactly.
    op_sizes = [list(job.operand_transfers(n, i)) for i in range(n)]
    drain = sum(
        max(1.0, b / p.wide_bw_bytes_per_cycle) for s in op_sizes for b in s
    )
    max_transfers = max((len(s) for s in op_sizes), default=0)
    if drain > 0:
        terms[Phase.E] = p.dma_setup(max_transfers) + p.dma_latency + drain
    else:
        terms[Phase.E] = 0.0

    # Phase F (eq. 2): init + slowest cluster (+ level barriers for BFS-like
    # jobs).
    max_compute = max(job.compute_cycles(n, i) for i in range(n))
    terms[Phase.F] = p.f_init + max_compute
    if job.levels > 1:
        terms[Phase.F] += (job.levels - 1) * intra_barrier(n, p)

    # Phase G (eq. 3): writebacks are skew-separated -> single-transfer cost
    # (same ≥1-beat refinement as phase E).
    wb_sizes = [list(job.writeback_transfers(n, i)) for i in range(n)]
    max_wb = max(
        (sum(max(1.0, b / p.wide_bw_bytes_per_cycle) for b in s) for s in wb_sizes),
        default=0.0,
    )
    wb_transfers = max((len(s) for s in wb_sizes), default=0)
    if max_wb > 0:
        terms[Phase.G] = p.dma_setup(wb_transfers) + p.dma_latency + max_wb
    else:
        terms[Phase.G] = 0.0
    return ModelBreakdown(terms)


def predict_total(job: JobSpec, n: int, p: OccamyParams = DEFAULT_PARAMS) -> float:
    return predict(job, n, p).total


# --- Closed forms (paper eqs. 5 and 6) -----------------------------------------


def axpy_closed_form(n: int, N: int) -> float:
    """Eq. 5 verbatim: t̂(n) = 400 + N/4 + 2.47·N/(8·n)."""
    return 400.0 + N / 4.0 + 2.47 * N / (8.0 * n)


def atax_closed_form_paper(n: int, N: int, M: int) -> float:
    """Eq. 6 verbatim: t̂(n) = 566 + 3.98·N·M + 2.9·N/(8n) + N(1+M)/8 · n."""
    return 566.0 + 3.98 * N * M + 2.9 * N / (8.0 * n) + N * (1.0 + M) / 8.0 * n


# --- Model v2 (beyond the paper): port-saturation lower bound -------------------


def port_bound(job: JobSpec, n: int, p: OccamyParams = DEFAULT_PARAMS) -> float:
    """Work-conserving bound on the wide port: when the job is DMA-bound the
    single SPM port serves E and G traffic back-to-back, and the runtime is
    pinned by the total drain regardless of n.  The paper's eq.-4 composition
    assumes phase G is skew-separated (eq. 3), which breaks exactly in this
    regime (§5.5 G documents the E/G coupling qualitatively).
    """
    start = sum(
        offload_constant(p, job.arg_words)[ph] for ph in (Phase.A, Phase.B, Phase.C, Phase.D)
    )
    op_sizes = [list(job.operand_transfers(n, i)) for i in range(n)]
    wb_sizes = [list(job.writeback_transfers(n, i)) for i in range(n)]
    drain = sum(max(1.0, b / p.wide_bw_bytes_per_cycle) for s in op_sizes for b in s)
    drain += sum(max(1.0, b / p.wide_bw_bytes_per_cycle) for s in wb_sizes for b in s)
    max_transfers = max((len(s) for s in op_sizes), default=0)
    tail = dict(offload_constant(p, job.arg_words))
    return (
        start
        + p.dma_setup(max_transfers)
        + drain
        + p.dma_latency
        + p.phase_sync
        + tail[Phase.H]
        + tail[Phase.I]
    )


def predict_total_v2(job: JobSpec, n: int, p: OccamyParams = DEFAULT_PARAMS) -> float:
    """max(eq-4 composition, port drain bound) — beyond-paper refinement that
    stays accurate into the DMA-saturated regime (EXPERIMENTS.md §Model-v2)."""
    return max(predict_total(job, n, p), port_bound(job, n, p))


# --- Validation against the simulator (fig. 12) --------------------------------


@dataclasses.dataclass
class ValidationPoint:
    n: int
    size: Tuple[int, ...]
    simulated: float
    predicted: float

    @property
    def rel_error(self) -> float:
        return abs(self.simulated - self.predicted) / self.simulated


def validate(
    make_job: Callable[..., JobSpec],
    sizes: Sequence[Tuple[int, ...]],
    ns: Sequence[int],
    p: OccamyParams = DEFAULT_PARAMS,
    predictor: Callable[[JobSpec, int, OccamyParams], float] = predict_total,
) -> List[ValidationPoint]:
    """Compare model predictions to simulated runtimes (the paper's fig. 12).

    The paper validates on the multicast implementation only (§5.6: the
    baseline's phase couplings make it much harder to model).
    """
    points = []
    for size in sizes:
        job = make_job(*size)
        for n in ns:
            sim = simulate(job, n, "multicast", p).total
            pred = predictor(job, n, p)
            points.append(ValidationPoint(n, tuple(size), sim, pred))
    return points


def max_rel_error(points: Sequence[ValidationPoint]) -> float:
    return max(pt.rel_error for pt in points)


# --- The offload decision (§1, §5.6) --------------------------------------------


def optimal_clusters(
    job_for_n: Callable[[], JobSpec],
    p: OccamyParams = DEFAULT_PARAMS,
    candidates: Sequence[int] = (1, 2, 4, 8, 16, 32),
) -> Tuple[int, float]:
    """Analytically best number of clusters — the paper's non-binary offload
    decision ("in addition to establishing *if* a job is suitable for offload,
    the question *how* to offload the job has to be answered as well")."""
    job = job_for_n()
    best_n, best_t = None, float("inf")
    for n in candidates:
        if n > p.num_clusters:
            continue
        t = predict_total(job, n, p)
        if t < best_t:
            best_n, best_t = n, t
    assert best_n is not None
    return best_n, best_t


def should_offload(job: JobSpec, host_cycles: float,
                   p: OccamyParams = DEFAULT_PARAMS) -> Tuple[bool, int, float]:
    """The binary offload decision: offload iff the modeled offloaded runtime
    (at the optimal cluster count) beats the host-only estimate."""
    n, t = optimal_clusters(lambda: job, p)
    return t < host_cycles, n, t
