"""Version-compatibility shims for the JAX substrate.

The framework targets the modern ``jax.shard_map`` entry point (with its
``check_vma=`` argument); older installs (<= 0.4.x) only ship
``jax.experimental.shard_map.shard_map`` whose equivalent knob is spelled
``check_rep=``.  ``shard_map`` below resolves whichever is available once at
import time so every caller (offload runtime, tests, benchmarks) goes through
one code path.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

__all__ = ["shard_map"]


def _resolve() -> Callable[..., Any]:
    new = getattr(jax, "shard_map", None)
    if new is not None:
        def via_new(f, *, mesh, in_specs, out_specs, check: bool = False):
            return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=check)
        return via_new

    from jax.experimental.shard_map import shard_map as old

    def via_old(f, *, mesh, in_specs, out_specs, check: bool = False):
        return old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check)
    return via_old


_impl = _resolve()


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Map ``f`` over shards of a mesh; ``check`` toggles the replication /
    varying-manual-axes checker (``check_vma`` on new JAX, ``check_rep`` on
    the experimental fallback)."""
    return _impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                 check=check)
