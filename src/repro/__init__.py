"""repro — a JAX/TPU reproduction of "Taming Offload Overheads in a Massively
Parallel Open-Source RISC-V MPSoC" (Colagrande & Benini, TPDS 2025), extended
into a production-grade multi-pod training/serving framework.

Layers (bottom-up):
  repro.kernels    — Pallas TPU kernels for the paper's compute hot spots
  repro.core       — the paper's contribution: multicast offload runtime,
                     job completion unit, phase simulator, analytical model
  repro.models     — architecture zoo (10 assigned archs + paper benchmarks)
  repro.dist       — mesh / sharding rules / collective helpers / compression
  repro.data       — deterministic synthetic data pipeline
  repro.optim      — AdamW + schedules (pure JAX)
  repro.train      — train-step builder (microbatching, remat, offload dispatch)
  repro.serve      — prefill/decode with KV cache and SSM state
  repro.checkpoint — sharded npz+manifest checkpoints, elastic restore
  repro.ft         — straggler mitigation, watchdog, elastic rescale
  repro.configs    — assigned architecture configs
  repro.launch     — mesh builders, dry-run, train/serve entry points
"""

__version__ = "1.0.0"
