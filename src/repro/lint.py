"""``python -m repro.lint`` — the perf linter's reporting CLI.

Runs :mod:`repro.analysis.perflint` over the repo's checked-in job
graphs (the same corpus ``make verify-graphs`` gates) and reports
``OFLP1##`` findings with their predicted cycle deltas:

    PYTHONPATH=src python -m repro.lint                # text report
    python -m repro.lint --json out.json               # machine-readable
    python -m repro.lint --sarif out.sarif             # GitHub code scanning
    python -m repro.lint --codes-md                    # README code table
    python -m repro.lint --explain-regret              # policy=AUTO regret
    python -m repro.lint --update-baseline             # accept findings

Exit status is 0 when every finding is *accounted for* — suppressed by
a file-level ``# repro: allow(OFLP10x)`` comment in the graph-builder
source, or present in the committed baseline (``LINT_baseline.json``)
— and 1 when new findings appear.  ``make lint-graphs`` wires this
into CI as the zero-new-findings gate.
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib.util
import json
import re
import sys
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

#: mesh width the CI bench mesh uses (matches benchmarks/verify_graphs.py)
MESH_WIDTH = 8

#: checked-in graph sources: ``<file>:<builder>`` where the builder
#: returns ``{name: [GraphNode, ...]}``
DEFAULT_CORPUS = (
    "examples/job_graph.py:build_graphs",
    "benchmarks/dag_bench.py:bench_graphs",
)

DEFAULT_BASELINE = "LINT_baseline.json"

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([^)]*)\)")


@dataclasses.dataclass
class CorpusGraph:
    """One checked-in graph plus its source-file suppression set."""

    name: str                    # "<source>:<graph>"
    path: Path                   # the builder's source file
    nodes: List[Any]
    allowed: frozenset           # codes a `# repro: allow(...)` suppresses


def _allowed_codes(path: Path) -> frozenset:
    try:
        text = path.read_text()
    except OSError:
        return frozenset()
    codes: set = set()
    for m in _ALLOW_RE.finditer(text):
        codes.update(c.strip().upper() for c in m.group(1).split(",")
                     if c.strip())
    return frozenset(codes)


def load_corpus(specs: Sequence[str],
                root: Optional[Path] = None) -> List[CorpusGraph]:
    """Load every ``<file>:<builder>`` spec (missing files are skipped
    with a note — the CLI is importable outside the repo checkout)."""
    root = Path.cwd() if root is None else root
    out: List[CorpusGraph] = []
    for spec in specs:
        fname, _, builder = spec.rpartition(":")
        path = (root / fname).resolve()
        if not path.exists():
            print(f"note: corpus source {fname} not found, skipping",
                  file=sys.stderr)
            continue
        modname = f"_repro_lint_{path.stem}"
        mspec = importlib.util.spec_from_file_location(modname, path)
        assert mspec is not None and mspec.loader is not None
        mod = importlib.util.module_from_spec(mspec)
        sys.modules[modname] = mod
        mspec.loader.exec_module(mod)
        allowed = _allowed_codes(path)
        source = str(Path(fname).with_suffix(""))
        for name, nodes in getattr(mod, builder)().items():
            out.append(CorpusGraph(name=f"{source}:{name}", path=path,
                                   nodes=list(nodes), allowed=allowed))
    return out


def lint_corpus(graphs: Iterable[CorpusGraph], *,
                width: int = MESH_WIDTH
                ) -> List[Tuple[CorpusGraph, List[Any]]]:
    from repro.analysis import perflint
    return [(g, perflint.lint_graph(g.nodes, default_width=width))
            for g in graphs]


# -- reporting surfaces ------------------------------------------------------


def codes_markdown() -> str:
    """The README diagnostic-code table, generated from the registry
    (``--codes-md``; ``tests/test_perflint.py`` fails on README drift)."""
    from repro.analysis.diagnostics import CODES
    lines = [
        "| code | severity | title |",
        "|------|----------|-------|",
    ]
    for code in sorted(CODES):
        info = CODES[code]
        lines.append(f"| `{code}` | {info.severity.value} | "
                     f"{info.title} |")
    return "\n".join(lines)


def finding_key(graph: str, finding: Any) -> str:
    return f"{graph}::{finding.key()}"


def to_json(results: List[Tuple[CorpusGraph, List[Any]]]) -> Dict[str, Any]:
    return {
        "schema": 1,
        "graphs": {
            g.name: [f.to_payload() for f in findings]
            for g, findings in results
        },
    }


def to_sarif(results: List[Tuple[CorpusGraph, List[Any]]]) -> Dict[str, Any]:
    """SARIF 2.1.0 (the GitHub code-scanning upload format)."""
    from repro.analysis.diagnostics import CODES, Severity
    level = {Severity.ERROR: "error", Severity.WARNING: "warning",
             Severity.PERF: "note"}
    rules = [{
        "id": code,
        "shortDescription": {"text": CODES[code].title},
        "fullDescription": {"text": CODES[code].explain},
        "defaultConfiguration": {
            "level": level[CODES[code].severity]},
    } for code in sorted(CODES)]
    sarif_results = []
    for g, findings in results:
        for f in findings:
            sarif_results.append({
                "ruleId": f.code,
                "level": level[f.diagnostic.severity],
                "message": {"text": f"{g.name}: {f}"},
                "locations": [{
                    "physicalLocation": {
                        "artifactLocation": {
                            "uri": g.path.name,
                            "uriBaseId": "SRCROOT"},
                        "region": {"startLine": 1},
                    },
                }],
                "properties": {
                    "graph": g.name,
                    "predictedCycles": f.predicted_cycles,
                    "optimalCycles": f.optimal_cycles,
                    "fix": (None if f.fix is None
                            else dataclasses.asdict(f.fix)),
                },
            })
    return {
        "$schema": ("https://raw.githubusercontent.com/oasis-tcs/"
                    "sarif-spec/master/Schemata/sarif-schema-2.1.0.json"),
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {"name": "repro-perflint",
                                "informationUri": "",
                                "rules": rules}},
            "results": sarif_results,
        }],
    }


def regret_report(results: List[Tuple[CorpusGraph, List[Any]]]) -> str:
    """Per-graph model regret: predicted critical path as checked in vs
    with every autofix applied (``--explain-regret``).  The migration
    story for ``policy=AUTO`` users: the planner already avoids these
    regrets on the fields it decides — the table shows what *pinned*
    fields and graph structure still leave on the table."""
    from repro.analysis import perflint
    from repro.core.simulator import graph_critical_path
    lines = [f"{'graph':44s} {'cycles':>10s} {'autofixed':>10s} "
             f"{'regret':>7s}"]
    for g, findings in results:
        jobs, _ = perflint.graph_jobs(g.nodes, default_width=MESH_WIDTH)
        cur = graph_critical_path(jobs)
        fixed_nodes = perflint.apply(findings, nodes=g.nodes).nodes
        assert fixed_nodes is not None
        fjobs, _ = perflint.graph_jobs(fixed_nodes,
                                       default_width=MESH_WIDTH)
        opt = graph_critical_path(fjobs)
        lines.append(f"{g.name:44s} {cur:10.0f} {opt:10.0f} "
                     f"{cur / opt if opt else 1.0:7.3f}")
    return "\n".join(lines)


# -- baseline ----------------------------------------------------------------


def load_baseline(path: Path) -> Dict[str, int]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {str(k): int(v) for k, v in data.get("findings", {}).items()}


def save_baseline(path: Path,
                  results: List[Tuple[CorpusGraph, List[Any]]]) -> None:
    counts: Dict[str, int] = {}
    for g, findings in results:
        for f in findings:
            if f.code in g.allowed:
                continue
            k = finding_key(g.name, f)
            counts[k] = counts.get(k, 0) + 1
    path.write_text(json.dumps(
        {"schema": 1, "findings": dict(sorted(counts.items()))},
        indent=2, sort_keys=True) + "\n")


def new_findings(results: List[Tuple[CorpusGraph, List[Any]]],
                 baseline: Dict[str, int]
                 ) -> List[Tuple[str, Any]]:
    """Findings neither suppressed in-source nor covered by the
    baseline (per-key counts: the baseline absorbs at most its recorded
    number of findings per key)."""
    budget = dict(baseline)
    fresh: List[Tuple[str, Any]] = []
    for g, findings in results:
        for f in findings:
            if f.code in g.allowed:
                continue
            k = finding_key(g.name, f)
            if budget.get(k, 0) > 0:
                budget[k] -= 1
                continue
            fresh.append((g.name, f))
    return fresh


# -- entry point -------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="model-driven performance linter over checked-in "
                    "job graphs")
    ap.add_argument("--graphs", action="append", metavar="FILE:BUILDER",
                    help="graph source (default: the checked-in corpus); "
                         "repeatable")
    ap.add_argument("--width", type=int, default=MESH_WIDTH,
                    help=f"default selection width (default "
                         f"{MESH_WIDTH})")
    ap.add_argument("--json", metavar="PATH",
                    help="write findings as JSON")
    ap.add_argument("--sarif", metavar="PATH",
                    help="write findings as SARIF 2.1.0")
    ap.add_argument("--baseline", metavar="PATH", default=DEFAULT_BASELINE,
                    help=f"baseline file (default {DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="accept the current findings into the baseline")
    ap.add_argument("--codes-md", action="store_true",
                    help="print the diagnostic-code table as markdown "
                         "and exit")
    ap.add_argument("--explain-regret", action="store_true",
                    help="print per-graph model regret (current vs "
                         "autofixed critical path)")
    args = ap.parse_args(argv)

    if args.codes_md:
        print(codes_markdown())
        return 0

    corpus = load_corpus(args.graphs or DEFAULT_CORPUS)
    results = lint_corpus(corpus, width=args.width)

    if args.json:
        Path(args.json).write_text(
            json.dumps(to_json(results), indent=2, sort_keys=True) + "\n")
    if args.sarif:
        Path(args.sarif).write_text(
            json.dumps(to_sarif(results), indent=2, sort_keys=True) + "\n")
    if args.explain_regret:
        print(regret_report(results))
        print()

    baseline_path = Path(args.baseline)
    if args.update_baseline:
        save_baseline(baseline_path, results)
        print(f"baseline written: {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    fresh = new_findings(results, baseline)
    total = sum(len(f) for _, f in results)
    suppressed = sum(1 for g, fs in results for f in fs
                     if f.code in g.allowed)
    for g, findings in results:
        status = ("clean" if not findings
                  else f"{len(findings)} finding(s)")
        print(f"  {g.name:45s} {len(g.nodes):3d} nodes  {status}")
        for f in findings:
            mark = ("allowed" if f.code in g.allowed else
                    "baseline" if (g.name, f) not in fresh else "NEW")
            print(f"    [{mark}] {f}")
    print(f"lint-graphs: {len(corpus)} graphs, {total} finding(s) "
          f"({suppressed} allowed, {total - suppressed - len(fresh)} "
          f"baselined, {len(fresh)} new)")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
