"""Architecture zoo: dense GQA transformers, MLA, MoE, Mamba-1/2, hybrids,
and stub multimodal frontends — every assigned architecture family."""

from repro.models.config import (
    FrontendConfig, HybridConfig, MLAConfig, MoEConfig, ModelConfig, SSMConfig,
    reduced,
)
from repro.models.model import (
    CallConfig, decode_step, decode_step_ragged, forward, init_cache,
    init_params, loss_fn, prefill,
)
from repro.models.registry import ARCHS, count_params, get

__all__ = [
    "ARCHS", "CallConfig", "FrontendConfig", "HybridConfig", "MLAConfig",
    "MoEConfig", "ModelConfig", "SSMConfig", "count_params", "decode_step",
    "decode_step_ragged",
    "forward", "get", "init_cache", "init_params", "loss_fn", "prefill",
    "reduced",
]
