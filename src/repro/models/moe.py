"""Mixture-of-Experts block: top-k routing, capacity-based dispatch, shared
experts, load-balancing auxiliary loss.

Dispatch is the production (E, C, D) buffer pattern: tokens scatter into
per-expert capacity slots, a single batched einsum runs all experts (exact
FLOPs — no dense-over-experts redundancy), and per-k gathers combine the
results.  The (E, C, D) buffer is the tensor that shards over the `model`
axis for expert parallelism: resharding it from token-sharded to
expert-sharded is XLA's all-to-all, which the roofline's collective term
picks up.  Overflowing tokens beyond capacity are dropped (their combine
weight is zero) — capacity_factor 1.25 keeps drops rare at convergence.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import gated_mlp

CAPACITY_FACTOR = 1.25


def capacity(tokens: int, n_experts: int, top_k: int,
             no_drop: bool = False) -> int:
    if no_drop:
        # Exact worst case: a token's k choices are DISTINCT experts, so no
        # expert can receive more than `tokens` entries.  (§Perf move M3:
        # was tokens*top_k, a k× overallocation that dominated MoE decode
        # FLOPs — see EXPERIMENTS.md.)
        return tokens
    c = int(tokens * top_k / n_experts * CAPACITY_FACTOR)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def moe_block(
    x: jnp.ndarray,                 # (B, S, D)
    p: Dict[str, jnp.ndarray],      # this layer's MoE params
    cfg: ModelConfig,
    no_drop: bool = False,          # exact routing (serving / eval)
    buffer_sharding=None,           # EP constraint on the (E, C, D) buffer
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_load_balance_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    c = capacity(t, e, k, no_drop)
    xf = x.reshape(t, d)

    # --- router (f32) ---------------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                # renormalize

    # --- load-balancing aux loss (Switch-style) --------------------------------
    me = probs.mean(axis=0)                                    # (E,)
    one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)
    aux = m.aux_loss_coef * e * jnp.sum(me * ce)

    # --- dispatch: positions within each expert's capacity ----------------------
    # flat (T*K,) expert choices, priority by (k, token) order
    e_flat = gate_idx.T.reshape(-1)                            # (K*T,)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)        # (K*T, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot             # (K*T, E)
    pos_flat = jnp.take_along_axis(pos_in_e, e_flat[:, None], axis=1)[:, 0]
    keep = pos_flat < c
    pos_clamped = jnp.minimum(pos_flat, c - 1)

    buf = jnp.zeros((e, c, d), x.dtype)
    tok_idx = jnp.tile(jnp.arange(t), k)
    contrib = jnp.where(keep[:, None], xf[tok_idx], 0.0)
    buf = buf.at[e_flat, pos_clamped].add(contrib)             # (E, C, D)
    if buffer_sharding is not None:
        buf = jax.lax.with_sharding_constraint(buf, buffer_sharding)

    # --- expert FFNs: one batched einsum over stacked experts -------------------
    dt = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["wg"].astype(dt))
    g = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g, approximate=True)
    y_buf = jnp.einsum("ecf,efd->ecd", g * h, p["experts"]["wo"].astype(dt))

    # --- combine: per-k weighted gathers (keeps transients at (T, D)) ------------
    y = jnp.zeros((t, d), jnp.float32)
    w_flat = gate_vals.T.reshape(-1)                           # (K*T,)
    for kk in range(k):
        sl = slice(kk * t, (kk + 1) * t)
        ek, pk = e_flat[sl], pos_clamped[sl]
        wk = jnp.where(keep[sl], w_flat[sl], 0.0)
        y = y + wk[:, None] * y_buf[ek, pk].astype(jnp.float32)
    y = y.astype(x.dtype)

    # --- shared experts (always-on) ----------------------------------------------
    if m.n_shared:
        y = y + gated_mlp(xf, p["shared"]["wi"], p["shared"]["wg"],
                          p["shared"]["wo"], cfg.act)
    return y.reshape(b, s, d), aux
