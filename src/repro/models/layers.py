"""Shared neural-net layers: norms, rotary/sinusoidal positions, gated MLPs."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm in f32 (gemma-style ``(1 + w)`` scaling when plus_one)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    w = 1.0 + w if plus_one else w
    return (normed * w).astype(x.dtype)


def gated_rms_norm(x: jnp.ndarray, gate: jnp.ndarray, weight: jnp.ndarray,
                   eps: float = 1e-5) -> jnp.ndarray:
    """Mamba-2's norm: RMSNorm(x * silu(gate)) fused before out_proj."""
    return rms_norm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype),
                    weight, eps)


# --- positions -----------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """NeoX-style half-rotation.  x: (..., S, D_head); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """MusicGen-style sinusoidal embeddings.  positions: (..., S) -> (..., S, D)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# --- MLPs ------------------------------------------------------------------------


def gated_mlp(x: jnp.ndarray, wi: jnp.ndarray, wg: jnp.ndarray, wo: jnp.ndarray,
              act: str = "silu") -> jnp.ndarray:
    """SwiGLU (silu) / GeGLU (gelu): wo( act(x·wg) * (x·wi) )."""
    h = jnp.einsum("...d,df->...f", x, wi.astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, wg.astype(x.dtype))
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    return jnp.einsum("...f,fd->...d", g * h, wo.astype(x.dtype))


# --- init -------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32) -> jnp.ndarray:
    """Truncated-normal fan-in init (the framework's only initializer)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = (1.0 / max(1, fan_in)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)
