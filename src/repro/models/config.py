"""Model configuration schema covering every assigned architecture family.

One frozen dataclass tree describes dense transformers (GQA/RoPE/SwiGLU,
optional QKV bias), MLA attention (DeepSeek-V2), MoE blocks (shared + routed
experts, top-k), Mamba-1 selective SSM, Mamba-2 SSD hybrids with a shared
attention block (Zamba2), and stub multimodal frontends (PaliGemma SigLIP
patches, MusicGen EnCodec tokens).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int               # per-expert FFN width
    n_shared: int = 0              # always-on shared experts
    router_noise: float = 0.0      # jitter for load balancing (train only)
    aux_loss_coef: float = 0.01    # load-balancing auxiliary loss


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int              # compressed KV dim (the MLA cache)
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    version: int                   # 1 = Mamba-1 (S6), 2 = Mamba-2 (SSD)
    d_state: int
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64              # Mamba-2 only
    chunk: int = 256               # chunked-scan block length
    dt_rank: int = 0               # Mamba-1: 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: one *shared* attention block applied every `period`
    SSM layers (weights reused at every application)."""

    period: int = 6
    shared_attn_heads: int = 32
    shared_attn_kv_heads: int = 32


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: input_specs() provides precomputed embeddings."""

    kind: str                      # "vision_stub" | "audio_stub"
    n_prefix_tokens: int = 0       # vision: patch tokens prepended (prefix-LM)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int                      # 0 for pure-ssm blocks
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    pos_embedding: str = "rope"    # "rope" | "sinusoidal" (musicgen)
    act: str = "silu"              # "silu" (SwiGLU) | "gelu" (GeGLU, gemma)
    embed_scale: bool = False      # gemma multiplies embeddings by sqrt(d)
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: Optional[FrontendConfig] = None
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family not in ("dense", "moe", "vlm", "hybrid", "audio", "ssm"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads (GQA)")

    # ---- derived sizes -------------------------------------------------------

    @property
    def q_dim(self) -> int:
        if self.mla:
            return self.n_heads * (
                self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim
            )
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        if self.mla:
            return self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts (state-based decode)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (drives roofline MODEL_FLOPS = 6·N·D)."""
        from repro.models.registry import count_params  # avoid cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized sibling of the same family (tests/per-arch smoke)."""
    base = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.hybrid else cfg.hybrid.period + 1),
        d_model=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
    )
    if cfg.moe:
        base["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            n_shared=min(cfg.moe.n_shared, 1),
        )
    if cfg.mla:
        base["mla"] = MLAConfig(
            kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16,
            v_head_dim=32,
        )
    if cfg.ssm:
        base["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=8, headdim=16, chunk=16,
        )
    if cfg.hybrid:
        base["hybrid"] = HybridConfig(
            period=2, shared_attn_heads=4, shared_attn_kv_heads=2
        )
        base["n_layers"] = 4
    if cfg.frontend:
        base["frontend"] = dataclasses.replace(cfg.frontend, n_prefix_tokens=8)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **base)
