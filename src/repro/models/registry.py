"""Architecture registry: the 10 assigned configurations, exactly as listed.

Sources are the public configs cited in the assignment; where the assignment
line and the upstream checkpoint disagree, the assignment line wins and the
deviation is noted in DESIGN.md §Arch-assumptions.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import (
    FrontendConfig,
    HybridConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
)

ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


PHI3_MEDIUM = _register(ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab_size=100352,
))

QWEN15_110B = _register(ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab_size=152064, qkv_bias=True,
))

SMOLLM_360M = _register(ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab_size=49152, tie_embeddings=True,
))

YI_9B = _register(ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab_size=64000,
))

LLAMA4_SCOUT = _register(ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
))

DEEPSEEK_V2_LITE = _register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
))

PALIGEMMA_3B = _register(ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    act="gelu", embed_scale=True, tie_embeddings=True,
    frontend=FrontendConfig(kind="vision_stub", n_prefix_tokens=256),
))

ZAMBA2_27B = _register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(version=2, d_state=64, headdim=64),
    hybrid=HybridConfig(period=6, shared_attn_heads=32, shared_attn_kv_heads=32),
))

MUSICGEN_LARGE = _register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    pos_embedding="sinusoidal",
    frontend=FrontendConfig(kind="audio_stub"),
))

FALCON_MAMBA_7B = _register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(version=1, d_state=16, expand=2),
))


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


# --- analytic parameter counting (no allocation: eval_shape over init) -----------


def _param_shapes(cfg: ModelConfig):
    from repro.models.model import init_params
    key = jax.eval_shape(lambda: jax.random.key(0))
    return jax.eval_shape(
        lambda k: init_params(k, cfg), jax.ShapeDtypeStruct(key.shape, key.dtype)
    )


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = _param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and cfg.moe is not None:
            keys = [getattr(p, "key", "") for p in path]
            if "experts" in keys:
                n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total
