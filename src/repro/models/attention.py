"""Attention: GQA + RoPE (+ QKV bias), MLA (DeepSeek-V2), prefix-LM masking.

Three implementations behind one switch:
  * "xla"     — dense masked attention (small sequences, smoke tests);
  * "chunked" — lax.scan over KV blocks with online softmax in pure jnp:
                O(S·chunk) memory, the dry-run-compatible sub-quadratic path
                for 32k prefill (XLA lowers it on any backend);
  * "pallas"  — the flash-attention kernel (TPU; interpret-validated on CPU).

Decode (single query token against a cache) is a separate, always-XLA path —
it is a matvec, and its roofline is HBM-bound cache streaming.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope

NEG_INF = -1e30


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)  # (B, H, S, d)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _mask(sq: int, skv: int, prefix_len: int = 0) -> jnp.ndarray:
    """Causal mask, optionally bidirectional over the first `prefix_len`
    positions (PaliGemma prefix-LM)."""
    rows = jnp.arange(sq)[:, None] + (skv - sq)   # absolute query positions
    cols = jnp.arange(skv)[None, :]
    allowed = cols <= rows
    if prefix_len > 0:
        allowed = allowed | (cols < prefix_len)
    return allowed


def _xla_attention(q, k, v, mask) -> jnp.ndarray:
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _chunked_attention(q, k, v, *, prefix_len: int, chunk: int = 512,
                       remat_chunk: bool = False,
                       q_sharding=None) -> jnp.ndarray:
    """Online-softmax over KV chunks (flash-attention in pure jnp/lax.scan).

    k and v may have different head dims (MLA: qk = nope+rope, v = v_dim).

    §Perf knobs:
      * ``remat_chunk`` — rematerialize the chunk body in the backward pass
        instead of saving the (B,H,Sq,chunk) probability tiles per step;
        trades ~1 extra forward of chunk compute for an O(S²/chunk)→O(S)
        reduction of saved residuals (the XLA-path analogue of the Pallas
        flash kernel's recomputed backward).
      * ``q_sharding`` — explicit sharding for the scaled query (sequence
        dim over the model axis): pins XLA to replicated-KV × local-scores
        partitioning instead of sharding the QK contraction (which inserts
        per-chunk score all-reduces).
    """
    b, h, sq, d = q.shape
    dv = v.shape[-1]
    skv = k.shape[2]
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(b, h, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    q32 = q.astype(jnp.float32) / (d ** 0.5)
    if q_sharding is not None:
        q32 = jax.lax.with_sharding_constraint(q32, q_sharding)
    rows = jnp.arange(sq)[:, None] + (skv - sq)

    def step(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kb.astype(jnp.float32))
        cols = idx * chunk + jnp.arange(chunk)[None, :]
        allowed = cols <= rows
        if prefix_len > 0:
            allowed = allowed | (cols < prefix_len)
        allowed = allowed & (cols < skv)
        s = jnp.where(allowed[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    if remat_chunk:
        step = jax.checkpoint(
            step, policy=jax.checkpoint_policies.nothing_saveable)

    m0 = jnp.full((b, h, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l).astype(q.dtype)


def multihead_attention(
    q: jnp.ndarray,           # (B, Hq, Sq, d)
    k: jnp.ndarray,           # (B, Hkv, Skv, d)
    v: jnp.ndarray,
    *,
    impl: str = "xla",
    prefix_len: int = 0,
    chunk: int = 512,
    remat_chunk: bool = False,
    q_sharding=None,
) -> jnp.ndarray:
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:  # GQA: repeat KV heads
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    if impl == "pallas":
        if prefix_len:
            raise NotImplementedError("prefix-LM uses xla/chunked")
        return kops.attention(q, k, v, causal=True, impl="pallas")
    if impl == "stub":
        # Measurement stub (§Perf flash substitution): preserves all shapes
        # and gradients at negligible FLOPs/traffic, so a cell compiled with
        # it isolates the everything-but-attention cost; the Pallas flash
        # kernel's analytic terms are then added back (launch/flashsub.py).
        o = jnp.mean(v, axis=2, keepdims=True) + 1e-6 * jnp.mean(
            q.astype(v.dtype), axis=-1, keepdims=True)
        return jnp.broadcast_to(
            o, q.shape[:3] + (v.shape[-1],)).astype(q.dtype)
    if impl == "chunked":
        return _chunked_attention(q, k, v, prefix_len=prefix_len, chunk=chunk,
                                  remat_chunk=remat_chunk,
                                  q_sharding=q_sharding)
    mask = _mask(q.shape[2], k.shape[2], prefix_len)
    return _xla_attention(q, k, v, mask)


# ---------------------------------------------------------------------------
# GQA block (dense/moe/vlm/audio families)
# ---------------------------------------------------------------------------


def gqa_project(x, p, cfg: ModelConfig, positions):
    """x -> rotated q, k, v with head split.  p: this layer's attn params."""
    dt = x.dtype
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k = apply_rope(k, positions[:, None], cfg.rope_theta)
    return q, k, v


def gqa_attention(x, p, cfg: ModelConfig, positions, *, impl="xla",
                  prefix_len=0, chunk=512, remat_chunk=False,
                  q_sharding=None) -> jnp.ndarray:
    q, k, v = gqa_project(x, p, cfg, positions)
    o = multihead_attention(q, k, v, impl=impl, prefix_len=prefix_len,
                            chunk=chunk, remat_chunk=remat_chunk,
                            q_sharding=q_sharding)
    return jnp.einsum("bsk,kd->bsd", _merge_heads(o), p["wo"].astype(x.dtype))


def gqa_decode(x, p, cfg: ModelConfig, k_cache, v_cache, pos):
    """One-token decode: update caches at `pos`, attend over cache[:pos+1].

    k_cache/v_cache: (B, Smax, Hkv*dh).  Returns (out, k_cache, v_cache).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = gqa_project(x, p, cfg, positions)            # (B,H,1,d)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, _merge_heads(k), (0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, _merge_heads(v), (0, pos, 0))
    kk = _split_heads(k_cache, cfg.n_kv_heads)             # (B,Hkv,Smax,d)
    vv = _split_heads(v_cache, cfg.n_kv_heads)
    hq = cfg.n_heads
    kk = jnp.repeat(kk, hq // cfg.n_kv_heads, axis=1)
    vv = jnp.repeat(vv, hq // cfg.n_kv_heads, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / (cfg.head_dim ** 0.5)
    valid = jnp.arange(k_cache.shape[1])[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1),
                   vv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", _merge_heads(o), p["wo"].astype(x.dtype))
    return out, k_cache, v_cache


def gqa_decode_ragged(x, p, cfg: ModelConfig, k_cache, v_cache, pos_b):
    """One-token decode with a *per-row* position (continuous batching).

    ``pos_b``: (B,) int32 — row b's cache is updated at ``pos_b[b]`` and
    attended over ``cache[b, :pos_b[b]+1]``, so slots whose requests joined
    the batch at different times (different prompt lengths / arrival steps)
    decode together in one program.  RoPE/positional encoding uses each
    row's own absolute position.  k_cache/v_cache: (B, Smax, Hkv*dh).
    """
    positions = pos_b[:, None]                              # (B, 1)
    q, k, v = gqa_project(x, p, cfg, positions)             # (B,H,1,d)
    upd = jax.vmap(
        lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (s, 0)))
    k_cache = upd(k_cache, _merge_heads(k), pos_b)
    v_cache = upd(v_cache, _merge_heads(v), pos_b)
    kk = _split_heads(k_cache, cfg.n_kv_heads)              # (B,Hkv,Smax,d)
    vv = _split_heads(v_cache, cfg.n_kv_heads)
    hq = cfg.n_heads
    kk = jnp.repeat(kk, hq // cfg.n_kv_heads, axis=1)
    vv = jnp.repeat(vv, hq // cfg.n_kv_heads, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / (cfg.head_dim ** 0.5)
    valid = (jnp.arange(k_cache.shape[1])[None, None, None, :]
             <= pos_b[:, None, None, None])
    s = jnp.where(valid, s, NEG_INF)
    o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1),
                   vv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", _merge_heads(o), p["wo"].astype(x.dtype))
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank KV compression; the cache stores only
# (c_kv, k_rope) — kv_lora_rank + rope_dim per token instead of 2·H·d.
# ---------------------------------------------------------------------------


def mla_project_q(x, p, cfg: ModelConfig, positions):
    m = cfg.mla
    dt = x.dtype
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(dt))
    q = q.reshape(x.shape[0], x.shape[1], cfg.n_heads,
                  m.qk_nope_head_dim + m.qk_rope_head_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions[:, None], cfg.rope_theta)
    return q_nope, q_rope


def mla_compress_kv(x, p, cfg: ModelConfig, positions):
    """x -> (c_kv normed, k_rope rotated): exactly what the MLA cache stores."""
    m = cfg.mla
    dt = x.dtype
    ckv = jnp.einsum("bsd,dk->bsk", x, p["wdkv"].astype(dt))
    c, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    from repro.models.layers import rms_norm
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :].transpose(0, 2, 1, 3),
                        positions[:, None], cfg.rope_theta)  # (B,1,S,rope)
    return c, k_rope


def mla_attention(x, p, cfg: ModelConfig, positions, *, impl="xla",
                  c=None, k_rope=None, chunk=512, remat_chunk=False,
                  q_sharding=None) -> jnp.ndarray:
    """Full-sequence MLA attention (c/k_rope may be precomputed for prefill)."""
    m = cfg.mla
    dt = x.dtype
    b, s, _ = x.shape
    if c is None:
        c, k_rope = mla_compress_kv(x, p, cfg, positions)
    q_nope, q_rope = mla_project_q(x, p, cfg, positions)
    k_nope = jnp.einsum("bsr,rk->bsk", c, p["wuk"].astype(dt))
    v = jnp.einsum("bsr,rk->bsk", c, p["wuv"].astype(dt))
    k_nope = _split_heads(k_nope, cfg.n_heads)
    v = _split_heads(v, cfg.n_heads)
    k_rope_b = jnp.broadcast_to(
        k_rope, (b, cfg.n_heads, k_rope.shape[2], m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = multihead_attention(q, k, v, impl=impl, chunk=chunk,
                            remat_chunk=remat_chunk, q_sharding=q_sharding)
    return jnp.einsum("bsk,kd->bsd", _merge_heads(o), p["wo"].astype(dt))


def mla_decode(x, p, cfg: ModelConfig, c_cache, rope_cache, pos):
    """One-token MLA decode against the compressed cache.

    c_cache: (B, Smax, rank); rope_cache: (B, Smax, rope_dim).
    """
    m = cfg.mla
    dt = x.dtype
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    c_new, k_rope_new = mla_compress_kv(x, p, cfg, positions)
    c_cache = jax.lax.dynamic_update_slice(c_cache, c_new, (0, pos, 0))
    rope_cache = jax.lax.dynamic_update_slice(
        rope_cache, k_rope_new[:, 0], (0, pos, 0))
    q_nope, q_rope = mla_project_q(x, p, cfg, positions)   # (B,H,1,·)

    # Absorb wuk into q (the MLA decode trick): score = (q_nope·wukᵀ)·c + q_rope·k_rope
    wuk = p["wuk"].astype(dt).reshape(m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim)
    q_c = jnp.einsum("bhqn,rhn->bhqr", q_nope, wuk)        # (B,H,1,rank)
    s = jnp.einsum("bhqr,bsr->bhqs", q_c.astype(jnp.float32),
                   c_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhqn,bsn->bhqs", q_rope.astype(jnp.float32),
                       rope_cache.astype(jnp.float32))
    s = s / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    valid = jnp.arange(c_cache.shape[1])[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    pattn = jax.nn.softmax(s, -1)
    o_c = jnp.einsum("bhqs,bsr->bhqr", pattn, c_cache.astype(jnp.float32))
    wuv = p["wuv"].astype(dt).reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
    o = jnp.einsum("bhqr,rhn->bhqn", o_c.astype(dt), wuv)  # (B,H,1,v_dim)
    out = jnp.einsum("bsk,kd->bsd", _merge_heads(o), p["wo"].astype(dt))
    return out, c_cache, rope_cache
