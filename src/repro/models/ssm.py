"""State-space blocks: Mamba-1 (S6 selective scan) and Mamba-2 (SSD).

Both reduce to the first-order linear recurrence h_t = a_t ⊙ h_{t-1} + b_t,
computed by a *chunked* scan: sequential ``lax.scan`` over fixed-size chunks
with a parallel ``associative_scan`` inside each chunk.  Chunking bounds the
materialized state history to (B, chunk, ...) — the TPU adaptation of
Mamba's kernel: VMEM-sized chunks instead of CUDA shared-memory tiles — and
is what lets falcon-mamba prefill 32k tokens without an O(S·d_inner·d_state)
blow-up.  Decode is the single-step recurrence on a carried state.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import gated_rms_norm


# --- the shared recurrence engine -----------------------------------------------


def _assoc(elem1, elem2):
    a1, b1 = elem1
    a2, b2 = elem2
    return a2 * a1, a2 * b1 + b2


def chunked_linear_recurrence(
    a: jnp.ndarray,      # (B, S, ...) decay per step
    b: jnp.ndarray,      # (B, S, ...) input per step
    h0: jnp.ndarray,     # (B, ...)    initial state
    chunk: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + b_t  ->  (all h_t : (B, S, ...), final state)."""
    B, S = a.shape[0], a.shape[1]
    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    nc = (S + pad) // chunk
    a_c = a.reshape((B, nc, chunk) + a.shape[2:]).swapaxes(0, 1)
    b_c = b.reshape((B, nc, chunk) + b.shape[2:]).swapaxes(0, 1)

    def step(h, ab):
        ac, bc = ab                                   # (B, chunk, ...)
        cum_a, cum_b = jax.lax.associative_scan(_assoc, (ac, bc), axis=1)
        h_all = cum_a * h[:, None] + cum_b            # (B, chunk, ...)
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(step, h0, (a_c, b_c))
    # a may be a broadcast-shaped decay (e.g. (B,S,H,1,1) against (B,S,H,P,N));
    # take the trailing dims from the materialized states.
    trailing = h_chunks.shape[3:]
    h_seq = h_chunks.swapaxes(0, 1).reshape((B, nc * chunk) + trailing)
    return h_seq[:, :S], h_last


# --- causal depthwise conv (k small, unrolled shifts) -----------------------------


def causal_conv(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C); w: (C, K); y_t = Σ_j w[:, j]·x_{t-K+1+j} + bias."""
    k = w.shape[-1]
    out = x * w[:, -1]
    for j in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[:, -1 - j]
    return out + bias


def conv_decode(x_new: jnp.ndarray, conv_state: jnp.ndarray,
                w: jnp.ndarray, bias: jnp.ndarray):
    """One-step conv: state (B, K-1, C) holds the last K-1 inputs."""
    window = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window, w) + bias
    return y, window[:, 1:]


# --- Mamba-1 (S6) -------------------------------------------------------------------


def _mamba1_gates(xc, p, cfg: ModelConfig):
    """Post-conv x -> (a, b_in, C_t) of the recurrence + dt for later use."""
    s1 = cfg.ssm
    dt_rank = s1.dt_rank or -(-cfg.d_model // 16)
    dbc = jnp.einsum("bsd,dk->bsk", xc, p["x_proj"].astype(xc.dtype))
    dt_low, B_t, C_t = jnp.split(dbc, [dt_rank, dt_rank + s1.d_state], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_low, p["dt_proj"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (din, N)
    a = jnp.exp(dt[..., None] * A)                            # (B,S,din,N)
    b = (dt * xc.astype(jnp.float32))[..., None] * B_t.astype(jnp.float32)[:, :, None, :]
    return a, b, C_t


def _chunk_inputs(arrs, chunk: int):
    """(B, S, ...) arrays -> (nc, B, chunk, ...) with zero padding."""
    B, S = arrs[0].shape[:2]
    pad = (-S) % chunk
    out = []
    for a in arrs:
        if pad:
            a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        nc = (S + pad) // chunk
        out.append(a.reshape((B, nc, chunk) + a.shape[2:]).swapaxes(0, 1))
    return out


def mamba1_block(x, p, cfg: ModelConfig, return_state: bool = False):
    """(B, S, D) -> (B, S, D); full-sequence S6.  With ``return_state``,
    also returns (conv_tail, h_last) for priming a decode cache.

    The (B, chunk, d_inner, d_state) gate tensors are built *inside* the
    chunk scan, so the O(S·d_inner·d_state) blow-up never materializes —
    peak state memory is one chunk (the VMEM-tile adaptation of the Mamba
    CUDA kernel, DESIGN.md §7)."""
    s1 = cfg.ssm
    B, S = x.shape[0], x.shape[1]
    dt_rank = s1.dt_rank or -(-cfg.d_model // 16)
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv(x_in, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype)))
    dbc = jnp.einsum("bsd,dk->bsk", xc, p["x_proj"].astype(xc.dtype))
    dt_low, B_t, C_t = jnp.split(dbc, [dt_rank, dt_rank + s1.d_state], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_low, p["dt_proj"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (din, N)

    xc_c, dt_c, B_c, C_c = _chunk_inputs(
        [xc.astype(jnp.float32), dt, B_t.astype(jnp.float32),
         C_t.astype(jnp.float32)], s1.chunk)

    def chunk_body(h, inp):
        xcc, dtc, Bc, Cc = inp                         # (B, chunk, ...)
        a = jnp.exp(dtc[..., None] * A)                # (B, chunk, din, N)
        b = (dtc * xcc)[..., None] * Bc[:, :, None, :]
        cum_a, cum_b = jax.lax.associative_scan(_assoc, (a, b), axis=1)
        h_all = cum_a * h[:, None] + cum_b
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Cc)
        return h_all[:, -1], y

    h0 = jnp.zeros((B, cfg.d_inner, s1.d_state), jnp.float32)
    h_last, y_c = jax.lax.scan(chunk_body, h0, (xc_c, dt_c, B_c, C_c))
    y = y_c.swapaxes(0, 1).reshape(B, -1, cfg.d_inner)[:, :S]
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        return out, (x_in[:, -(s1.d_conv - 1):], h_last)
    return out


def mamba1_decode(x, p, cfg: ModelConfig, conv_state, h):
    """x: (B, 1, D); returns (y, conv_state, h)."""
    s1 = cfg.ssm
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    x_in, z = jnp.split(xz[:, 0], 2, axis=-1)                 # (B, din)
    xc_flat, conv_state = conv_decode(x_in, conv_state,
                                      p["conv_w"].astype(x.dtype),
                                      p["conv_b"].astype(x.dtype))
    xc = jax.nn.silu(xc_flat)[:, None]                        # (B,1,din)
    a, b, C_t = _mamba1_gates(xc, p, cfg)
    h = a[:, 0] * h + b[:, 0]                                 # (B,din,N)
    y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)[:, None]
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype)), conv_state, h


# --- Mamba-2 (SSD, groups=1) ----------------------------------------------------------


def _mamba2_split(cfg: ModelConfig):
    s2 = cfg.ssm
    din = cfg.d_inner
    h = din // s2.headdim
    return din, h, s2.headdim, s2.d_state


def _mamba2_gates(xbc, dt_raw, p, cfg: ModelConfig):
    din, H, P, N = _mamba2_split(cfg)
    x_c, B_c, C_c = jnp.split(xbc, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    a = jnp.exp(dt * A)                                       # (B,S,H)
    xh = x_c.reshape(x_c.shape[:-1] + (H, P))
    b = (dt[..., None] * xh.astype(jnp.float32))[..., None] \
        * B_c.astype(jnp.float32)[:, :, None, None, :]        # (B,S,H,P,N)
    return a[..., None, None], b, xh, C_c


def mamba2_block(x, p, cfg: ModelConfig, return_state: bool = False):
    """Mamba-2 SSD with the same chunk-internal gate construction as
    mamba1_block (states exist one chunk at a time)."""
    s2 = cfg.ssm
    din, H, P, N = _mamba2_split(cfg)
    B, S = x.shape[0], x.shape[1]
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xbc_raw, dt_raw = jnp.split(proj, [din, 2 * din + 2 * N], axis=-1)
    xbc = jax.nn.silu(causal_conv(xbc_raw, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype)))
    x_c, B_t, C_t = jnp.split(xbc, [din, din + N], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    xh = x_c.reshape(B, S, H, P)

    xh_c, dt_c, B_c, C_c = _chunk_inputs(
        [xh.astype(jnp.float32), dt, B_t.astype(jnp.float32),
         C_t.astype(jnp.float32)], s2.chunk)

    def chunk_body(h, inp):
        xhc, dtc, Bc, Cc = inp
        a = jnp.exp(dtc * A)[..., None, None]          # (B, chunk, H, 1, 1)
        b = (dtc[..., None] * xhc)[..., None] * Bc[:, :, None, None, :]
        cum_a, cum_b = jax.lax.associative_scan(_assoc, (a, b), axis=1)
        h_all = cum_a * h[:, None] + cum_b             # (B, chunk, H, P, N)
        y = jnp.einsum("bchpn,bcn->bchp", h_all, Cc)
        return h_all[:, -1], y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_last, y_c = jax.lax.scan(chunk_body, h0, (xh_c, dt_c, B_c, C_c))
    y = y_c.swapaxes(0, 1).reshape(B, -1, H, P)[:, :S]
    y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, din).astype(x.dtype)
    y = gated_rms_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        return out, (xbc_raw[:, -(s2.d_conv - 1):], h_last)
    return out


def mamba2_decode(x, p, cfg: ModelConfig, conv_state, h):
    s2 = cfg.ssm
    din, H, P, N = _mamba2_split(cfg)
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = jnp.split(proj[:, 0], [din, 2 * din + 2 * N], axis=-1)
    xbc_flat, conv_state = conv_decode(xbc, conv_state,
                                       p["conv_w"].astype(x.dtype),
                                       p["conv_b"].astype(x.dtype))
    xbc1 = jax.nn.silu(xbc_flat)[:, None]
    a, b, xh, C_c = _mamba2_gates(xbc1, dt_raw[:, None], p, cfg)
    h = a[:, 0] * h + b[:, 0]                                 # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", h, C_c[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[:, None] * xh[:, 0].astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, din).astype(x.dtype)
    y = gated_rms_norm(y, z[:, None], p["norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype)), conv_state, h
