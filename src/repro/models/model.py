"""Model assembly: init / train forward / prefill / decode for every family.

Design rules (DESIGN.md §7):
  * parameters are stacked over layers (leading L axis) and the forward pass
    is one ``lax.scan`` over the stack -> HLO and compile time are O(1) in
    depth (an 80-layer 110B config lowers as fast as an 18-layer 3B one);
  * the scan body is rematerialized (``jax.checkpoint``, nothing saveable):
    live activations are the per-layer carries only;
  * heterogeneity (Zamba2's shared attention block, prefix-LM masks) lives
    *inside* the homogeneous scan via ``lax.cond`` on the layer index, so the
    stack stays scannable;
  * every entry point is a pure function of (params, batch) — the launch
    layer owns shardings; optional ``residual_spec`` forces sequence-parallel
    residuals between layers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    dense_init,
    gated_mlp,
    rms_norm,
    sinusoidal_positions,
    softcap,
)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class CallConfig:
    """Per-call knobs owned by the launcher, not the architecture."""

    attn_impl: str = "xla"          # "xla" | "chunked" | "pallas"
    attn_chunk: int = 512
    remat: bool = True
    residual_spec: Optional[Any] = None   # PartitionSpec for the residual
    moe_no_drop: bool = False       # exact MoE routing (serving / eval)
    # --- §Perf hillclimbing knobs (EXPERIMENTS.md) -------------------------
    attn_chunk_remat: bool = False  # recompute chunk bodies in backward
    attn_q_sharding: Optional[Any] = None   # NamedSharding for scaled q
    cast_params_once: bool = False  # bf16 weight copy before the layer scan
    moe_buffer_sharding: Optional[Any] = None  # EP constraint on (E,C,D)


# =============================================================================
# Initialization
# =============================================================================


def _attn_params(key, cfg: ModelConfig, L: int, heads: int, kv_heads: int,
                 head_dim: int, dtype) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], (L, d, heads * head_dim), -2, dtype),
        "wk": dense_init(ks[1], (L, d, kv_heads * head_dim), -2, dtype),
        "wv": dense_init(ks[2], (L, d, kv_heads * head_dim), -2, dtype),
        "wo": dense_init(ks[3], (L, heads * head_dim, d), -2, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((L, heads * head_dim), dtype)
        p["bk"] = jnp.zeros((L, kv_heads * head_dim), dtype)
        p["bv"] = jnp.zeros((L, kv_heads * head_dim), dtype)
    return p


def _mla_params(key, cfg: ModelConfig, L: int, dtype) -> Params:
    m = cfg.mla
    ks = jax.random.split(key, 5)
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wq": dense_init(ks[0], (L, d, h * (m.qk_nope_head_dim + m.qk_rope_head_dim)), -2, dtype),
        "wdkv": dense_init(ks[1], (L, d, m.kv_lora_rank + m.qk_rope_head_dim), -2, dtype),
        "kv_norm": jnp.ones((L, m.kv_lora_rank), dtype),
        "wuk": dense_init(ks[2], (L, m.kv_lora_rank, h * m.qk_nope_head_dim), -2, dtype),
        "wuv": dense_init(ks[3], (L, m.kv_lora_rank, h * m.v_head_dim), -2, dtype),
        "wo": dense_init(ks[4], (L, h * m.v_head_dim, d), -2, dtype),
    }


def _mlp_params(key, L: int, d: int, f: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], (L, d, f), -2, dtype),
        "wg": dense_init(ks[1], (L, d, f), -2, dtype),
        "wo": dense_init(ks[2], (L, f, d), -2, dtype),
    }


def _moe_params(key, cfg: ModelConfig, L: int, dtype) -> Params:
    m = cfg.moe
    ks = jax.random.split(key, 5)
    d, e, fe = cfg.d_model, m.n_experts, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], (L, d, e), -2, jnp.float32),
        "experts": {
            "wi": dense_init(ks[1], (L, e, d, fe), -2, dtype),
            "wg": dense_init(ks[2], (L, e, d, fe), -2, dtype),
            "wo": dense_init(ks[3], (L, e, fe, d), -2, dtype),
        },
    }
    if m.n_shared:
        p["shared"] = _mlp_params(ks[4], L, d, m.n_shared * fe, dtype)
    return p


def _mamba1_params(key, cfg: ModelConfig, L: int, dtype) -> Params:
    s = cfg.ssm
    din, d, N = cfg.d_inner, cfg.d_model, s.d_state
    R = s.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (L, d, 2 * din), -2, dtype),
        "conv_w": dense_init(ks[1], (L, din, s.d_conv), -1, dtype),
        "conv_b": jnp.zeros((L, din), dtype),
        "x_proj": dense_init(ks[2], (L, din, R + 2 * N), -2, dtype),
        "dt_proj": dense_init(ks[3], (L, R, din), -2, dtype),
        "dt_bias": jnp.full((L, din), -4.6, dtype),    # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (L, din, N))).astype(dtype),
        "D": jnp.ones((L, din), dtype),
        "out_proj": dense_init(ks[4], (L, din, d), -2, dtype),
    }


def _mamba2_params(key, cfg: ModelConfig, L: int, dtype) -> Params:
    s = cfg.ssm
    din, d, N = cfg.d_inner, cfg.d_model, s.d_state
    H = din // s.headdim
    conv_dim = din + 2 * N
    ks = jax.random.split(key, 3)
    return {
        "in_proj": dense_init(ks[0], (L, d, 2 * din + 2 * N + H), -2, dtype),
        "conv_w": dense_init(ks[1], (L, conv_dim, s.d_conv), -1, dtype),
        "conv_b": jnp.zeros((L, conv_dim), dtype),
        "A_log": jnp.zeros((L, H), dtype),
        "D": jnp.ones((L, H), dtype),
        "dt_bias": jnp.full((L, H), -4.6, dtype),
        "norm": jnp.ones((L, din), dtype),
        "out_proj": dense_init(ks[2], (L, din, d), -2, dtype),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    L = cfg.n_layers
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), -1, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), -2, dtype)

    layer: Params = {}
    if cfg.family == "ssm":
        layer["ln"] = jnp.ones((L, cfg.d_model), dtype)
        layer["mixer"] = _mamba1_params(keys[2], cfg, L, dtype)
    elif cfg.family == "hybrid":
        layer["ln"] = jnp.ones((L, cfg.d_model), dtype)
        layer["mixer"] = _mamba2_params(keys[2], cfg, L, dtype)
        hb = cfg.hybrid
        hd = cfg.d_model // hb.shared_attn_heads
        shared_cfg = dataclasses.replace(
            cfg, n_heads=hb.shared_attn_heads, n_kv_heads=hb.shared_attn_kv_heads,
            head_dim=hd, qkv_bias=False)
        params["shared_block"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": jax.tree.map(
                lambda a: a[0],
                _attn_params(keys[3], shared_cfg, 1, hb.shared_attn_heads,
                             hb.shared_attn_kv_heads, hd, dtype)),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": jax.tree.map(lambda a: a[0],
                                _mlp_params(keys[4], 1, cfg.d_model, cfg.d_ff, dtype)),
        }
    else:
        layer["ln1"] = jnp.ones((L, cfg.d_model), dtype)
        layer["ln2"] = jnp.ones((L, cfg.d_model), dtype)
        if cfg.mla:
            layer["attn"] = _mla_params(keys[2], cfg, L, dtype)
        else:
            layer["attn"] = _attn_params(
                keys[2], cfg, L, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype)
        if cfg.moe:
            layer["moe"] = _moe_params(keys[3], cfg, L, dtype)
        else:
            layer["mlp"] = _mlp_params(keys[3], L, cfg.d_model, cfg.d_ff, dtype)
    params["layers"] = layer
    return params


# =============================================================================
# Embedding / unembedding
# =============================================================================


def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """-> (x, positions, prefix_len).  Handles the stub modality frontends."""
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    prefix_len = 0
    if cfg.frontend and cfg.frontend.kind == "vision_stub":
        patches = batch["patches"].astype(dt)       # precomputed (B, P, D)
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.pos_embedding == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(dt)
    return x, positions, prefix_len


def unembed(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps,
                 plus_one=cfg.embed_scale)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


# =============================================================================
# Train / prefill forward (scan over the layer stack)
# =============================================================================


def _shared_attn_block(x, params, cfg: ModelConfig, positions, call: CallConfig):
    """Zamba2's shared transformer block (weights reused every application)."""
    hb = cfg.hybrid
    shared_cfg = dataclasses.replace(
        cfg, n_heads=hb.shared_attn_heads, n_kv_heads=hb.shared_attn_kv_heads,
        head_dim=cfg.d_model // hb.shared_attn_heads, qkv_bias=False)
    sb = params["shared_block"]
    h = rms_norm(x, sb["ln1"], cfg.norm_eps)
    x = x + attn.gqa_attention(h, sb["attn"], shared_cfg, positions,
                               impl=call.attn_impl, chunk=call.attn_chunk,
                               remat_chunk=call.attn_chunk_remat)
    h = rms_norm(x, sb["ln2"], cfg.norm_eps)
    return x + gated_mlp(h, sb["mlp"]["wi"], sb["mlp"]["wg"], sb["mlp"]["wo"],
                         cfg.act)


def _constrain(x, call: CallConfig):
    if call.residual_spec is not None:
        x = jax.lax.with_sharding_constraint(x, call.residual_spec)
    return x


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            call: CallConfig = CallConfig()) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward pass -> (logits f32, aux_loss)."""
    if call.cast_params_once:
        # One compute-dtype weight copy per step, sharded like the originals:
        # the layer scan then gathers/reads 2-byte weights instead of 4-byte
        # (halves FSDP gather traffic + weight HBM reads; §Perf move M2).
        dt = jnp.dtype(cfg.compute_dtype)
        params = dict(params, layers=jax.tree.map(
            lambda a: a.astype(dt) if a.dtype == jnp.float32 else a,
            params["layers"]))
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    L = cfg.n_layers

    def body(x, xs):
        lp, idx = xs
        aux = jnp.float32(0.0)
        if cfg.family == "ssm":
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            x = x + ssm_lib.mamba1_block(h, lp["mixer"], cfg)
        elif cfg.family == "hybrid":
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            x = x + ssm_lib.mamba2_block(h, lp["mixer"], cfg)
            period = cfg.hybrid.period
            x = jax.lax.cond(
                (idx + 1) % period == 0,
                lambda v: _shared_attn_block(v, params, cfg, positions, call),
                lambda v: v,
                x,
            )
        else:
            h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.embed_scale)
            if cfg.mla:
                x = x + attn.mla_attention(h, lp["attn"], cfg, positions,
                                           impl=call.attn_impl,
                                           chunk=call.attn_chunk,
                                           remat_chunk=call.attn_chunk_remat,
                                           q_sharding=call.attn_q_sharding)
            else:
                x = x + attn.gqa_attention(h, lp["attn"], cfg, positions,
                                           impl=call.attn_impl,
                                           prefix_len=prefix_len,
                                           chunk=call.attn_chunk,
                                           remat_chunk=call.attn_chunk_remat,
                                           q_sharding=call.attn_q_sharding)
            h = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.embed_scale)
            if cfg.moe:
                delta, aux = moe_lib.moe_block(h, lp["moe"], cfg,
                                               no_drop=call.moe_no_drop,
                                               buffer_sharding=call.moe_buffer_sharding)
                x = x + delta
            else:
                x = x + gated_mlp(h, lp["mlp"]["wi"], lp["mlp"]["wg"],
                                  lp["mlp"]["wo"], cfg.act)
        return _constrain(x, call), aux

    if call.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, aux = jax.lax.scan(body, x, (params["layers"], jnp.arange(L)))
    return unembed(params, cfg, x), jnp.sum(aux)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            call: CallConfig = CallConfig()) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross entropy (text positions only for VLM) + MoE aux."""
    logits, aux = forward(params, cfg, batch, call)
    labels = batch["labels"]
    if cfg.frontend and cfg.frontend.kind == "vision_stub":
        logits = logits[:, -labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = nll + aux
    return total, {"nll": nll, "aux": aux}


# =============================================================================
# KV / state caches
# =============================================================================


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype_str: Optional[str] = None) -> Params:
    dt = jnp.dtype(dtype_str or cfg.compute_dtype)
    L = cfg.n_layers
    if cfg.family == "ssm":
        s = cfg.ssm
        return {
            "conv": jnp.zeros((L, batch_size, s.d_conv - 1, cfg.d_inner), dt),
            "h": jnp.zeros((L, batch_size, cfg.d_inner, s.d_state), jnp.float32),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        s = cfg.ssm
        H = cfg.d_inner // s.headdim
        A = cfg.n_layers // cfg.hybrid.period
        hb = cfg.hybrid
        kvd = hb.shared_attn_kv_heads * (cfg.d_model // hb.shared_attn_heads)
        return {
            "conv": jnp.zeros((L, batch_size, s.d_conv - 1,
                               cfg.d_inner + 2 * s.d_state), dt),
            "h": jnp.zeros((L, batch_size, H, s.headdim, s.d_state), jnp.float32),
            "k": jnp.zeros((A, batch_size, max_len, kvd), dt),
            "v": jnp.zeros((A, batch_size, max_len, kvd), dt),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.mla:
        m = cfg.mla
        return {
            "c": jnp.zeros((L, batch_size, max_len, m.kv_lora_rank), dt),
            "krope": jnp.zeros((L, batch_size, max_len, m.qk_rope_head_dim), dt),
            "pos": jnp.zeros((), jnp.int32),
        }
    kvd = cfg.n_kv_heads * cfg.head_dim
    return {
        "k": jnp.zeros((L, batch_size, max_len, kvd), dt),
        "v": jnp.zeros((L, batch_size, max_len, kvd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


# =============================================================================
# Decode step (one token, cache-carried)
# =============================================================================


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, call: CallConfig = CallConfig()
                ) -> Tuple[jnp.ndarray, Params]:
    """tokens: (B, 1) -> (logits (B, 1, V) f32, updated cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.pos_embedding == "sinusoidal":
        positions = jnp.full((b, 1), pos, jnp.int32)
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(dt)

    if cfg.family == "ssm":
        def body(x, xs):
            lp, conv, h = xs
            hin = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, conv, h = ssm_lib.mamba1_decode(hin, lp["mixer"], cfg, conv, h)
            return x + y, (conv, h)

        x, (conv, h) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["h"]))
        new_cache = {"conv": conv, "h": h, "pos": pos + 1}

    elif cfg.family == "hybrid":
        hb = cfg.hybrid
        period = hb.period
        shared_cfg = dataclasses.replace(
            cfg, n_heads=hb.shared_attn_heads, n_kv_heads=hb.shared_attn_kv_heads,
            head_dim=cfg.d_model // hb.shared_attn_heads, qkv_bias=False)
        sb = params["shared_block"]

        def body(carry, xs):
            x, kc, vc = carry
            lp, conv, h, idx = xs
            hin = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, conv, h = ssm_lib.mamba2_decode(hin, lp["mixer"], cfg, conv, h)
            x = x + y

            def apply_shared(args):
                x, kc, vc = args
                app = idx // period
                k_app = jax.lax.dynamic_index_in_dim(kc, app, 0, keepdims=False)
                v_app = jax.lax.dynamic_index_in_dim(vc, app, 0, keepdims=False)
                hh = rms_norm(x, sb["ln1"], cfg.norm_eps)
                o, k_app, v_app = attn.gqa_decode(
                    hh, sb["attn"], shared_cfg, k_app, v_app, pos)
                x = x + o
                hh = rms_norm(x, sb["ln2"], cfg.norm_eps)
                x = x + gated_mlp(hh, sb["mlp"]["wi"], sb["mlp"]["wg"],
                                  sb["mlp"]["wo"], cfg.act)
                kc = jax.lax.dynamic_update_index_in_dim(kc, k_app, app, 0)
                vc = jax.lax.dynamic_update_index_in_dim(vc, v_app, app, 0)
                return x, kc, vc

            x, kc, vc = jax.lax.cond(
                (idx + 1) % period == 0, apply_shared, lambda a: a, (x, kc, vc))
            return (x, kc, vc), (conv, h)

        (x, kc, vc), (conv, h) = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], cache["conv"], cache["h"],
             jnp.arange(cfg.n_layers)))
        new_cache = {"conv": conv, "h": h, "k": kc, "v": vc, "pos": pos + 1}

    elif cfg.mla:
        def body(x, xs):
            lp, c, kr = xs
            hin = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, c, kr = attn.mla_decode(hin, lp["attn"], cfg, c, kr, pos)
            x = x + o
            hin = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.moe:
                delta, _ = moe_lib.moe_block(hin, lp["moe"], cfg, no_drop=True)
                x = x + delta
            else:
                x = x + gated_mlp(hin, lp["mlp"]["wi"], lp["mlp"]["wg"],
                                  lp["mlp"]["wo"], cfg.act)
            return x, (c, kr)

        x, (c, kr) = jax.lax.scan(
            body, x, (params["layers"], cache["c"], cache["krope"]))
        new_cache = {"c": c, "krope": kr, "pos": pos + 1}

    else:
        def body(x, xs):
            lp, kcl, vcl = xs
            hin = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.embed_scale)
            o, kcl, vcl = attn.gqa_decode(hin, lp["attn"], cfg, kcl, vcl, pos)
            x = x + o
            hin = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.embed_scale)
            if cfg.moe:
                delta, _ = moe_lib.moe_block(hin, lp["moe"], cfg, no_drop=True)
                x = x + delta
            else:
                x = x + gated_mlp(hin, lp["mlp"]["wi"], lp["mlp"]["wg"],
                                  lp["mlp"]["wo"], cfg.act)
            return x, (kcl, vcl)

        x, (kc, vc) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": kc, "v": vc, "pos": pos + 1}

    return unembed(params, cfg, x), new_cache


def decode_step_ragged(params: Params, cfg: ModelConfig, cache: Params,
                       tokens: jnp.ndarray, pos_b: jnp.ndarray,
                       call: CallConfig = CallConfig()
                       ) -> Tuple[jnp.ndarray, Params]:
    """One decode step with *per-row* positions (continuous batching).

    ``pos_b``: (B,) int32 — each batch row writes its KV at its own cache
    position and attends over its own prefix, so rows at different
    generation depths (late-joining requests, different prompt lengths)
    share one program.  Attention families only: SSM/hybrid state caches
    are position-free recurrences whose shared scan carry cannot be
    row-shifted, and MLA keeps the uniform-``pos`` path for now.
    """
    if cfg.family in ("ssm", "hybrid") or cfg.mla or cfg.frontend:
        raise NotImplementedError(
            "ragged decode is implemented for the plain attention family "
            "only (no SSM/hybrid/MLA state, no modality-prefix frontends)")
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.pos_embedding == "sinusoidal":
        x = x + sinusoidal_positions(pos_b[:, None], cfg.d_model).astype(dt)

    def body(x, xs):
        lp, kcl, vcl = xs
        hin = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.embed_scale)
        o, kcl, vcl = attn.gqa_decode_ragged(hin, lp["attn"], cfg, kcl, vcl,
                                             pos_b)
        x = x + o
        hin = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.embed_scale)
        if cfg.moe:
            delta, _ = moe_lib.moe_block(hin, lp["moe"], cfg, no_drop=True)
            x = x + delta
        else:
            x = x + gated_mlp(hin, lp["mlp"]["wi"], lp["mlp"]["wg"],
                              lp["mlp"]["wo"], cfg.act)
        return x, (kcl, vcl)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    new_cache = {"k": kc, "v": vc, "pos": jnp.max(pos_b) + 1}
    return unembed(params, cfg, x), new_cache


# =============================================================================
# Prefill: forward + cache population
# =============================================================================


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            max_len: int, call: CallConfig = CallConfig()
            ) -> Tuple[jnp.ndarray, Params]:
    """Process a full prompt, returning (last-position logits, primed cache)."""
    if call.cast_params_once:
        dtc = jnp.dtype(cfg.compute_dtype)
        params = dict(params, layers=jax.tree.map(
            lambda a: a.astype(dtc) if a.dtype == jnp.float32 else a,
            params["layers"]))
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    dt = jnp.dtype(cfg.compute_dtype)

    if cfg.family == "ssm":
        def body(x, lp):
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, (conv_tail, h_last) = ssm_lib.mamba1_block(
                h, lp["mixer"], cfg, return_state=True)
            return x + y, (conv_tail, h_last)

        if call.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, (conv, h) = jax.lax.scan(body, x, params["layers"])
        cache = {"conv": conv, "h": h, "pos": jnp.asarray(s, jnp.int32)}
        return unembed(params, cfg, x[:, -1:]), cache

    if cfg.family == "hybrid":
        # Mamba-2 layers run full-sequence; the shared attention block's KV
        # cache rides the scan carry (written at its application index).
        cache = init_cache(cfg, b, max_len)
        period = cfg.hybrid.period

        def body(carry, xs):
            x, kc, vc = carry
            lp, idx = xs
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, (conv_tail, h_last) = ssm_lib.mamba2_block(
                h, lp["mixer"], cfg, return_state=True)
            x = x + y

            def apply_shared(args):
                x, kc, vc = args
                hb = cfg.hybrid
                shared_cfg = dataclasses.replace(
                    cfg, n_heads=hb.shared_attn_heads,
                    n_kv_heads=hb.shared_attn_kv_heads,
                    head_dim=cfg.d_model // hb.shared_attn_heads, qkv_bias=False)
                sb = params["shared_block"]
                hh = rms_norm(x, sb["ln1"], cfg.norm_eps)
                q, k, v = attn.gqa_project(hh, sb["attn"], shared_cfg, positions)
                o = attn.multihead_attention(q, k, v, impl=call.attn_impl)
                x = x + jnp.einsum("bsk,kd->bsd", attn._merge_heads(o),
                                   sb["attn"]["wo"].astype(dt))
                hh = rms_norm(x, sb["ln2"], cfg.norm_eps)
                x = x + gated_mlp(hh, sb["mlp"]["wi"], sb["mlp"]["wg"],
                                  sb["mlp"]["wo"], cfg.act)
                app = idx // period
                km = attn._merge_heads(k).astype(kc.dtype)
                vm = attn._merge_heads(v).astype(vc.dtype)
                kc = jax.lax.dynamic_update_slice(
                    kc, jax.lax.dynamic_update_slice(
                        jax.lax.dynamic_index_in_dim(kc, app, 0, keepdims=False),
                        km, (0, 0, 0))[None], (app, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    vc, jax.lax.dynamic_update_slice(
                        jax.lax.dynamic_index_in_dim(vc, app, 0, keepdims=False),
                        vm, (0, 0, 0))[None], (app, 0, 0, 0))
                return x, kc, vc

            x, kc, vc = jax.lax.cond(
                (idx + 1) % period == 0, apply_shared, lambda a: a, (x, kc, vc))
            return (x, kc, vc), (conv_tail, h_last)

        (x, kc, vc), (conv, h) = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        cache = {"conv": conv, "h": h, "k": kc, "v": vc,
                 "pos": jnp.asarray(s, jnp.int32)}
        return unembed(params, cfg, x[:, -1:]), cache

    # Attention families: run the train forward while collecting K/V (or MLA
    # compressed states) per layer.
    def body(x, xs):
        lp, idx = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.embed_scale)
        if cfg.mla:
            c, krope = attn.mla_compress_kv(h, lp["attn"], cfg, positions)
            x = x + attn.mla_attention(h, lp["attn"], cfg, positions,
                                       impl=call.attn_impl, c=c, k_rope=krope,
                                       chunk=call.attn_chunk,
                                       remat_chunk=call.attn_chunk_remat,
                                       q_sharding=call.attn_q_sharding)
            stash = (c, krope[:, 0])
        else:
            q, k, v = attn.gqa_project(h, lp["attn"], cfg, positions)
            o = attn.multihead_attention(q, k, v, impl=call.attn_impl,
                                         prefix_len=prefix_len,
                                         chunk=call.attn_chunk,
                                         remat_chunk=call.attn_chunk_remat,
                                         q_sharding=call.attn_q_sharding)
            x = x + jnp.einsum("bsk,kd->bsd", attn._merge_heads(o),
                               lp["attn"]["wo"].astype(dt))
            stash = (attn._merge_heads(k), attn._merge_heads(v))
        h = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.embed_scale)
        if cfg.moe:
            delta, _ = moe_lib.moe_block(h, lp["moe"], cfg,
                                         no_drop=call.moe_no_drop)
            x = x + delta
        else:
            x = x + gated_mlp(h, lp["mlp"]["wi"], lp["mlp"]["wg"],
                              lp["mlp"]["wo"], cfg.act)
        return x, stash

    if call.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, stash = jax.lax.scan(body, x, (params["layers"], jnp.arange(cfg.n_layers)))

    cache = init_cache(cfg, b, max_len)
    seq = x.shape[1]
    if cfg.mla:
        cache["c"] = jax.lax.dynamic_update_slice(
            cache["c"], stash[0].astype(cache["c"].dtype), (0, 0, 0, 0))
        cache["krope"] = jax.lax.dynamic_update_slice(
            cache["krope"], stash[1].astype(cache["krope"].dtype), (0, 0, 0, 0))
    else:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], stash[0].astype(cache["k"].dtype), (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], stash[1].astype(cache["v"].dtype), (0, 0, 0, 0))
    cache["pos"] = jnp.asarray(seq, jnp.int32)
    return unembed(params, cfg, x[:, -1:]), cache
