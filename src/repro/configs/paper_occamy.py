"""The paper's own evaluation platform: Occamy (§3.1) — 1 CVA6 host +
8 quadrants × 4 clusters × (8 compute + 1 DMA) Snitch cores, and the six
benchmark kernels of §5.1 with the measured machine constants of §5.5.
"""

from repro.core.jobs import PAPER_JOBS  # noqa: F401
from repro.core.params import DEFAULT_PARAMS, OccamyParams  # noqa: F401

NAME = "occamy"
CONFIG = DEFAULT_PARAMS
assert CONFIG.num_clusters == 32
assert CONFIG.num_cores == 32 * 9 + 1   # 289 incl. the CVA6 host
