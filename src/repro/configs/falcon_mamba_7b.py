"""ssm 64L d4096 attn-free mamba1 sstate16 v65024 [arXiv:2410.05355]

Selectable via ``--arch falcon-mamba-7b`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "falcon-mamba-7b"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
