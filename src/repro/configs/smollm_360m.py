"""dense 32L d960 15H/kv5 ff2560 v49152 llama-arch small [hf:HuggingFaceTB/SmolLM-360M]

Selectable via ``--arch smollm-360m`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "smollm-360m"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
