"""hybrid 54L d2560 mamba2 sstate64 + shared 32H attn block every 6 [arXiv:2411.15242]

Selectable via ``--arch zamba2-2.7b`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "zamba2-2.7b"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
