"""moe 27L d2048 16H ff1408 v102400 MLA kvlora512 2shared+64routed top-6 [arXiv:2405.04434]

Selectable via ``--arch deepseek-v2-lite-16b`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "deepseek-v2-lite-16b"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
