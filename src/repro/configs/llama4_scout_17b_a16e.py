"""moe 48L d5120 40H/kv8 ff8192 v202048 16e top-1 + shared [hf:meta-llama/Llama-4-Scout-17B-16E]

Selectable via ``--arch llama4-scout-17b-a16e`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "llama4-scout-17b-a16e"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
