"""dense 40L d5120 40H/kv10 ff17920 v100352 RoPE SwiGLU GQA [arXiv:2404.14219]

Selectable via ``--arch phi3-medium-14b`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "phi3-medium-14b"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
