"""dense 80L d8192 64H/kv8 ff49152 v152064 QKV-bias [hf:Qwen/Qwen1.5-110B]

Selectable via ``--arch qwen1.5-110b`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "qwen1.5-110b"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
