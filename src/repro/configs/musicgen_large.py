"""audio 48L d2048 32H ff8192 v2048 decoder-only over EnCodec tokens, sinusoidal pos [arXiv:2306.05284]

Selectable via ``--arch musicgen-large`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "musicgen-large"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
