"""One config module per assigned architecture (``--arch <id>``).

All ten re-export from :mod:`repro.models.registry`; import any of them or
use ``repro.models.get(name)`` directly.
"""

from repro.models.registry import ARCHS, get  # noqa: F401
