"""dense 48L d4096 32H/kv4 ff11008 v64000 llama-arch GQA [arXiv:2403.04652]

Selectable via ``--arch yi-9b`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "yi-9b"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
