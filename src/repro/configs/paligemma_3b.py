"""vlm 18L d2048 8H/kv1 hd256 ff16384 v257216 SigLIP-stub + gemma prefix-LM [arXiv:2407.07726]

Selectable via ``--arch paligemma-3b`` in repro.launch.{dryrun,train,serve}.
The exact configuration lives in :mod:`repro.models.registry` (single source
of truth); this module re-exports it plus the cell shape table and the
reduced smoke-test sibling.
"""

from repro.launch.cells import SHAPES  # noqa: F401  (the 4 input shapes)
from repro.models.config import reduced
from repro.models.registry import get

NAME = "paligemma-3b"
CONFIG = get(NAME)
REDUCED = reduced(CONFIG)
