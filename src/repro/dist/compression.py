"""Gradient compression for data-parallel reductions.

Int8 linear quantization with per-call scale, an error-feedback residual
(1-bit-Adam style: what quantization drops this step is carried and added
back next step, so the *accumulated* compressed sum tracks the true sum),
and the two collective helpers built on them:

* ``compressed_psum``     — quantize locally, all-reduce the dequantized
  values (models the wire carrying int8 payloads + one fp32 scale).
* ``dp_grads_compressed`` — per-shard ``value_and_grad`` whose gradient
  all-reduce goes through ``compressed_psum`` (mean over the axis), for use
  inside ``shard_map`` data-parallel training.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x -> (int8 codes, fp32 scale); round-to-nearest, |err| <= scale/2."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_residual(tree: Pytree) -> Pytree:
    """Zero error-feedback residual matching a gradient pytree."""
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)


def error_feedback_compress(grads: Pytree, residual: Pytree
                            ) -> Tuple[Pytree, Pytree]:
    """-> (dequantized compressed grads, updated residual).

    Compresses ``grads + residual``; the new residual is exactly the
    quantization error, so successive compressed steps sum to the true sum
    up to one quantization step.
    """
    def one(g, r):
        y = g.astype(jnp.float32) + r
        q, scale = quantize_int8(y)
        dq = dequantize_int8(q, scale)
        return dq, y - dq

    pairs = jax.tree.map(one, grads, residual)
    dq, res = jax.tree.transpose(
        jax.tree.structure(grads), jax.tree.structure((0, 0)), pairs)
    return dq, res


def compressed_psum(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """All-reduce of locally int8-quantized values (inside ``shard_map``)."""
    q, scale = quantize_int8(x)
    return jax.lax.psum(dequantize_int8(q, scale), axis)


def dp_grads_compressed(loss_fn: Callable[..., jnp.ndarray], axis: str
                        ) -> Callable[..., Tuple[jnp.ndarray, Pytree]]:
    """Data-parallel grads with a compressed all-reduce.

    ``loss_fn(w, batch)`` is evaluated on the local shard; the returned
    function (for use inside ``shard_map``) all-reduces gradients through
    ``compressed_psum`` and averages, and p-means the loss.
    """
    def gfn(w: Pytree, batch: Dict[str, jnp.ndarray]):
        loss, g = jax.value_and_grad(loss_fn)(w, batch)
        n = jax.lax.psum(jnp.float32(1.0), axis)
        g = jax.tree.map(lambda t: compressed_psum(t, axis) / n, g)
        return jax.lax.pmean(loss, axis), g

    return gfn
