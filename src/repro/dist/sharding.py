"""Sharding rules: one place that maps pytrees onto the mesh.

Every launcher-side builder (train step, serve step, dry-run cells) derives
its explicit in/out shardings from the three rule functions here, so that
the same program partitioning is used whether a cell is AOT-compiled for the
dry-run or actually executed on the CPU test mesh:

* ``param_specs``  — tensor parallelism: shard the widest divisible trailing
  axis of every >=2-D parameter over the ``model`` axis (layer-stacked
  parameters keep their leading ``L`` axis replicated); 1-D scales/biases
  replicate.
* ``batch_specs``  — data parallelism: shard the leading batch axis over the
  data axes (``pod`` composes into ``data`` on multi-pod meshes).
* ``cache_specs``  — KV/state caches are laid out ``(L, B, ...)``; the batch
  axis (axis 1) shards over the data axes, everything else replicates.
  The scalar ``pos`` counter replicates.

All rules are divisibility-guarded: an axis that does not divide evenly over
its mesh axes falls back to replication instead of erroring, so reduced test
configs and odd meshes always produce a valid (if less parallel) layout.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

#: mesh axes that compose into data parallelism, outermost first
DP_AXES: Tuple[str, ...] = ("pod", "data")
#: the tensor-parallel mesh axis
TP_AXIS = "model"


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axis names present on this mesh, outermost first."""
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _dp_size(mesh: Mesh) -> int:
    sizes = _axis_sizes(mesh)
    n = 1
    for a in dp_axes(mesh):
        n *= sizes[a]
    return n


def _shape(leaf: Any) -> Tuple[int, ...]:
    return tuple(leaf.shape)


def _is_spec(x: Any) -> bool:
    return isinstance(x, P)


def param_specs(params: Pytree, mesh: Mesh) -> Pytree:
    """PartitionSpec per parameter: widest divisible trailing axis -> model."""
    sizes = _axis_sizes(mesh)
    tp = sizes.get(TP_AXIS, 1)

    def rule(leaf):
        shape = _shape(leaf)
        if tp <= 1 or len(shape) < 2:
            return P()
        # trailing axes first: (L, d_in, d_out) prefers the output dim, which
        # keeps matmul outputs model-sharded (Megatron-style column parallel)
        for ax in range(len(shape) - 1, 0, -1):
            if shape[ax] % tp == 0 and shape[ax] >= tp:
                return P(*([None] * ax + [TP_AXIS]))
        return P()

    return jax.tree.map(rule, params)


def batch_specs(shapes: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """PartitionSpec per model input: leading batch axis -> data axes."""
    dp = dp_axes(mesh)
    dpn = _dp_size(mesh)

    def rule(leaf):
        shape = _shape(leaf)
        if not dp or dpn <= 1 or not shape or shape[0] % dpn:
            return P()
        return P(dp)

    return jax.tree.map(rule, shapes)


def cache_specs(cache_shapes: Pytree, mesh: Mesh) -> Pytree:
    """PartitionSpec per cache entry: (L, B, ...) batch axis -> data axes."""
    dp = dp_axes(mesh)
    dpn = _dp_size(mesh)

    def rule(leaf):
        shape = _shape(leaf)
        if not dp or dpn <= 1 or len(shape) < 2 or shape[1] % dpn:
            return P()
        return P(None, dp)

    return jax.tree.map(rule, cache_shapes)


def to_shardings(specs: Pytree, mesh: Mesh) -> Pytree:
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=_is_spec)
