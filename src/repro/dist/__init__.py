"""Distribution layer: sharding rules shared by train / serve / dry-run."""

from repro.dist.sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    dp_axes,
    param_specs,
    to_shardings,
)
