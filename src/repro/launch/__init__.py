"""Launch layer: production meshes, multi-pod dry-run, train/serve drivers."""
from repro.launch.mesh import make_mesh, make_production_mesh
__all__ = ["make_mesh", "make_production_mesh"]
