"""Flash-kernel substitution: the measured roofline of a cell with the
Pallas flash-attention kernel in place of the XLA fallback path.

Method (§Perf): the XLA chunked-attention path materializes every
(B,H,Sq,chunk) score/probability tile at fusion boundaries — traffic and
temp memory a TPU flash kernel does not have (tiles live in VMEM).  The TPU
kernel cannot lower on the CPU dry-run backend, so its cell-level effect is
measured as:

    cell(flash) = cell(stub) + flash_kernel_terms

where ``cell(stub)`` is the same program compiled with a shape/grad-
preserving zero-cost attention stub (attn_impl="stub" — isolates the
everything-but-attention cost, including QKV/O projections, MLP, optimizer,
collectives), and ``flash_kernel_terms`` are the kernel's analytic
FLOPs/HBM-traffic per the standard flash accounting:

    fwd  FLOPs = 2 · 2 · B·H·S²·dh · causal_frac      (QKᵀ + PV)
    bwd  FLOPs = 2.5 × fwd                             (dQ,dK,dV + recompute)
    remat fwd  = 1 × fwd                               (train-only recompute)
    HBM bytes  = passes · (3 reads + 1 write) · B·H·S·dh · dtype_bytes
                 (+ O(S) softmax stats, negligible)

Collective bytes are taken from the stub compile (the kernel adds none).
Every number lands in the §Perf log as "flash-substituted (modeled on
measured stub)" — explicitly distinguished from directly-compiled cells.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.launch.roofline import HBM_BW, PEAK_FLOPS_BF16, Roofline
from repro.models.config import ModelConfig


@dataclasses.dataclass
class AttnShape:
    layers: int
    batch_global: int
    heads: int          # query heads
    head_dim: int       # qk head dim (v dim assumed equal for traffic)
    seq: int
    causal_frac: float = 0.5
    passes_flops: float = 4.5    # fwd(1) + remat(1) + bwd(2.5) — train
    passes_bytes: float = 3.0    # qkv+o streamed per pass
    dtype_bytes: int = 2


def attn_shape_for(cfg: ModelConfig, mode: str, seq: int, gbatch: int
                   ) -> Optional[AttnShape]:
    if cfg.family == "ssm":
        return None
    heads = cfg.n_heads
    hd = cfg.head_dim
    layers = cfg.n_layers
    if cfg.mla:
        hd = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
    if cfg.family == "hybrid":
        layers = cfg.n_layers // cfg.hybrid.period   # shared-block apps
        heads = cfg.hybrid.shared_attn_heads
        hd = cfg.d_model // heads
    if mode == "prefill":
        return AttnShape(layers, gbatch, heads, hd, seq,
                         passes_flops=1.0, passes_bytes=1.0)
    return AttnShape(layers, gbatch, heads, hd, seq)


def flash_terms(a: AttnShape, chips: int) -> Tuple[float, float]:
    """(flops_per_device, hbm_bytes_per_device) of the flash kernel."""
    fwd = 2.0 * 2.0 * a.batch_global * a.heads * a.seq ** 2 * a.head_dim \
        * a.causal_frac
    flops = fwd * a.passes_flops / chips
    stream = (4.0 * a.batch_global * a.heads * a.seq * a.head_dim
              * a.dtype_bytes)
    nbytes = stream * a.passes_bytes * max(1.0, a.passes_flops / 2) / chips
    return a.layers * flops, a.layers * nbytes


def substitute(stub_roof: Roofline, a: Optional[AttnShape]) -> Roofline:
    """Roofline of stub-cell + flash kernel terms."""
    if a is None:
        return stub_roof
    f, b = flash_terms(a, stub_roof.chips)
    return dataclasses.replace(
        stub_roof,
        flops=stub_roof.flops + f,
        bytes_accessed=stub_roof.bytes_accessed + b,
    )
