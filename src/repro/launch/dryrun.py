import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only this entry point sees 512 placeholder devices; tests and benches see 1.

"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, prove it fits, and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Per cell it prints ``compiled.memory_analysis()`` (fits-per-device proof)
and ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline), parses the
collective schedule out of the partitioned HLO, and appends a JSON record.
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.launch.cells import SHAPES, applicable, build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.models.registry import ARCHS, get


def run_cell(arch: str, shape: str, multi_pod: bool,
             call_overrides: Optional[Dict] = None,
             train_overrides: Optional[Dict] = None,
             keep_hlo: bool = False) -> Dict:
    cfg = get(arch)
    ok, why = applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        print(f"[dryrun] {arch} × {shape} × {mesh_name}: SKIPPED ({why})")
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, meta = build_cell(arch, shape, mesh,
                                call_overrides, train_overrides)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[dryrun] {arch} × {shape} × {mesh_name}")
    print(f"  memory_analysis: {mem}")
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")
    roof = analyze(compiled, meta.model_flops, meta.chips)
    print(f"  roofline: t_comp={roof.t_compute:.3e}s t_mem={roof.t_memory:.3e}s "
          f"t_coll={roof.t_collective:.3e}s bottleneck={roof.bottleneck} "
          f"frac={roof.roofline_fraction:.3f}")

    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(mem, "alias_size_in_bytes", None),
        },
        tokens=meta.tokens,
        params_total=meta.params_total,
        params_active=meta.params_active,
        roofline=roof.to_dict(),
    )
    if keep_hlo:
        rec["hlo"] = compiled.as_text()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), action="append")
    ap.add_argument("--shape", choices=sorted(SHAPES), action="append")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--call-override", default=None,
                    help="JSON dict of CallConfig overrides (hillclimbing)")
    ap.add_argument("--train-override", default=None,
                    help="JSON dict of TrainConfig overrides (hillclimbing)")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.all or not args.arch else args.arch
    shapes = sorted(SHAPES) if args.all or not args.shape else args.shape
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    co = json.loads(args.call_override) if args.call_override else None
    to = json.loads(args.train_override) if args.train_override else None

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp, co, to)
                except Exception as e:                      # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "pod2x16x16" if mp else "pod16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    n_fail += 1
                    print(f"[dryrun] {arch} × {shape}: ERROR {e!r}")
                rec["tag"] = args.tag
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{args.tag}.json"
                    with open(os.path.join(args.out, name), "w") as f:
                        json.dump(rec, f, indent=1)
    if n_fail:
        raise SystemExit(f"{n_fail} cell(s) failed")


if __name__ == "__main__":
    main()
