"""Trip-count-corrected HLO cost analysis.

Why this exists: XLA's ``compiled.cost_analysis()`` counts every computation
ONCE — a ``lax.scan`` of 80 layers reports one layer's FLOPs (verified in
tests/test_hlo_cost.py).  Every program in this framework is scan-shaped
(layer stack, microbatches, attention chunks, SSM chunks), so the raw
numbers under-count by orders of magnitude.  This module re-derives cost
from the *partitioned* HLO text with loop trip counts applied:

  * module parse: computations, instructions, per-computation symbol tables;
  * ``while``: body+condition cost × trip count, where the trip count is the
    s32 bound constant in the condition computation (all loops we emit are
    0..N step-1 counters — scan/fori lower to exactly this form);
  * ``fusion``/``call``: called computation, FLOPs counted inside, memory
    traffic counted at the fusion boundary only (internals live in
    registers — this is *closer* to true HBM traffic than XLA's own
    "bytes accessed", which double-counts every fused op);
  * ``conditional``: max across branches (upper bound; noted in §Roofline);
  * ``dot``: 2 × numel(result) × contracted extent; elementwise: numel;
  * collectives: operand bytes × enclosing trip counts — GSPMD-inserted
    per-layer all-gathers/reduce-scatters are multiplied correctly.

Outputs feed :mod:`repro.launch.roofline`; raw XLA numbers are also kept in
the dry-run records for comparison.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: ops costing ~1 flop per output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "not", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "power",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                   "sine", "cosine", "tan", "atan2", "expm1", "log1p", "erf",
                   "cbrt", "exponential-minus-one"}
#: pure data-movement ops whose result bytes count as traffic
_MOVEMENT = {"copy", "transpose", "broadcast", "iota", "concatenate", "pad",
             "slice", "reverse", "reduce", "reduce-window", "sort",
             "convert", "select-and-scatter", "rng", "rng-bit-generator"}
#: in-place / windowed ops: traffic is the moved WINDOW, not the operand
#: buffer (XLA aliases the buffer in place inside while loops; counting the
#: full buffer per loop iteration would overstate scan-carried grads and KV
#: caches by the trip count — tests/test_hlo_cost.py::test_dus_in_place)
_WINDOWED = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}
#: zero-cost bookkeeping
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "reshape", "after-all", "token", "partition-id", "replica-id",
         "bitcast-convert", "opt-barrier", "custom-call", "domain"}


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]          # symbol -> type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Optional[Dict[str, float]] = None
    #: largest individual contributors, trip-multiplied:
    #: (kind-or-op, metadata-op_name-fragment, bytes)
    top_collectives: Optional[List[Tuple[str, str, float]]] = None
    top_traffic: Optional[List[Tuple[str, str, float]]] = None

    def __post_init__(self):
        if self.collective_counts is None:
            self.collective_counts = {k: 0.0 for k in COLLECTIVE_KINDS}
        if self.top_collectives is None:
            self.top_collectives = []
        if self.top_traffic is None:
            self.top_traffic = []

    def _merge_tops(self, other: "Cost", m: float = 1.0) -> None:
        self.top_collectives = sorted(
            self.top_collectives
            + [(k, n, b * m) for k, n, b in other.top_collectives],
            key=lambda t: -t[2])[:12]
        self.top_traffic = sorted(
            self.top_traffic
            + [(k, n, b * m) for k, n, b in other.top_traffic],
            key=lambda t: -t[2])[:12]

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        self.transcendentals += other.transcendentals
        self.collective_bytes += other.collective_bytes
        for k in COLLECTIVE_KINDS:
            self.collective_counts[k] += other.collective_counts[k]
        self._merge_tops(other)
        return self

    def scaled(self, m: float) -> "Cost":
        c = Cost(
            self.flops * m, self.bytes * m, self.transcendentals * m,
            self.collective_bytes * m,
            {k: v * m for k, v in self.collective_counts.items()},
        )
        c.top_collectives = [(k, n, b * m) for k, n, b in self.top_collectives]
        c.top_traffic = [(k, n, b * m) for k, n, b in self.top_traffic]
        return c


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{")
_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\((?:[^()]|\([^)]*\))*\)|[\w\[\],{}\/]+)\s+([\w\-]+)"
)
_PARAM = re.compile(r"%?([\w.\-]+)\s*:\s*(\((?:[^()]|\([^)]*\))*\)|[^,)]+)")
_ARRAY = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(t: str) -> int:
    """Total bytes of an (array or tuple) type string."""
    total = 0
    for dt, dims in _ARRAY.findall(t):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _array_dims(t: str) -> Optional[Tuple[str, List[int]]]:
    m = _ARRAY.match(t.strip())
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _numel(t: str) -> int:
    a = _array_dims(t)
    if not a:
        return 0
    n = 1
    for d in a[1]:
        n *= d
    return n


def _extract_operands(rest: str) -> Tuple[List[str], str]:
    """rest starts at '('; returns (operand names, attrs after the parens)."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner = rest[1:i]
                ops = []
                for a in _split_top(inner):
                    a = a.strip()
                    if " " in a:            # 'f32[8]{0} %x' inline-typed
                        a = a.split()[-1]
                    a = a.lstrip("%")
                    if a:
                        ops.append(a)
                return ops, rest[i + 1:]
    return [], rest


def _split_top(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(stripped)
            if m and stripped.endswith("{"):
                name, paramstr = m.groups()
                cur = Computation(name, [], {})
                if stripped.startswith("ENTRY"):
                    entry = name
                for pm in _PARAM.finditer(paramstr):
                    cur.shapes[pm.group(1)] = pm.group(2).strip()
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        is_root, name, rtype, op = m.groups()
        rest = line[m.end():]
        operands, attrs = _extract_operands(rest.lstrip()) if rest.lstrip().startswith("(") else ([], rest)
        instr = Instr(name, rtype, op, operands, attrs, bool(is_root))
        cur.instrs.append(instr)
        cur.shapes[name] = rtype
    return comps, entry


_TRIP_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _while_trip(comps: Dict[str, Computation], cond_name: str) -> int:
    """Trip count from the condition computation's s32 bound constant.

    Our loops are all 0..N step-1 counters (lax.scan / fori_loop), whose
    lowered condition is ``compare(iv, constant(N)), direction=LT``.  The
    constant may live behind a wrapped-compare fusion; take the largest s32
    constant reachable from the condition computation (and its callees).
    """
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        nm = stack.pop()
        if nm in seen or nm not in comps:
            continue
        seen.add(nm)
        for ins in comps[nm].instrs:
            if ins.op == "constant" and ins.result_type.strip().startswith("s32[]"):
                # the literal '(N)' parses as the operand list: ['N']
                if ins.operands and ins.operands[0].isdigit():
                    best = max(best, int(ins.operands[0]))
            m2 = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
            if m2:
                stack.append(m2.group(1))
    return best


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out = _numel(instr.result_type)
    lhs = shapes.get(instr.operands[0], "") if instr.operands else ""
    a = _array_dims(lhs)
    contracted = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    if a and m and m.group(1):
        for d in m.group(1).split(","):
            contracted *= a[1][int(d)]
    # batch dims are part of `out` already.
    return 2.0 * out * contracted


def analyze_computation(
    comps: Dict[str, Computation], name: str,
    memo: Dict[str, Cost],
) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    total = Cost()
    if comp is None:
        memo[name] = total
        return total
    memo[name] = total     # provisional (cycles impossible in HLO, but safe)

    def _meta(ins: Instr) -> str:
        m = re.search(r'op_name="([^"]{0,120})', ins.attrs)
        return m.group(1) if m else ins.name

    for ins in comp.instrs:
        op = ins.op
        kind = next((k for k in COLLECTIVE_KINDS if op.startswith(k)), None)
        if kind is not None and not op.startswith(kind + "-done"):
            nbytes = sum(_type_bytes(comp.shapes.get(o, "")) for o in ins.operands)
            if nbytes == 0:
                nbytes = _type_bytes(ins.result_type)
            total.collective_bytes += nbytes
            total.collective_counts[kind] += 1
            total.bytes += nbytes + _type_bytes(ins.result_type)
            total.top_collectives.append((kind, _meta(ins), float(nbytes)))
            total.top_collectives.sort(key=lambda t: -t[2])
            del total.top_collectives[12:]
            continue
        if op == "while":
            cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
            body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
            trip = _while_trip(comps, cond.group(1)) if cond else 1
            inner = Cost()
            if body:
                inner += analyze_computation(comps, body.group(1), memo)
            if cond:
                inner += analyze_computation(comps, cond.group(1), memo)
            total += inner.scaled(trip)
            continue
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=%?([\w.\-]+))",
                                  ins.attrs)
            names: List[str] = []
            for grp, single in branches:
                if grp:
                    names.extend(x.strip().lstrip("%") for x in grp.split(","))
                if single:
                    names.append(single)
            if names:
                costs = [analyze_computation(comps, n, memo) for n in names]
                best = max(costs, key=lambda c: c.flops + c.bytes)
                total += best
            continue
        if op in ("fusion", "call", "async-start", "map"):
            m = re.search(r"(?:calls|to_apply|called_computation)=%?([\w.\-]+)",
                          ins.attrs)
            called = comps.get(m.group(1)) if m else None
            if m:
                inner = analyze_computation(comps, m.group(1), memo)
                # FLOPs happen; internal traffic stays on-chip.
                total.flops += inner.flops
                total.transcendentals += inner.transcendentals
                total.collective_bytes += inner.collective_bytes
                for k in COLLECTIVE_KINDS:
                    total.collective_counts[k] += inner.collective_counts[k]
                total._merge_tops(inner)
            # In-place-update fusions (root = dynamic-update-slice on a
            # parameter buffer) alias their buffer: traffic is the window,
            # not the buffer — the dominant pattern of scan-carried grads,
            # KV caches and stacked-ys.
            root = _root_instr(called) if called else None
            if root is not None and root.op == "tuple" and called is not None:
                # multi-output fusion: if every tuple element is a dus, the
                # whole fusion is an in-place multi-carry update
                defs = {i.name: i for i in called.instrs}
                elems = [defs.get(o) for o in root.operands]
                if elems and all(e is not None and e.op == "dynamic-update-slice"
                                 for e in elems):
                    root = None
                    traffic = sum(_windowed_bytes(e, called) for e in elems)
                    total.bytes += traffic
                    total.top_traffic.append(
                        ("fusion-dus", _meta(ins), float(traffic)))
                    total.top_traffic.sort(key=lambda t: -t[2])
                    del total.top_traffic[12:]
                    continue
            if root is not None and root.op == "dynamic-update-slice":
                traffic = _windowed_bytes(root, called)
            else:
                traffic = _type_bytes(ins.result_type) + sum(
                    _type_bytes(comp.shapes.get(o, "")) for o in ins.operands)
            total.bytes += traffic
            if traffic > 0:
                total.top_traffic.append(("fusion", _meta(ins), float(traffic)))
                total.top_traffic.sort(key=lambda t: -t[2])
                del total.top_traffic[12:]
            continue
        if op in ("dot", "dot-general") or op.startswith("dot"):
            total.flops += _dot_flops(ins, comp.shapes)
            traffic = _type_bytes(ins.result_type) + sum(
                _type_bytes(comp.shapes.get(o, "")) for o in ins.operands)
            total.bytes += traffic
            total.top_traffic.append(("dot", _meta(ins), float(traffic)))
            total.top_traffic.sort(key=lambda t: -t[2])
            del total.top_traffic[12:]
            continue
        if op == "convolution":
            # rare here; approximate as dot on result × window (unused paths)
            total.flops += 2.0 * _numel(ins.result_type)
            total.bytes += _type_bytes(ins.result_type)
            continue
        if op in _FREE:
            continue
        if op in _TRANSCENDENTAL:
            n = _numel(ins.result_type)
            total.flops += n
            total.transcendentals += n
            total.bytes += _type_bytes(ins.result_type) + sum(
                _type_bytes(comp.shapes.get(o, "")) for o in ins.operands)
            continue
        if op in _WINDOWED:
            total.bytes += _windowed_bytes(ins, comp)
            continue
        if op in _ELEMENTWISE or op in _MOVEMENT:
            if op in _ELEMENTWISE:
                total.flops += _numel(ins.result_type)
            total.bytes += _type_bytes(ins.result_type) + sum(
                _type_bytes(comp.shapes.get(o, "")) for o in ins.operands)
            continue
        # unknown op: count traffic only
        total.bytes += _type_bytes(ins.result_type)
    memo[name] = total
    return total


def _windowed_bytes(ins: Instr, comp: Computation) -> float:
    """Traffic of in-place / windowed ops = 2 × the moved window."""
    if ins.op == "dynamic-update-slice":
        # operands: [buffer, update, indices...] -> read+write the update
        upd = comp.shapes.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
        return 2.0 * _type_bytes(upd)
    if ins.op == "dynamic-slice":
        return 2.0 * _type_bytes(ins.result_type)
    if ins.op == "gather":
        idx = comp.shapes.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
        return 2.0 * _type_bytes(ins.result_type) + _type_bytes(idx)
    # scatter: operands [buffer, indices, updates]
    upd = comp.shapes.get(ins.operands[-1], "") if ins.operands else ""
    idx = comp.shapes.get(ins.operands[1], "") if len(ins.operands) > 2 else ""
    return 2.0 * _type_bytes(upd) + _type_bytes(idx)


def _root_instr(comp: Computation) -> Optional[Instr]:
    for ins in comp.instrs:
        if ins.is_root:
            return ins
    return comp.instrs[-1] if comp.instrs else None


def analyze_hlo_text(text: str) -> Cost:
    comps, entry = parse_module(text)
    if entry is None:
        return Cost()
    return analyze_computation(comps, entry, {})
