"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets its
XLA device-count flag before any JAX initialization, and tests import this
module under a normal 1-device runtime without side effects.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The target deployment mesh: one v5e pod (16×16 = 256 chips) or two
    pods (2×16×16 = 512 chips) with a leading ``pod`` axis that composes
    into data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence[jax.Device]] = None) -> Mesh:
    """Arbitrary mesh over explicit devices (tests, examples, elastic)."""
    devs = list(devices if devices is not None else jax.devices())
    n = int(np.prod(shape))
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(tuple(shape)), tuple(axes))
