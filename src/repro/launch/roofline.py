"""Roofline analysis from compiled dry-run artifacts.

Three terms per (architecture × shape × mesh) cell, all in seconds-per-step
on the TARGET hardware (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
ICI per link):

    compute    = HLO_FLOPs_per_device   / 197e12
    memory     = HLO_bytes_per_device   / 819e9
    collective = collective_bytes_per_device / 50e9

Accounting notes (verified against a hand-checked matmul in
tests/test_roofline.py):

* XLA:CPU's ``compiled.cost_analysis()`` reports **per-device** (post-SPMD-
  partitioning) flops / bytes, so no division by chip count is applied.
* ``bytes accessed`` counts every operator's reads+writes, an upper bound on
  unique HBM traffic (fusion reduces real traffic) — conservative for a
  memory-bound verdict.
* collective bytes are parsed from the partitioned HLO: for every
  all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
  the *operand* sizes are summed (two-pass parse resolves operand shapes);
  ``*-done`` halves of async pairs are skipped so nothing is double-counted.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# --- TPU v5e hardware constants (per chip) ---------------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"^([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[128,512]{1,0}' — 0 for tuples/tokens/opaque."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Two-pass parse: symbol table of result shapes, then operand sums."""
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, shape, _op = m.groups()
            shapes[name] = shape

    counts = {k: 0 for k in COLLECTIVE_OPS}
    nbytes = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, result_shape, op = m.groups()
        kind = next((k for k in COLLECTIVE_OPS if op.startswith(k)), None)
        if kind is None or op.startswith(kind + "-done"):
            continue
        counts[kind] += 1
        # operand list: the (...) right after the op name
        rest = line[line.index(op) + len(op):]
        args = rest[rest.index("(") + 1: _match_paren(rest)] if "(" in rest else ""
        total = 0
        for a in args.split(","):
            a = a.strip().lstrip("%")
            # strip inline shapes like 'bf16[8,128]{1,0} %param.1'
            if " " in a:
                a = a.split()[-1].lstrip("%")
            if a in shapes:
                total += _shape_bytes(shapes[a])
        if total == 0:
            total = _shape_bytes(result_shape)   # fallback: result size
        nbytes[kind] += total
    return CollectiveStats(counts, nbytes)


def _match_paren(s: str) -> int:
    depth = 0
    for i, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s)


@dataclasses.dataclass
class Roofline:
    flops: float                     # per device (trip-count corrected)
    bytes_accessed: float            # per device (fusion-boundary traffic)
    collective_bytes: float          # per device (trip-count corrected)
    collectives: Dict[str, int]
    model_flops: float = 0.0         # 6·N·D (active N for MoE), global
    chips: int = 1
    raw_flops: float = 0.0           # XLA cost_analysis (loop bodies ×1)
    raw_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        """Roofline step time (s): max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops × chips): how much compiled compute is
        'useful' (catches remat/redundancy waste)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization at the roofline bound: the score.
        = (MODEL_FLOPS / chips / peak) / max-term."""
        if self.bound == 0:
            return 0.0
        t_useful = self.model_flops / self.chips / PEAK_FLOPS_BF16
        return t_useful / self.bound

    def to_dict(self) -> Dict:
        return {
            "raw_xla_flops_per_device": self.raw_flops,
            "raw_xla_bytes_per_device": self.raw_bytes,
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collective_bytes,
            "collective_counts": self.collectives,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, model_flops: float, chips: int) -> Roofline:
    """Primary numbers come from the trip-count-corrected HLO walk
    (:mod:`repro.launch.hlo_cost`): XLA's own ``cost_analysis()`` counts
    while-loop bodies once (verified in tests/test_hlo_cost.py), which
    under-counts every scan-shaped program here by the trip count.  The raw
    XLA numbers are preserved in ``raw_*`` for comparison."""
    from repro.launch.hlo_cost import analyze_hlo_text

    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older API returned [dict]
        cost = cost[0]
    corrected = analyze_hlo_text(compiled.as_text())
    return Roofline(
        flops=corrected.flops,
        bytes_accessed=corrected.bytes,
        collective_bytes=corrected.collective_bytes,
        collectives={k: int(v) for k, v in corrected.collective_counts.items()},
        model_flops=model_flops,
        chips=chips,
        raw_flops=float(cost.get("flops", 0.0)),
        raw_bytes=float(cost.get("bytes accessed", 0.0)),
    )


def model_flops_train(n_params_active: int, tokens: int) -> float:
    """6·N·D for a training step (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * tokens


def model_flops_forward(n_params_active: int, tokens: int) -> float:
    return 2.0 * n_params_active * tokens
