"""Cell builder: one AOT-compilable program per (architecture × shape).

A *cell* is the unit of the dry-run and the roofline table:

    train_4k     train_step       seq 4096,   global batch 256
    prefill_32k  prefill          seq 32768,  global batch 32
    decode_32k   serve_step       KV cache 32768, global batch 128
    long_500k    serve_step       state/cache 524288, global batch 1
                 (sub-quadratic archs only: zamba2, falcon-mamba —
                  full-attention archs are skipped per the assignment,
                  see DESIGN.md §6)

``build_cell`` returns (jitted_fn, example_args_as_ShapeDtypeStructs, meta);
``fn.lower(*args).compile()`` never allocates device memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import input_specs
from repro.dist.sharding import (
    batch_specs, cache_specs, param_specs, to_shardings,
)
from repro.models.config import ModelConfig
from repro.models.model import CallConfig, init_cache, init_params, prefill
from repro.models.registry import count_params, get
from repro.launch.roofline import model_flops_forward, model_flops_train
from repro.serve.engine import build_serve_step
from repro.train.step import TrainConfig, build_train_step

SHAPES: Dict[str, Tuple[str, int, int]] = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention architecture: 500k-token dense KV "
                       "decode is out of regime (assignment: run for "
                       "SSM/hybrid only)")
    return True, ""


@dataclasses.dataclass
class CellMeta:
    arch: str
    shape: str
    mode: str
    seq: int
    global_batch: int
    tokens: int
    chips: int
    model_flops: float
    params_total: int
    params_active: int


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def default_call(mode: str, seq: int, overrides: Optional[Dict] = None,
                 mesh: Optional[Mesh] = None,
                 cfg: Optional[ModelConfig] = None) -> CallConfig:
    kw: Dict[str, Any] = {}
    if mode in ("train", "prefill"):
        kw["attn_impl"] = "chunked" if seq > 2048 else "xla"
        kw["attn_chunk"] = 512
        kw["remat"] = mode == "train"
    if mode != "train":
        kw["moe_no_drop"] = mode == "decode"  # decode exact; prefill capacity
    if overrides:
        kw.update(overrides)
    # String-valued sharding knobs resolve against the mesh here (JSON
    # overrides from the dryrun CLI cannot carry NamedShardings).
    if mesh is not None:
        if kw.get("attn_q_sharding") in ("seq_model", "auto"):
            # scaled q: (B, H, S, d) — sequence over the model axis.
            # §Perf finding: forcing sequence sharding wins exactly when the
            # (repeated) head count does NOT divide the model axis (XLA then
            # falls back to sharding the QK contraction → per-chunk score
            # all-reduces); when heads divide cleanly, XLA's head-sharded
            # plan is better and the constraint is withheld ("auto").
            force = kw["attn_q_sharding"] == "seq_model"
            heads = cfg.n_heads if cfg is not None else 0
            tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
            if force or (heads and heads % tp != 0):
                kw["attn_q_sharding"] = NamedSharding(
                    mesh, P(None, None, "model", None))
            else:
                kw["attn_q_sharding"] = None
        if kw.get("moe_buffer_sharding") == "ep":
            # (E, C, D) dispatch buffer: experts over the model axis
            kw["moe_buffer_sharding"] = NamedSharding(
                mesh, P("model", None, None))
    return CallConfig(**kw)


def build_cell(
    arch: str,
    shape: str,
    mesh: Mesh,
    call_overrides: Optional[Dict] = None,
    train_overrides: Optional[Dict] = None,
):
    """-> (jitted fn, tuple of ShapeDtypeStruct args, CellMeta)."""
    cfg = get(arch)
    ok, why = applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} × {shape} skipped: {why}")
    mode, seq, gbatch = SHAPES[shape]
    chips = int(mesh.devices.size)
    call = default_call(mode, seq, call_overrides, mesh, cfg)

    key_spec = jax.eval_shape(lambda: jax.random.key(0))
    key_sds = jax.ShapeDtypeStruct(key_spec.shape, key_spec.dtype)
    pshapes = jax.eval_shape(lambda k: init_params(k, cfg), key_sds)
    pspecs = param_specs(pshapes, mesh)
    n_total = count_params(cfg)
    n_active = count_params(cfg, active_only=True)

    batch_sds = input_specs(cfg, mode=mode, batch=gbatch, seq=seq)

    if mode == "train":
        tokens = gbatch * seq
        tcfg = TrainConfig(**(train_overrides or {}), call=call)
        fn, pspecs, ospecs, bspecs = build_train_step(
            cfg, mesh, tcfg, batch_sds)
        oshapes = {
            "mu": pshapes, "nu": pshapes,
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        }
        oshapes = jax.eval_shape(
            lambda p: {"mu": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                       "nu": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                       "count": jnp.zeros((), jnp.int32)}, pshapes)
        args = (pshapes, oshapes, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        mf = model_flops_train(n_active, tokens)
    elif mode == "prefill":
        tokens = gbatch * seq
        bspecs = batch_specs(batch_sds, mesh)

        def pf(params, batch):
            return prefill(params, cfg, batch, seq, call)

        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, gbatch, seq))
        cspecs = cache_specs(cache_shapes, mesh)
        fn = jax.jit(
            pf,
            in_shardings=(to_shardings(pspecs, mesh),
                          to_shardings(bspecs, mesh)),
            out_shardings=(NamedSharding(mesh, P()),
                           to_shardings(cspecs, mesh)),
        )
        args = (pshapes, batch_sds)
        mf = model_flops_forward(n_active, tokens)
    else:  # decode
        tokens = gbatch
        fn, cspecs, _ = build_serve_step(cfg, mesh, gbatch, seq, call)
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, gbatch, seq))
        args = (pshapes, cache_shapes, jax.ShapeDtypeStruct((gbatch, 1), jnp.int32))
        mf = model_flops_forward(n_active, tokens)

    meta = CellMeta(
        arch=arch, shape=shape, mode=mode, seq=seq, global_batch=gbatch,
        tokens=tokens, chips=chips, model_flops=mf,
        params_total=n_total, params_active=n_active,
    )
    return fn, args, meta
