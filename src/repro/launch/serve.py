"""Batched serving driver (CPU-runnable).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \\
        --batch 4 --prompt-len 16 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data import DataConfig, SyntheticStream
from repro.dist.sharding import param_specs, to_shardings
from repro.launch.mesh import make_mesh
from repro.models import CallConfig, get, init_params, reduced
from repro.serve import ServeConfig, ServeEngine

import jax


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-mode", default="step",
                    choices=["step", "chunk", "host"],
                    help="decode loop: device-resident step, lax.scan chunk, "
                         "or the legacy host round-trip")
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens per dispatch in chunk mode")
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))

    params = init_params(jax.random.key(args.seed), cfg)
    pspecs = param_specs(params, mesh)
    params = jax.device_put(params, to_shardings(pspecs, mesh))

    scfg = ServeConfig(batch=args.batch,
                       max_len=args.prompt_len + args.new_tokens + 1,
                       temperature=args.temperature, seed=args.seed,
                       decode_mode=args.decode_mode,
                       decode_chunk=args.decode_chunk)
    engine = ServeEngine(cfg, params, mesh, scfg)

    stream = SyntheticStream(
        DataConfig(vocab_size=cfg.vocab_size, batch_size=args.batch,
                   seq_len=args.prompt_len, seed=args.seed), cfg)
    ex = stream.batch(0)
    prompts = ex["tokens"]
    extra = {k: v for k, v in ex.items() if k == "patches"}

    t0 = time.time()
    out = engine.generate(prompts, args.new_tokens, extra or None)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"[serve] generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, batch {args.batch})")
    for b in range(min(2, args.batch)):
        print(f"  slot {b}: prompt={prompts[b][:8].tolist()}... "
              f"-> {out[b][:16].tolist()}")


if __name__ == "__main__":
    main()
