"""Batched serving driver (CPU-runnable).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \\
        --batch 4 --prompt-len 16 --new-tokens 32

Continuous batching (variable-length requests streamed into the fixed
decode batch under a Poisson-ish arrival trace):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \\
        --batch 4 --continuous --requests 8 --arrival-rate 0.5
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import (
    FabricScheduler, ServeConfig, ServeEngine, ServeTenant, Staging,
)
from repro.data import DataConfig, SyntheticStream
from repro.launch.mesh import make_mesh
from repro.models import get, init_params, reduced

import jax


def _continuous_trace(args, cfg):
    """The streamed-request trace both engines share: variable-length
    prompts under a Poisson-ish arrival process."""
    rng = np.random.default_rng(args.seed)
    lens = rng.integers(max(2, args.prompt_len // 2),
                        args.prompt_len + 1, size=args.requests)
    reqs = [(rng.integers(0, cfg.vocab_size, (int(s),)).astype(np.int32),
             args.new_tokens) for s in lens]
    gaps = rng.poisson(1.0 / max(args.arrival_rate, 1e-6),
                       size=args.requests)
    arrivals = np.cumsum(gaps) - gaps[0]
    return reqs, arrivals, lens


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-mode", default="step",
                    choices=["step", "chunk", "host"],
                    help="decode loop: device-resident step, lax.scan chunk, "
                         "or the legacy host round-trip")
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens per dispatch in chunk mode")
    ap.add_argument("--staging", default="direct",
                    choices=["direct", "tree", "tree_reshard"],
                    help="replicated-placement strategy for weights and "
                         "prefill inserts (repro.api.Staging)")
    ap.add_argument("--fabric", action="store_true",
                    help="serve as a lease-holding fabric tenant: hold a "
                         "--serve-floor cluster floor, grow to the free "
                         "fabric per decode burst, shrink back between "
                         "bursts (repro.api.FabricScheduler)")
    ap.add_argument("--serve-floor", type=int, default=1,
                    help="resident lease size between bursts (fabric mode)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: stream --requests variable-"
                         "length prompts through the slot scheduler")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of streamed requests (continuous mode)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="mean arrivals per decode step of the Poisson-ish "
                         "trace (continuous mode)")
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))

    params = jax.device_get(init_params(jax.random.key(args.seed), cfg))

    scfg = ServeConfig(batch=args.batch,
                       max_len=args.prompt_len + args.new_tokens + 1,
                       temperature=args.temperature, seed=args.seed,
                       decode_mode=args.decode_mode,
                       decode_chunk=args.decode_chunk,
                       staging=Staging(args.staging))
    if args.fabric:
        # serve as a fabric tenant: a resident floor lease, elastically
        # grown to the free fabric for each decode burst; the clusters
        # released between bursts are leasable by offload tenants
        sched = FabricScheduler(jax.devices())
        tenant = ServeTenant(sched, cfg, params, scfg,
                             floor=min(args.serve_floor, sched.num_clusters))
        t0 = time.time()
        if args.continuous:
            reqs, arrivals, _ = _continuous_trace(args, cfg)
            outs = tenant.generate_many(reqs,
                                        arrival_steps=arrivals.tolist())
            dt = time.time() - t0
            total = sum(len(o) for o in outs)
            head = f"continuous, {args.requests} requests"
            samples = [o[:12].tolist() for o in outs[:2]]
        else:
            stream = SyntheticStream(
                DataConfig(vocab_size=cfg.vocab_size,
                           batch_size=args.batch,
                           seq_len=args.prompt_len, seed=args.seed), cfg)
            out = tenant.generate(stream.batch(0)["tokens"],
                                  args.new_tokens)
            dt = time.time() - t0
            total = args.batch * args.new_tokens
            head = f"batch {args.batch}"
            samples = [out[b][:12].tolist() for b in range(min(2, args.batch))]
        print(f"[serve] fabric tenant ({head}): {total} tokens in "
              f"{dt:.2f}s ({total / dt:.1f} tok/s); lease floor "
              f"{tenant.lease.n}/{sched.num_clusters} clusters, burst "
              f"window {tenant.peak_burst}, free between bursts: "
              f"{len(sched.free_clusters())}")
        for i, s in enumerate(samples):
            print(f"  slot {i}: -> {s}")
        tenant.close()
        return
    engine = ServeEngine(cfg, params, mesh, scfg)
    # weight placement honours --staging: under "tree" every replicated
    # leaf crosses the host link once and fans out device-to-device
    engine.place_params(params)

    if args.continuous:
        reqs, arrivals, lens = _continuous_trace(args, cfg)
        t0 = time.time()
        outs = engine.generate_many(reqs, arrival_steps=arrivals.tolist())
        dt = time.time() - t0
        total = sum(len(o) for o in outs)
        print(f"[serve] continuous: {args.requests} requests, {total} tokens "
              f"in {dt:.2f}s ({total / dt:.1f} tok/s, batch {args.batch}, "
              f"{engine.stats['prefill_inserts']} inserts)")
        for r in range(min(2, args.requests)):
            print(f"  req {r}: prompt_len={lens[r]} arrival={arrivals[r]} "
                  f"-> {outs[r][:12].tolist()}")
        return

    stream = SyntheticStream(
        DataConfig(vocab_size=cfg.vocab_size, batch_size=args.batch,
                   seq_len=args.prompt_len, seed=args.seed), cfg)
    ex = stream.batch(0)
    prompts = ex["tokens"]
    extra = {k: v for k, v in ex.items() if k == "patches"}

    t0 = time.time()
    out = engine.generate(prompts, args.new_tokens, extra or None)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"[serve] generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, batch {args.batch})")
    for b in range(min(2, args.batch)):
        print(f"  slot {b}: prompt={prompts[b][:8].tolist()}... "
              f"-> {out[b][:16].tolist()}")


if __name__ == "__main__":
    main()
