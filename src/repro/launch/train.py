"""End-to-end training driver (CPU-runnable; the same code path the dry-run
AOT-compiles for the production mesh).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \\
        --steps 200 --batch 8 --seq 128 --mesh 1x1 --ckpt /tmp/run1

Every step is dispatched through the paper's offload model: per-step scalars
ride the multicast path (replicated shardings), the loss reduction is the
completion-unit arrival psum, and the host tracks completion + stragglers
through CompletionUnit/StepWatchdog.  ``--resume`` continues bit-for-bit
from the newest checkpoint (same data indices).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.core.completion import CompletionUnit
from repro.data import DataConfig, SyntheticStream
from repro.dist.sharding import to_shardings
from repro.ft.straggler import StepWatchdog
from repro.launch.mesh import make_mesh
from repro.models import get, init_params, reduced
from repro.optim.adamw import adamw_init
from repro.train import TrainConfig, build_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized sibling of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))

    stream = SyntheticStream(
        DataConfig(vocab_size=cfg.vocab_size, batch_size=args.batch,
                   seq_len=args.seq, seed=args.seed), cfg)
    ex = stream.batch(0)
    batch_shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                    for k, v in ex.items()}
    tcfg = TrainConfig(base_lr=args.lr, warmup_steps=max(1, args.steps // 20),
                       total_steps=args.steps, microbatches=args.microbatches)
    step_fn, pspecs, ospecs, bspecs = build_train_step(
        cfg, mesh, tcfg, batch_shapes)

    start = 0
    if args.resume and args.ckpt and latest_step(args.ckpt) is not None:
        start, data_index, state = restore(
            args.ckpt, mesh, {"params": pspecs, "opt": ospecs})
        params, opt = state["params"], state["opt"]
        print(f"[train] resumed step {start} (data index {data_index})")
    else:
        params = jax.device_put(init_params(jax.random.key(args.seed), cfg),
                                to_shardings(pspecs, mesh))
        opt = jax.device_put(adamw_init(params, tcfg.adamw),
                             to_shardings(ospecs, mesh))

    unit = CompletionUnit(n_units=4)
    watchdog = StepWatchdog()
    bshard = to_shardings(bspecs, mesh)
    t_start = time.time()
    for i in range(start, args.steps):
        batch = jax.device_put(stream.batch(i), bshard)
        unit.program(1, i)                      # offload register (fig. 6)
        t0 = time.monotonic()
        params, opt, metrics = step_fn(params, opt, batch, jnp.asarray(i))
        arrivals = int(metrics["arrivals"])    # fused completion reduction
        unit.arrive(i, arrivals)
        assert unit.clear() == i
        watchdog.observe(time.monotonic() - t0)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"[train] step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"deadline={watchdog.deadline():.2f}s")
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            save(args.ckpt, i + 1, {"params": params, "opt": opt},
                 {"params": pspecs, "opt": ospecs}, data_index=i + 1)
    dt = time.time() - t_start
    steps_run = args.steps - start
    print(f"[train] done: {steps_run} steps in {dt:.1f}s "
          f"({steps_run / max(dt, 1e-9):.2f} steps/s)")
    if args.ckpt:
        save(args.ckpt, args.steps, {"params": params, "opt": opt},
             {"params": pspecs, "opt": ospecs}, data_index=args.steps)


if __name__ == "__main__":
    main()
