"""Aggregate dry-run JSON records into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.launch.report results/dryrun [--tag baseline]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


def load_records(directory: str, tag: str = None) -> List[Dict]:
    recs = []
    for f in sorted(os.listdir(directory)):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(directory, f)) as fh:
            r = json.load(fh)
        if tag and r.get("tag") != tag:
            continue
        recs.append(r)
    return recs


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}GB"


def roofline_table(recs: List[Dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | status | t_comp (s) | t_mem (s) | t_coll (s) | "
        "bottleneck | MODEL/HLO | roofline frac | args/dev | temp/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}…) "
                f"| - | - | - | - | - | - | - | - |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | - | - "
                f"| - | - | - |")
            continue
        ro = r["roofline"]
        mem = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {ro['t_compute_s']:.3e} | {ro['t_memory_s']:.3e} "
            f"| {ro['t_collective_s']:.3e} | {ro['bottleneck']} "
            f"| {ro['useful_flops_fraction']:.3f} "
            f"| {ro['roofline_fraction']:.4f} "
            f"| {fmt_bytes(mem['argument_bytes_per_device'])} "
            f"| {fmt_bytes(mem['temp_bytes_per_device'])} |")
    return "\n".join(lines)


def summarize(recs: List[Dict]) -> str:
    pods = [r for r in recs if r["mesh"] == "pod16x16"]
    mpods = [r for r in recs if r["mesh"] == "pod2x16x16"]
    ok_p = sum(1 for r in pods if r["status"] == "ok")
    ok_m = sum(1 for r in mpods if r["status"] == "ok")
    sk_p = sum(1 for r in pods if r["status"] == "skipped")
    sk_m = sum(1 for r in mpods if r["status"] == "skipped")
    er = [f"{r['arch']}×{r['shape']}×{r['mesh']}"
          for r in recs if r["status"] == "error"]
    out = [f"single-pod 16x16: {ok_p} ok, {sk_p} documented skips",
           f"multi-pod 2x16x16: {ok_m} ok, {sk_m} documented skips"]
    if er:
        out.append(f"ERRORS: {er}")
    # interesting cells for hillclimbing
    ok_cells = [r for r in pods if r["status"] == "ok"]
    if ok_cells:
        worst = min(ok_cells, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok_cells, key=lambda r: r["roofline"]["t_collective_s"]
                   / max(1e-30, r["roofline"]["t_compute_s"]))
        out.append(f"worst roofline fraction: {worst['arch']}×{worst['shape']} "
                   f"({worst['roofline']['roofline_fraction']:.4f})")
        out.append(f"most collective-bound: {coll['arch']}×{coll['shape']} "
                   f"(t_coll/t_comp="
                   f"{coll['roofline']['t_collective_s']/max(1e-30, coll['roofline']['t_compute_s']):.2f})")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("directory")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    recs = load_records(args.directory, args.tag)
    print(summarize(recs))
    print()
    print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
