"""Deterministic synthetic data pipeline.

A seeded, stateless token stream: batch ``i`` is a pure function of
(seed, i), so any worker can regenerate any batch — exactly the property
fault-tolerant restart needs (resume from step k replays batch k bit-for-bit,
tested in tests/test_checkpoint.py).  The stream synthesizes a Zipf-ish
unigram mixture with short-range structure so losses move during the
end-to-end examples (unstructured uniform tokens give a flat loss).
"""

from repro.data.pipeline import DataConfig, SyntheticStream, input_specs

__all__ = ["DataConfig", "SyntheticStream", "input_specs"]
