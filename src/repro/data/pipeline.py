"""Synthetic token stream + dry-run input specs.

``SyntheticStream.batch(i)`` is a pure function of (seed, i): restartable,
shardable (each data-parallel group slices its rows), and cheap.  The token
distribution is Zipf-like with a 30 % repeat-previous structure so a model
can actually reduce loss on it (examples/train_lm.py shows ~2-nat drops in a
few hundred steps).

``input_specs`` is the dry-run contract (system prompt step 2): weak-type-
correct ``ShapeDtypeStruct`` stand-ins for every model input of a given
(architecture × input-shape) cell — no device allocation ever happens.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch_size: int
    seq_len: int
    seed: int = 0
    zipf_alpha: float = 1.1
    repeat_prob: float = 0.3


class SyntheticStream:
    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(key=[c.seed, index]))
        b, s = c.batch_size, c.seq_len
        # Zipf-ish unigram draw via inverse-CDF power law.
        u = rng.random((b, s + 1))
        base = np.minimum(
            (c.vocab_size * u ** c.zipf_alpha).astype(np.int64),
            c.vocab_size - 1,
        )
        # Short-range structure: repeat the previous token with prob p.
        rep = rng.random((b, s + 1)) < c.repeat_prob
        toks = base.copy()
        for col in range(1, s + 1):
            toks[:, col] = np.where(rep[:, col], toks[:, col - 1], toks[:, col])
        out = {
            "tokens": toks[:, :s].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        mc = self.model_cfg
        if mc is not None and mc.frontend and mc.frontend.kind == "vision_stub":
            # Precomputed patch embeddings (the SigLIP stub): deterministic.
            p = mc.frontend.n_prefix_tokens
            out["patches"] = rng.standard_normal(
                (b, p, mc.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self):
        i = 0
        while True:
            yield self.batch(i)
            i += 1


# --- dry-run input specs -----------------------------------------------------------


def input_specs(
    cfg: ModelConfig,
    *,
    mode: str,                  # "train" | "prefill" | "decode"
    batch: int,
    seq: int,
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    i32 = jnp.int32
    f32 = jnp.float32
    if mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend and cfg.frontend.kind == "vision_stub":
        p = cfg.frontend.n_prefix_tokens
        text = max(seq - p, 1)
        specs["patches"] = jax.ShapeDtypeStruct((batch, p, cfg.d_model), f32)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, text), i32)
        if mode == "train":
            specs["labels"] = jax.ShapeDtypeStruct((batch, text), i32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
    if mode == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    return specs
