"""AdamW with decoupled weight decay and global-norm clipping (pure JAX).

Optimizer state is a pytree congruent with the parameters, so it inherits
the parameters' shardings (ZeRO-style: 2-D-sharded params ⇒ 2-D-sharded
moments; nothing is replicated that does not have to be).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0         # 0 disables clipping
    moment_dtype: str = "float32"


def adamw_init(params: Pytree, cfg: AdamWConfig = AdamWConfig()) -> Dict[str, Pytree]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Pytree,
    state: Dict[str, Pytree],
    params: Pytree,
    lr: jnp.ndarray,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Pytree, Dict[str, Pytree], Dict[str, jnp.ndarray]]:
    """-> (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        step = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, {"grad_norm": gnorm}
