"""Static offload verification + virtual-cycle hazard sanitizing.

``repro.analysis`` is the compiler front-end to the offload back-end:

* :mod:`~repro.analysis.diagnostics` — the stable ``OFL###`` code table
  and the typed :class:`Diagnostic` record (dependency-free leaf).
* :mod:`~repro.analysis.verifier` — :func:`verify_graph` /
  :func:`verify` / :func:`verify_policy`, run automatically by
  :class:`repro.core.session.Session` before any staging.
* :mod:`~repro.analysis.sanitizer` — ``REPRO_SANITIZE=1`` vector-clock
  happens-before instrumentation of the live runtime protocol
  (dependency-free leaf).
* :mod:`~repro.analysis.perflint` — the performance twin of the
  verifier: :func:`lint` / :func:`lint_graph` / :func:`lint_session`
  run the §6 cost models over a submission and emit ``OFLP1##``
  findings (severity ``PERF``) with machine-applicable fixes
  (:func:`perflint.apply`); surfaced by ``Session.submit(lint=True)``
  and the ``python -m repro.lint`` CLI.

The leaves import eagerly; :mod:`~repro.analysis.verifier` and
:mod:`~repro.analysis.perflint` pull in the core modules, so their
names resolve lazily (PEP 562) — core modules may ``from repro.analysis
import diagnostics, sanitizer`` at module level without a cycle.
"""

from __future__ import annotations

from typing import Any

from . import diagnostics, sanitizer
from .diagnostics import (
    CODES, Diagnostic, DiagnosticsLog, Severity, UnknownDiagnosticCode,
    contradiction, explain, invalid_field, invalid_mode, use_after_donate,
)
from .sanitizer import Sanitizer, SanitizerError

__all__ = [
    "CODES", "Diagnostic", "DiagnosticsLog", "Fix", "PerfFinding",
    "Sanitizer", "SanitizerError", "Severity", "UnknownDiagnosticCode",
    "VerificationError", "contradiction", "diagnostics", "explain",
    "invalid_field", "invalid_mode", "lint", "lint_graph", "lint_session",
    "perflint", "sanitizer", "use_after_donate", "verifier", "verify",
    "verify_graph", "verify_policy",
]

_VERIFIER_NAMES = ("VerificationError", "verify", "verify_graph",
                   "verify_policy", "raise_errors")

_PERFLINT_NAMES = ("Applied", "Fix", "PerfFinding", "lint", "lint_graph",
                   "lint_session", "suggested_policy")


def __getattr__(name: str) -> Any:
    if name == "verifier" or name in _VERIFIER_NAMES:
        import importlib
        mod = importlib.import_module(".verifier", __name__)
        if name == "verifier":
            return mod
        return getattr(mod, name)
    if name == "perflint" or name in _PERFLINT_NAMES:
        import importlib
        mod = importlib.import_module(".perflint", __name__)
        if name == "perflint":
            return mod
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
