"""Static offload verification + virtual-cycle hazard sanitizing.

``repro.analysis`` is the compiler front-end to the offload back-end:

* :mod:`~repro.analysis.diagnostics` — the stable ``OFL###`` code table
  and the typed :class:`Diagnostic` record (dependency-free leaf).
* :mod:`~repro.analysis.verifier` — :func:`verify_graph` /
  :func:`verify` / :func:`verify_policy`, run automatically by
  :class:`repro.core.session.Session` before any staging.
* :mod:`~repro.analysis.sanitizer` — ``REPRO_SANITIZE=1`` vector-clock
  happens-before instrumentation of the live runtime protocol
  (dependency-free leaf).

The leaves import eagerly; :mod:`~repro.analysis.verifier` pulls in the
core modules, so its names resolve lazily (PEP 562) — core modules may
``from repro.analysis import diagnostics, sanitizer`` at module level
without a cycle.
"""

from __future__ import annotations

from typing import Any

from . import diagnostics, sanitizer
from .diagnostics import (
    CODES, Diagnostic, Severity, contradiction, explain, invalid_field,
    invalid_mode, use_after_donate,
)
from .sanitizer import Sanitizer, SanitizerError

__all__ = [
    "CODES", "Diagnostic", "Sanitizer", "SanitizerError", "Severity",
    "VerificationError", "contradiction", "diagnostics", "explain",
    "invalid_field", "invalid_mode", "sanitizer", "use_after_donate",
    "verifier", "verify", "verify_graph", "verify_policy",
]

_VERIFIER_NAMES = ("VerificationError", "verify", "verify_graph",
                   "verify_policy", "raise_errors")


def __getattr__(name: str) -> Any:
    if name == "verifier" or name in _VERIFIER_NAMES:
        import importlib
        mod = importlib.import_module(".verifier", __name__)
        if name == "verifier":
            return mod
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
