"""Virtual-cycle hazard sanitizer — ``REPRO_SANITIZE=1``.

Vector-clock happens-before instrumentation for the offload runtime's
host-side protocol.  The core modules call the hooks below at every
buffer staging/forward/donation, scoreboard issue/retire, completion
collect/cancel, and lease grant; when the sanitizer is off
(:func:`active` returns ``None`` — the default) each hook site costs
one function call and a ``None`` check, so the instrumented runtime is
the shipped runtime.

What it asserts (each violation raises :class:`SanitizerError` with
both events' vector clocks in the message):

* **no read-after-donate / read-after-revoke** — every staged, forwarded
  or result buffer is tracked; a donating launch marks its operands
  donated, ``DispatchPlan.invalidate`` marks residents revoked, and any
  later read of such a buffer (forward, resident redispatch, result
  fetch) fails.
* **issue order consistent with declared deps** — a scoreboard node's
  issue event must happen-after every producer's issue event: each
  node's clock is the merge of its producers' clocks plus its own tick,
  so a consumer issued before a producer has no clock to merge and
  fails.  Retire requires issued-exactly-once.
* **completion protocol** — ``collect`` must follow ``program`` for the
  same job on the same unit and never repeats; ``cancel`` withdraws the
  job so a later collect of it fails.
* **no lease-window overlap** — a fabric grant must not hand a cluster
  that another live lease still owns.

The module is dependency-free (no jax, no other ``repro`` imports) so
every core module can import it at module level without cycles.
"""

from __future__ import annotations

import os
import weakref
from typing import Any, Dict, List, Mapping, Optional, Tuple

__all__ = [
    "SanitizerError", "Sanitizer", "VClock", "active", "disable", "enable",
]

ENV_VAR = "REPRO_SANITIZE"


class SanitizerError(RuntimeError):
    """A virtual-cycle hazard the sanitizer caught (see module docs)."""


class VClock:
    """A tiny vector clock: one component per event actor."""

    __slots__ = ("_c",)

    def __init__(self, components: Optional[Mapping[str, int]] = None):
        self._c: Dict[str, int] = dict(components or {})

    def tick(self, actor: str) -> "VClock":
        self._c[actor] = self._c.get(actor, 0) + 1
        return self

    def merge(self, other: "VClock") -> "VClock":
        for k, v in other._c.items():
            if v > self._c.get(k, 0):
                self._c[k] = v
        return self

    def dominates(self, other: "VClock") -> bool:
        """True when every component of ``other`` is <= ours (other
        happened-before-or-equal this clock)."""
        return all(self._c.get(k, 0) >= v for k, v in other._c.items())

    def copy(self) -> "VClock":
        return VClock(self._c)

    def __repr__(self) -> str:
        inner = ",".join(f"{k}:{v}" for k, v in sorted(self._c.items()))
        return "{" + inner + "}"


#: tracked-buffer lifecycle states
_LIVE, _DONATED, _REVOKED = "live", "donated", "revoked"


class Sanitizer:
    """The event recorder + hazard checks.  One instance per process
    (see :func:`active`); tests may construct their own via
    :func:`enable`."""

    def __init__(self) -> None:
        self.events = 0
        self.violations = 0
        self._now = VClock()
        # id(buffer) -> [state, description, strong ref, state's clock].
        # The strong ref pins the id; donation deletes the device memory
        # regardless, so the tombstone costs only the host object.
        self._buffers: Dict[int, List[Any]] = {}
        # scoreboard id -> [weakref|None, node->issue clock, node->state].
        # The weakref guards against id() reuse: a fresh scoreboard at a
        # recycled address must not inherit a dead one's state.
        self._sb: Dict[int, List[Any]] = {}
        # completion-unit id -> [weakref|None, programmed, collected]
        self._units: Dict[int, List[Any]] = {}

    # -- plumbing -----------------------------------------------------------

    def _fail(self, message: str) -> None:
        self.violations += 1
        raise SanitizerError(f"{ENV_VAR}: {message}")

    def _tick(self, actor: str = "host") -> VClock:
        self.events += 1
        return self._now.tick(actor).copy()

    @staticmethod
    def _slot(table: Dict[int, List[Any]], obj: Any,
              fresh: Tuple[Any, ...]) -> List[Any]:
        """Per-object state, keyed by id but pinned by weakref so a new
        object at a recycled address starts clean.  Ints (tests driving
        the hooks directly) key by value and persist."""
        key = obj if isinstance(obj, int) else id(obj)
        rec = table.get(key)
        if rec is not None and (rec[0] is None or rec[0]() is obj):
            return rec
        ref = None
        if not isinstance(obj, int):
            try:
                ref = weakref.ref(obj)
            except TypeError:
                ref = None
        rec = [ref] + [f() for f in fresh]
        table[key] = rec
        return rec

    # -- buffer lifecycle ---------------------------------------------------

    def track(self, buf: Any, what: str) -> None:
        """A buffer came alive (staged, forwarded copy, launch result)."""
        if buf is None:
            return
        self._buffers[id(buf)] = [_LIVE, what, buf, self._tick()]

    def read(self, buf: Any, what: str) -> None:
        """``what`` reads ``buf`` — fails if donated/revoked."""
        if buf is None:
            return
        vc = self._tick()
        rec = self._buffers.get(id(buf))
        if rec is not None and rec[0] != _LIVE:
            self._fail(
                f"read-after-{rec[0]}: {what} reads {rec[1]}, "
                f"{rec[0]} at {rec[3]!r} (read at {vc!r})")

    def _mark(self, buf: Any, state: str, what: Optional[str]) -> None:
        if buf is None:
            return
        vc = self._tick()
        rec = self._buffers.get(id(buf))
        if rec is None:
            self._buffers[id(buf)] = [state, what or "buffer", buf, vc]
        else:
            rec[0], rec[3] = state, vc

    def donate(self, buf: Any, what: Optional[str] = None) -> None:
        """A donating launch consumed ``buf`` (XLA deleted it)."""
        self._mark(buf, _DONATED, what)

    def revoke(self, buf: Any, what: Optional[str] = None) -> None:
        """``buf`` was invalidated (plan.invalidate / lease revocation)."""
        self._mark(buf, _REVOKED, what)

    def revive(self, buf: Any, what: str) -> None:
        """A restage replaced ``buf``'s role with a fresh live buffer."""
        self.track(buf, what)

    # -- scoreboard issue/retire --------------------------------------------

    def sb_issue(self, sb: Any, node: int, deps: Tuple[int, ...]) -> None:
        rec = self._slot(self._sb, sb, (dict, dict))
        clocks, states = rec[1], rec[2]
        if node in states:
            self._fail(f"scoreboard node {node} issued twice "
                       f"(state {states[node]!r})")
        vc = VClock()
        for d in deps:
            dvc = clocks.get(d)
            if dvc is None:
                self._fail(
                    f"issue order violates declared deps: node {node} "
                    f"issued before its producer {d} (issued so far: "
                    f"{sorted(clocks)})")
            else:
                vc.merge(dvc)
        sid = sb if isinstance(sb, int) else id(sb)
        vc.tick(f"sb{sid % 9973}.n{node}")
        self.events += 1
        clocks[node] = vc
        states[node] = "issued"
        # sanity: by construction our clock dominates every producer's
        for d in deps:
            if not vc.dominates(clocks[d]):
                self._fail(
                    f"node {node}'s issue clock {vc!r} does not dominate "
                    f"producer {d}'s {clocks[d]!r}")

    def sb_retire(self, sb: Any, node: int) -> None:
        states = self._slot(self._sb, sb, (dict, dict))[2]
        if states.get(node) != "issued":
            self._fail(f"retire of scoreboard node {node} in state "
                       f"{states.get(node)!r} (want 'issued')")
        states[node] = "retired"
        self.events += 1

    # -- completion unit ----------------------------------------------------

    def unit_program(self, unit: Any, job_id: int) -> None:
        rec = self._slot(self._units, unit, (set, set))
        rec[1].add(job_id)
        rec[2].discard(job_id)
        self.events += 1

    def unit_collect(self, unit: Any, job_id: int) -> None:
        rec = self._slot(self._units, unit, (set, set))
        programmed, collected = rec[1], rec[2]
        if job_id in collected:
            self._fail(f"job {job_id} collected twice from completion "
                       "unit (double retire/wait would steal another "
                       "job's parked cause)")
        if job_id not in programmed:
            self._fail(f"collect for job {job_id} that was never "
                       "programmed on this unit (or was cancelled)")
        collected.add(job_id)
        self.events += 1

    def unit_cancel(self, unit: Any, job_id: int) -> None:
        # cancel withdraws the job: a later collect of it is a hazard
        rec = self._slot(self._units, unit, (set, set))
        rec[1].discard(job_id)
        rec[2].discard(job_id)
        self.events += 1

    # -- fabric leases ------------------------------------------------------

    def lease_grant(self, lease_id: int, clusters: Tuple[int, ...],
                    owner: Mapping[int, int]) -> None:
        """Check a grant's window against the scheduler's live owner map
        (a resize re-granting the same lease id is not an overlap)."""
        self.events += 1
        clash = {c: owner[c] for c in clusters
                 if c in owner and owner[c] != lease_id}
        if clash:
            self._fail(
                f"lease-window overlap: lease {lease_id} granted "
                f"clusters {sorted(clash)} still owned by leases "
                f"{sorted(set(clash.values()))}")

    # -- reporting ----------------------------------------------------------

    def report(self) -> Dict[str, int]:
        return {"events": self.events, "violations": self.violations,
                "tracked_buffers": len(self._buffers)}


_instance: Optional[Sanitizer] = None
_resolved = False


def active() -> Optional[Sanitizer]:
    """The process sanitizer, or ``None`` when off (the hook fast path).

    Resolved once from ``REPRO_SANITIZE`` (any value but ``""``/``0``
    enables); :func:`enable`/:func:`disable` override programmatically.
    """
    global _instance, _resolved
    if not _resolved:
        _resolved = True
        if os.environ.get(ENV_VAR, "0") not in ("", "0"):
            _instance = Sanitizer()
    return _instance


def enable() -> Sanitizer:
    """Turn the sanitizer on for this process (fresh instance)."""
    global _instance, _resolved
    _resolved = True
    _instance = Sanitizer()
    return _instance


def disable() -> None:
    """Turn the sanitizer off for this process."""
    global _instance, _resolved
    _resolved = True
    _instance = None
