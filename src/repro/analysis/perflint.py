"""Model-driven performance linting — the §6 cost model as a compiler pass.

PR 9's verifier answers *"is this submission correct?"*; this module
answers the paper's other question: *"is it leaving predicted cycles on
the table?"*.  Every pass abstractly interprets a submission — a
(job, policy, selection) triple or a ``submit_graph`` node list —
against the validated cost models (``staging_model`` /
``simulate_staging``, the eq.-4 amortization terms,
``graph_critical_path`` / ``forward_model``, the multicast subcube
encoder) and emits ``OFLP1##`` findings with severity
:attr:`~repro.analysis.diagnostics.Severity.PERF`:

=======  ==============================================================
OFLP101  pinned ``staging=`` slower than the model's best mode
OFLP102  batched submit pins ``fuse=`` below the model-optimal factor
OFLP103  ``window=`` pins the pipeline below the model's pick
OFLP104  a dataflow edge pays a d2d reshard on the critical path
OFLP105  the cluster selection needs >1 multicast request
OFLP106  ``Session.stage()`` residency never redispatched
OFLP107  donation disabled where fused stacked buffers die at launch
=======  ==============================================================

Each :class:`PerfFinding` carries the model-predicted cycles of the
current configuration, the cycles with the fix applied, and a
machine-applicable :class:`Fix`; :func:`apply` rewrites a policy /
node list / selection from a batch of findings, and
:func:`suggested_policy` is the one-liner for the common policy case.

PERF findings never gate a submit (``raise_errors`` raises on ERROR
only); they surface through ``Session.submit(..., lint=True)``,
``handle.explain()``, the ``python -m repro.lint`` CLI (JSON/SARIF,
baselines, suppressions) and the ``perflint`` bench suite, which
measures that applying every autofix reduces simulated cycles.

Like the verifier, linting is advisory *static* analysis: it needs no
devices and never touches the runtime.
"""

from __future__ import annotations

import dataclasses
import math
from typing import (
    Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union,
)

import numpy as np

from repro.core import model as amodel
from repro.core import simulator
from repro.core.params import DEFAULT_PARAMS, OccamyParams
from repro.core.phases import Phase
from repro.core.policy import (
    AUTO, InfoDist, OffloadPolicy, Residency, Staging,
)
from repro.core.scoreboard import GraphNode, Ref
from repro.core.session import CONST_PHASES, Planner, amortized_per_job

from . import verifier as _verifier
from .diagnostics import CODES, Diagnostic, Severity

__all__ = [
    "Applied", "Fix", "PerfFinding", "apply", "dispatch_replay_cycles",
    "donation_copy_cycles", "graph_jobs", "lint", "lint_graph",
    "lint_session", "suggested_policy",
]

#: a finding must beat the baseline by this fraction of its own cost
#: (plus an absolute floor of one cycle) — the §6 model's error bar is
#: 15 %, so sub-2 % "improvements" are noise, not advice
MIN_DELTA_FRAC = 0.02

#: dispatch front-end phases replayed per extra multicast request
#: (send job information, wakeup, pointer + argument retrieval)
_REPLAY_PHASES = (Phase.A, Phase.B, Phase.C, Phase.D)


# -- records -----------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Fix:
    """One machine-applicable rewrite.

    ``target`` says what :func:`apply` patches: ``"policy"`` pins a
    policy field, ``"node"`` rewrites an attribute of graph node
    ``node``, ``"selection"`` replaces a submit's ``clusters=``, and
    ``"stage"`` asks the caller to drop a dead ``Session.stage()`` call
    (advice only — apply() cannot un-stage device memory).
    """

    target: str
    field: str
    value: Any
    node: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PerfFinding:
    """One ``OFLP1##`` finding: a diagnostic plus its cycle economics.

    ``predicted_cycles`` models the affected leg under the current
    configuration, ``optimal_cycles`` the same leg with ``fix``
    applied; ``delta`` is the predicted saving.
    """

    diagnostic: Diagnostic
    predicted_cycles: float
    optimal_cycles: float
    fix: Optional[Fix] = None

    @property
    def code(self) -> str:
        return self.diagnostic.code

    @property
    def node(self) -> Optional[int]:
        return self.diagnostic.node

    @property
    def delta(self) -> float:
        return self.predicted_cycles - self.optimal_cycles

    def key(self) -> str:
        """Stable identity for baselines: code + fix site, no cycle
        numbers (model retunes must not churn a committed baseline)."""
        fx = self.fix
        site = (f"{fx.target}.{fx.field}" if fx is not None else "-")
        where = "-" if self.node is None else str(self.node)
        return f"{self.code}:{site}:node={where}"

    def __str__(self) -> str:
        return (f"{self.diagnostic} (predicted -{self.delta:.0f} cycles: "
                f"{self.predicted_cycles:.0f} -> "
                f"{self.optimal_cycles:.0f})")

    def to_payload(self) -> Dict[str, Any]:
        import json
        return {
            "diagnostic": json.loads(self.diagnostic.to_json()),
            "predicted_cycles": self.predicted_cycles,
            "optimal_cycles": self.optimal_cycles,
            "fix": None if self.fix is None else dataclasses.asdict(self.fix),
            "key": self.key(),
        }

    @classmethod
    def from_payload(cls, d: Mapping[str, Any]) -> "PerfFinding":
        diag = d["diagnostic"]
        fix = d.get("fix")
        fx: Optional[Fix] = None
        if fix is not None:
            value = fix["value"]
            if isinstance(value, list):
                value = tuple(value)
            fx = Fix(target=fix["target"], field=fix["field"], value=value,
                     node=fix.get("node"))
        return cls(
            diagnostic=Diagnostic(
                code=diag["code"], message=diag["message"],
                severity=Severity(diag["severity"]), node=diag.get("node"),
                name=diag.get("name"),
                suggestion=diag.get("suggestion", "")),
            predicted_cycles=float(d["predicted_cycles"]),
            optimal_cycles=float(d["optimal_cycles"]), fix=fx)


@dataclasses.dataclass
class Applied:
    """What :func:`apply` rewrote (and what it could not)."""

    policy: Optional[OffloadPolicy] = None
    nodes: Optional[List[GraphNode]] = None
    clusters: Optional[Tuple[int, ...]] = None
    applied: List[PerfFinding] = dataclasses.field(default_factory=list)
    skipped: List[PerfFinding] = dataclasses.field(default_factory=list)


# -- shared model pieces -----------------------------------------------------


def dispatch_replay_cycles(spec: simulator.JobSpec, n: int,
                           params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Dispatch front-end cycles replayed per extra multicast request
    (phases A-D of the eq.-4 terms at width ``n``)."""
    terms = amodel.predict(spec, n, params).terms
    return sum(terms.get(p, 0.0) for p in _REPLAY_PHASES)


def donation_copy_cycles(nbytes: float,
                         params: OccamyParams = DEFAULT_PARAMS) -> float:
    """Device-side buffer copy one non-donating fused launch pays to
    materialize its output instead of aliasing the dead stacked operand
    (the same per-hop DMA term the forward model charges)."""
    p = params
    return (p.dma_setup_one + max(1.0, nbytes / p.wide_bw_bytes_per_cycle)
            + p.dma_latency)


def _significant(cur: float, opt: float) -> bool:
    return (cur - opt) > max(1.0, MIN_DELTA_FRAC * max(cur, 1.0))


def _finding(code: str, message: str, cur: float, opt: float,
             fix: Optional[Fix] = None, node: Optional[int] = None,
             name: Optional[str] = None,
             suggestion: str = "") -> PerfFinding:
    return PerfFinding(
        diagnostic=Diagnostic(code, message, severity=CODES[code].severity,
                              node=node, name=name, suggestion=suggestion),
        predicted_cycles=float(cur), optimal_cycles=float(opt), fix=fix)


def _phase_terms(spec: simulator.JobSpec, n: int, policy: OffloadPolicy,
                 params: OccamyParams) -> Dict[Phase, float]:
    """The eq.-4 per-phase terms `estimate` would report for this
    implementation (closed form for multicast, simulated baseline)."""
    if policy.info_dist is InfoDist.MULTICAST:
        return dict(amodel.predict(spec, n, params).terms)
    sim = simulator.simulate(spec, n, "baseline", params)
    return {ph: st.max for ph, st in sim.phase_stats().items()}


def _normalize_selection(n: Optional[int], clusters: Optional[Sequence[int]],
                         params: OccamyParams) -> List[int]:
    if clusters is not None:
        return sorted({int(c) for c in clusters})
    width = int(n) if n is not None else min(8, params.num_clusters)
    return list(range(width))


def _host_shapes(job: Any, operands: Mapping[str, Any]
                 ) -> Optional[Dict[str, Tuple[int, ...]]]:
    shapes: Dict[str, Tuple[int, ...]] = {}
    for name, v in operands.items():
        shape = _verifier._shape_of(v)
        if shape is None:
            return None
        shapes[name] = shape
    return shapes


def _shard_ok(job: Any, operands: Mapping[str, Any], width: int) -> bool:
    """Would every sharded operand split evenly over ``width`` clusters?"""
    for name, v in operands.items():
        axis = job.shard_axes.get(name)
        if axis is None:
            continue
        shape = _verifier._shape_of(v)
        if shape is None or axis >= len(shape) or shape[axis] % width:
            return False
    return True


def _aligned_windows(width: int, allowed: Sequence[int],
                     num_clusters: int) -> List[Tuple[int, ...]]:
    """Single-request candidates near ``width``: aligned power-of-two
    windows (size = the pow2 bracket around ``width``) inside the
    allowed cluster set."""
    lo = 1 << max(0, int(math.floor(math.log2(max(1, width)))))
    sizes = {lo} if lo == width else {lo, min(num_clusters, lo << 1)}
    allow = set(int(c) for c in allowed)
    out: List[Tuple[int, ...]] = []
    for k in sorted(sizes):
        for base in range(0, num_clusters, k):
            w = tuple(range(base, base + k))
            if set(w) <= allow:
                out.append(w)
    return out


# -- the single-submit passes ------------------------------------------------


def lint(job: Any, operands: Optional[Mapping[str, Any]] = None, *,
         policy: Optional[OffloadPolicy] = None,
         batch: int = 1,
         n: Optional[int] = None,
         clusters: Optional[Sequence[int]] = None,
         allowed: Optional[Sequence[int]] = None,
         n_units: int = 4,
         params: OccamyParams = DEFAULT_PARAMS,
         planner: Optional[Planner] = None) -> List[PerfFinding]:
    """Perf-lint one ``Session.submit``-shaped dispatch (model only).

    Mirrors :func:`repro.core.session.estimate`'s inputs; ``allowed``
    bounds OFLP105's rewrite candidates to a lease window (defaults to
    the full mesh).  Returns findings sorted by predicted saving;
    configurations the verifier rejects return no findings (lint is
    meaningful for *valid* submissions only).
    """
    pol = AUTO if policy is None else policy
    if any(d.severity is Severity.ERROR
           for d in _verifier.verify_policy(pol)):
        return []
    sel = _normalize_selection(n, clusters, params)
    width = len(sel)
    if width < 1 or batch < 1:
        return []
    plan = planner or Planner(params)
    if operands is None:
        operands, _ = job.make_instance(0)
    resident = pol.residency is Residency.RESIDENT
    decision = plan.decide(job, sel, batch, pol, n_units, operands=operands)
    rep = plan.replicated_bytes(job, operands)
    terms = _phase_terms(job.spec, width, pol, params)
    findings: List[PerfFinding] = []

    # OFLP101 — pinned staging mode vs. the model's best (cycle domain,
    # the ordering the staging suite validates; the code's explain text
    # carries the substrate wallclock caveat).
    if pol.staging is not None and not resident and rep > 0 and width >= 2:
        eff = rep * decision.fuse
        fan = plan.staging_cost(eff, sel, Staging.HOST_FANOUT)
        tree = plan.staging_cost(eff, sel, Staging.TREE)
        cur = tree if pol.staging in (Staging.TREE, Staging.TREE_RESHARD) \
            else fan
        best_mode = Staging.TREE if tree < fan else Staging.DIRECT
        best = min(tree, fan)
        if _significant(cur, best):
            findings.append(_finding(
                "OFLP101",
                f"staging={pol.staging.value} moves {eff} replicated "
                f"bytes in {cur:.0f} cycles where "
                f"{best_mode.value} takes {best:.0f}",
                cur, best, fix=Fix("policy", "staging", best_mode.value),
                name="staging",
                suggestion=f"pin staging={best_mode.value!r} (or leave it "
                           f"open for the planner)"))

    # OFLP102 — pinned fuse below the planner's pick on a batched submit.
    if batch > 1 and pol.fuse is not None and not resident:
        best_f = min(plan.pick_fuse(job.spec, width, batch), batch)
        if decision.fuse < best_f:
            def _total(f: int) -> float:
                w = (pol.window if pol.window is not None
                     else plan.pick_window(batch, f, n_units))
                return batch * amortized_per_job(terms, f, w)
            cur, opt = _total(decision.fuse), _total(best_f)
            if _significant(cur, opt):
                findings.append(_finding(
                    "OFLP102",
                    f"fuse={decision.fuse} pays the dispatch constant "
                    f"{math.ceil(batch / decision.fuse)}x over batch="
                    f"{batch}; fuse={best_f} amortizes it",
                    cur, opt, fix=Fix("policy", "fuse", best_f),
                    name="fuse",
                    suggestion=f"pin fuse={best_f} (or leave it open)"))

    # OFLP103 — pinned window below the planner's pick.
    if pol.window is not None and not resident:
        opt_w = plan.pick_window(batch, decision.fuse, n_units)
        if decision.window < opt_w:
            cur = batch * amortized_per_job(terms, decision.fuse,
                                            decision.window)
            opt = batch * amortized_per_job(terms, decision.fuse, opt_w)
            if _significant(cur, opt):
                findings.append(_finding(
                    "OFLP103",
                    f"window={decision.window} runs the pipeline "
                    f"synchronously; window={opt_w} overlaps host work "
                    f"with device phases",
                    cur, opt, fix=Fix("policy", "window", opt_w),
                    name="window",
                    suggestion=f"pin window={opt_w} (or leave it open)"))

    # OFLP105 — the selection decomposes into several multicast requests.
    if clusters is not None:
        f105 = _lint_selection(job, operands, sel, decision, rep, params,
                               plan, allowed=allowed)
        if f105 is not None:
            findings.append(f105)

    # OFLP107 — fused fresh staging with donation off and an output-
    # shaped operand: the stacked input buffers die at launch.
    if (not pol.donate_operands and not resident and decision.fuse > 1
            and isinstance(operands, Mapping)):
        f107 = _lint_donation(job, operands, decision, batch, params)
        if f107 is not None:
            findings.append(f107)

    findings.sort(key=lambda f: -f.delta)
    return findings


def _submit_selection_cost(job: Any, s: Sequence[int], rep: int,
                           staging: Staging, params: OccamyParams,
                           plan: Planner) -> float:
    r = simulator.selection_requests(s, params.num_clusters)
    total = amodel.predict_total_v2(job.spec, len(s), params)
    stag = plan.staging_cost(rep, s, staging) if rep > 0 else 0.0
    return total + stag + (r - 1) * dispatch_replay_cycles(
        job.spec, len(s), params)


def _lint_selection(job: Any, operands: Mapping[str, Any],
                    sel: List[int], decision: Any, rep: int,
                    params: OccamyParams, plan: Planner, *,
                    allowed: Optional[Sequence[int]] = None,
                    node: Optional[int] = None,
                    name: Optional[str] = None) -> Optional[PerfFinding]:
    """OFLP105 for one explicit selection (submit or graph node)."""
    r = simulator.selection_requests(sel, params.num_clusters)
    if r <= 1:
        return None
    allow = (list(allowed) if allowed is not None
             else list(range(params.num_clusters)))
    cands = [w for w in _aligned_windows(len(sel), allow, params.num_clusters)
             if _shard_ok(job, operands, len(w))]
    if not cands:
        return None
    cur = _submit_selection_cost(job, sel, rep, decision.staging, params,
                                 plan)
    scored = sorted(
        (_submit_selection_cost(job, w, rep, decision.staging, params,
                                plan), w) for w in cands)
    best_cost, best = scored[0]
    if not _significant(cur, best_cost):
        return None
    target = "node" if node is not None else "selection"
    return _finding(
        "OFLP105",
        f"clusters={list(sel)} needs {r} multicast requests; the "
        f"aligned window {list(best)} dispatches in one",
        cur, best_cost,
        fix=Fix(target, "clusters", tuple(best), node=node),
        node=node, name=name,
        suggestion=f"select the aligned power-of-two window {list(best)}")


def _lint_donation(job: Any, operands: Mapping[str, Any], decision: Any,
                   batch: int, params: OccamyParams
                   ) -> Optional[PerfFinding]:
    """OFLP107: fused fresh launches with a dead output-shaped operand."""
    for v in operands.values():
        if callable(getattr(v, "is_deleted", None)):
            return None          # live device buffers may have readers
    shapes = _host_shapes(job, operands)
    if shapes is None:
        return None
    status, out_shape = _verifier._eval_out_shape(job, shapes)
    if status != "ok":
        return None
    match = next((nm for nm, sh in shapes.items() if sh == tuple(out_shape)),
                 None)
    if match is None:
        return None
    nbytes = int(np.asarray(operands[match]).nbytes) * decision.fuse
    launches = math.ceil(batch / decision.fuse)
    cur = launches * donation_copy_cycles(nbytes, params)
    if not _significant(cur, 0.0):
        return None
    return _finding(
        "OFLP107",
        f"donate_operands=False allocates+fills a fresh output per "
        f"launch; the stacked {match!r} buffer dies at launch and "
        f"matches the output shape",
        cur, 0.0, fix=Fix("policy", "donate_operands", True),
        name="donate_operands",
        suggestion="pin donate_operands=True for fused fresh submits")


# -- the graph passes --------------------------------------------------------


def graph_jobs(nodes: Sequence[GraphNode], *,
               default_width: Optional[int] = None,
               params: OccamyParams = DEFAULT_PARAMS
               ) -> Tuple[List[simulator.GraphJob], Dict[str, Any]]:
    """Lower GraphNodes to the simulator's :class:`GraphJob` vocabulary.

    Returns the parallel job list plus metadata (``data_edges`` as
    ``(producer, consumer, operand)`` triples and per-node
    ``out_bytes``).  The shared lowering between :func:`lint_graph` and
    the ``perflint`` bench, so findings and measurements see the same
    structure.
    """
    n_nodes = len(nodes)
    names: Dict[str, int] = {nd.name: i for i, nd in enumerate(nodes)
                             if nd.name}
    sels: List[Tuple[int, ...]] = []
    for nd in nodes:
        if nd.clusters is not None:
            sels.append(tuple(sorted({int(c) for c in nd.clusters})))
        elif nd.n is not None:
            sels.append(tuple(range(int(nd.n))))
        else:
            sels.append(tuple(range(default_width
                                    if default_width is not None else 8)))
    edges: List[Tuple[int, int, str]] = []
    for i, nd in enumerate(nodes):
        if not isinstance(nd.operands, Mapping):
            continue
        for opname, v in nd.operands.items():
            if isinstance(v, Ref):
                d = _verifier._resolve_ref(v.node, names, n_nodes)
                if d is not None:
                    edges.append((d, i, opname))
    # shape propagation in topo order (Kahn over dataflow edges)
    indeg = [0] * n_nodes
    outs: List[List[int]] = [[] for _ in range(n_nodes)]
    for d, v, _ in edges:
        indeg[v] += 1
        outs[d].append(v)
    order = [i for i in range(n_nodes) if indeg[i] == 0]
    for i in order:
        for v in outs[i]:
            indeg[v] -= 1
            if indeg[v] == 0:
                order.append(v)
    out_bytes = [0.0] * n_nodes
    out_shapes: List[Optional[Tuple[int, ...]]] = [None] * n_nodes
    for i in order:
        nd = nodes[i]
        if not isinstance(nd.operands, Mapping):
            continue
        shapes: Dict[str, Tuple[int, ...]] = {}
        itemsize = 8
        complete = True
        for opname, v in nd.operands.items():
            if isinstance(v, Ref):
                d = _verifier._resolve_ref(v.node, names, n_nodes)
                shape = out_shapes[d] if d is not None else None
            else:
                shape = _verifier._shape_of(v)
                arr = np.asarray(v) if shape is not None else None
                if arr is not None:
                    itemsize = int(arr.dtype.itemsize)
            if shape is None:
                complete = False
                break
            shapes[opname] = shape
        if not complete:
            continue
        status, out = _verifier._eval_out_shape(nd.job, shapes)
        if status == "ok":
            out_shapes[i] = tuple(out)
            out_bytes[i] = float(int(np.prod(out)) * itemsize)
    jobs: List[simulator.GraphJob] = []
    for i, nd in enumerate(nodes):
        deps = tuple(d for d, v, _ in edges if v == i)
        rep_in = any(
            isinstance(nd.operands, Mapping)
            and nd.job.shard_axes.get(opname) is None
            for d, v, opname in edges if v == i)
        jobs.append(simulator.GraphJob(
            spec=nd.job.spec, clusters=sels[i], deps=deps,
            out_bytes=out_bytes[i], replicate_in=rep_in))
    return jobs, {"data_edges": edges, "out_bytes": out_bytes,
                  "selections": sels}


def _patched(nodes: Sequence[GraphNode], idx: int,
             sel: Sequence[int]) -> List[GraphNode]:
    out = list(nodes)
    out[idx] = dataclasses.replace(out[idx], clusters=list(sel))
    return out


def _graph_clean(nodes: Sequence[GraphNode],
                 policy: Optional[OffloadPolicy], n_units: int,
                 default_width: Optional[int]) -> bool:
    return not any(
        d.severity is Severity.ERROR
        for d in _verifier.verify_graph(nodes, policy=policy,
                                        n_units=n_units,
                                        default_width=default_width))


def lint_graph(nodes: Sequence[GraphNode], *,
               policy: Optional[OffloadPolicy] = None,
               n_units: int = 4,
               default_width: Optional[int] = None,
               allowed: Optional[Sequence[int]] = None,
               params: OccamyParams = DEFAULT_PARAMS,
               planner: Optional[Planner] = None) -> List[PerfFinding]:
    """Perf-lint a ``submit_graph`` node list against the graph models.

    Runs OFLP104 (cross-selection forward on the critical path — the
    fix realigns the consumer's ``clusters=`` with its producer) and
    OFLP105 (multi-request selections) per node.  Every proposed
    rewrite is re-verified: a fix that would introduce a correctness
    diagnostic is never suggested.  Graphs the verifier rejects return
    no findings.
    """
    if not nodes:
        return []
    if not _graph_clean(nodes, policy, n_units, default_width):
        return []
    plan = planner or Planner(params)
    jobs, meta = graph_jobs(nodes, default_width=default_width,
                            params=params)
    sels = meta["selections"]
    base = simulator.graph_critical_path(jobs, params)
    findings: List[PerfFinding] = []

    # OFLP104 — one finding per consumer paying a forward leg, aligned
    # to whichever producer lowers the closed-form makespan most.
    consumers = sorted({v for _, v, _ in meta["data_edges"]})
    for v in consumers:
        producers = sorted({d for d, vv, _ in meta["data_edges"] if vv == v})
        crossing = [d for d in producers if sels[d] != sels[v]]
        if not crossing:
            continue
        best: Optional[Tuple[float, int, Tuple[int, ...]]] = None
        for d in crossing:
            cand_sel = sels[d]
            nd = nodes[v]
            if (isinstance(nd.operands, Mapping)
                    and not _shard_ok(nd.job, {
                        k: o for k, o in nd.operands.items()
                        if not isinstance(o, Ref)}, len(cand_sel))):
                continue
            cand_jobs = [dataclasses.replace(j, clusters=cand_sel)
                         if i == v else j for i, j in enumerate(jobs)]
            cp = simulator.graph_critical_path(cand_jobs, params)
            if best is None or cp < best[0]:
                best = (cp, d, cand_sel)
        if best is None:
            continue
        cp, d, cand_sel = best
        if not _significant(base, cp):
            continue
        if not _graph_clean(_patched(nodes, v, cand_sel), policy, n_units,
                            default_width):
            continue
        fwd = simulator.forward_model(
            meta["out_bytes"][d], sels[d], sels[v],
            replicate=jobs[v].replicate_in, params=params)
        findings.append(_finding(
            "OFLP104",
            f"node {v} reads node {d} across selections "
            f"({list(sels[d])} -> {list(sels[v])}), paying a "
            f"{fwd:.0f}-cycle forward on the critical path",
            base, cp, fix=Fix("node", "clusters", cand_sel, node=v),
            node=v, name=nodes[v].name,
            suggestion=f"align node {v} clusters= with its producer "
                       f"({list(cand_sel)}) to forward by aliasing"))

    # OFLP105 — per-node multi-request selections (explicit clusters only;
    # request-encoded nodes are the runtime's business).
    for i, nd in enumerate(nodes):
        if nd.clusters is None or nd.request is not None:
            continue
        if not isinstance(nd.operands, Mapping):
            continue
        host_ops = {k: v for k, v in nd.operands.items()
                    if not isinstance(v, Ref)}
        rep = sum(int(np.asarray(v).nbytes) for k, v in host_ops.items()
                  if nd.job.shard_axes.get(k) is None)
        decision = plan.decide(nd.job, list(sels[i]), 1,
                               policy or AUTO, n_units, operands=host_ops)
        f = _lint_selection(nd.job, host_ops, list(sels[i]), decision, rep,
                            params, plan, allowed=allowed, node=i,
                            name=nd.name)
        if f is not None and f.fix is not None:
            if _graph_clean(_patched(nodes, i, f.fix.value), policy,
                            n_units, default_width):
                findings.append(f)

    findings.sort(key=lambda f: -f.delta)
    return findings


# -- the session pass --------------------------------------------------------


def lint_session(session: Any) -> List[PerfFinding]:
    """OFLP106: ``stage()``d residency no later submit redispatched.

    Reads the session's staged-residency ledger (every ``stage()`` call
    records its staging cycles; resident submits bump the use counter)
    and flags entries whose staging leg was pure waste.
    """
    staged: Mapping[Any, Dict[str, Any]] = getattr(
        session, "_staged_residency", {})
    findings: List[PerfFinding] = []
    for key, rec in staged.items():
        if rec.get("uses", 0) > 0:
            continue
        job_name, ids = key
        cyc = float(rec.get("cycles", 0.0))
        findings.append(_finding(
            "OFLP106",
            f"stage({job_name!r}) on clusters {list(ids)} paid "
            f"{cyc:.0f} staging cycles but no submit used "
            f"residency=RESIDENT",
            cyc, 0.0, fix=Fix("stage", "drop", (job_name, tuple(ids))),
            name=job_name,
            suggestion="drop the stage() call, or redispatch with "
                       "operands=Residency.RESIDENT"))
    findings.sort(key=lambda f: -f.delta)
    return findings


# -- autofix -----------------------------------------------------------------


def apply(findings: Iterable[PerfFinding], *,
          policy: Optional[OffloadPolicy] = None,
          nodes: Optional[Sequence[GraphNode]] = None,
          clusters: Optional[Sequence[int]] = None) -> Applied:
    """Apply every machine-applicable fix to the given artifacts.

    Pass whichever of ``policy`` / ``nodes`` / ``clusters`` the findings
    target; fixes without a matching artifact (and advice-only fixes
    like dropping a dead stage) land in ``Applied.skipped``.  ``nodes``
    is never mutated — a patched copy comes back.
    """
    new_nodes = list(nodes) if nodes is not None else None
    new_clusters = (tuple(int(c) for c in clusters)
                    if clusters is not None else None)
    out = Applied(policy=policy, nodes=new_nodes, clusters=new_clusters)
    for f in findings:
        fx = f.fix
        if fx is None:
            out.skipped.append(f)
            continue
        if fx.target == "policy" and out.policy is not None:
            out.policy = out.policy.pinned(**{fx.field: fx.value})
        elif (fx.target == "node" and out.nodes is not None
                and fx.node is not None and 0 <= fx.node < len(out.nodes)):
            value = (list(fx.value) if fx.field == "clusters"
                     else fx.value)
            out.nodes[fx.node] = dataclasses.replace(
                out.nodes[fx.node], **{fx.field: value})
        elif fx.target == "selection" and out.clusters is not None:
            out.clusters = tuple(int(c) for c in fx.value)
        else:
            out.skipped.append(f)
            continue
        out.applied.append(f)
    return out


def suggested_policy(findings: Iterable[PerfFinding],
                     policy: OffloadPolicy) -> OffloadPolicy:
    """The policy with every policy-targeted fix pinned (see
    :meth:`OffloadPolicy.diff` for rendering what changed)."""
    result = apply(findings, policy=policy).policy
    assert result is not None
    return result
